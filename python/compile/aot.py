"""AOT lowering: operator zoo -> HLO text artifacts + manifest.

This is the compile-path half of the three-layer architecture. It lowers
every (operator, grid-shape) pair from ``model.py`` to HLO *text* (NOT a
serialized HloModuleProto: jax >= 0.5 emits 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly) and writes a ``manifest.json`` that the Rust runtime
uses to (a) profile each operator on the PJRT backend and (b) execute ops in
the Fig. 2 ground-truth engine.

Usage:
    python -m compile.aot --out ../artifacts [--quick]

Python runs ONCE here; nothing in this package is imported at simulation
time.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Shape grids. The trace-driven perf model interpolates between grid points,
# so the grids are geometric in the token/context dimensions (latency is
# piecewise-linear in tokens for GEMMs and in ctx for attention).
TOKEN_GRID = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512]
EXPERT_TOKEN_GRID = [1, 2, 4, 8, 16, 32, 64, 128, 256]
PREFILL_GRID = [16, 32, 64, 128, 256, 512]
DECODE_BATCH_GRID = [1, 2, 4, 8, 16, 32]
DECODE_CTX_GRID = [64, 128, 256, 512]

QUICK_TOKEN_GRID = [1, 8, 64]
QUICK_PREFILL_GRID = [16, 64]
QUICK_DECODE_BATCH_GRID = [1, 4]
QUICK_DECODE_CTX_GRID = [64, 128]


def to_hlo_text(lowered) -> str:
    """jax Lowered -> HLO text via StableHLO -> XlaComputation."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _spec_json(s):
    return {"shape": list(s.shape), "dtype": "f32"}


# --------------------------------------------------------------------------
# Operator catalogue: (op kind, callable, param specs, grid vars, flops, bytes)
# --------------------------------------------------------------------------

def catalogue(cfg: M.ModelConfig, quick: bool):
    """Yield one entry per (operator, grid point) for a model config."""
    h, nh, d = cfg.hidden, cfg.heads, cfg.head_dim
    f, v = cfg.ffn, cfg.vocab
    tok_grid = QUICK_TOKEN_GRID if quick else TOKEN_GRID
    exp_grid = QUICK_TOKEN_GRID if quick else EXPERT_TOKEN_GRID
    pre_grid = QUICK_PREFILL_GRID if quick else PREFILL_GRID
    db_grid = QUICK_DECODE_BATCH_GRID if quick else DECODE_BATCH_GRID
    dc_grid = QUICK_DECODE_CTX_GRID if quick else DECODE_CTX_GRID

    for t in tok_grid:
        yield dict(
            name=f"qkv_proj_t{t}",
            op="qkv_proj",
            fn=lambda x, wq, wk, wv: M.qkv_proj(x, wq, wk, wv, heads=nh),
            specs=[f32(t, h), f32(h, h), f32(h, h), f32(h, h)],
            grid={"tokens": t},
            flops=2 * t * h * h * 3,
            bytes=4 * (t * h + 3 * h * h + 3 * t * h),
        )
        yield dict(
            name=f"out_proj_t{t}",
            op="out_proj",
            fn=lambda a, wo: (M.out_proj(a, wo),),
            specs=[f32(nh, t, d), f32(h, h)],
            grid={"tokens": t},
            flops=2 * t * h * h,
            bytes=4 * (t * h * 2 + h * h),
        )
        yield dict(
            name=f"ffn_t{t}",
            op="ffn",
            fn=lambda x, w1, w3, w2: (M.ffn(x, w1, w3, w2),),
            specs=[f32(t, h), f32(h, f), f32(h, f), f32(f, h)],
            grid={"tokens": t},
            flops=2 * t * h * f * 3,
            bytes=4 * (t * h * 2 + 3 * h * f),
        )
        yield dict(
            name=f"lm_head_t{t}",
            op="lm_head",
            fn=lambda x, wl: (M.lm_head(x, wl),),
            specs=[f32(t, h), f32(h, v)],
            grid={"tokens": t},
            flops=2 * t * h * v,
            bytes=4 * (t * h + h * v + t * v),
        )
        yield dict(
            name=f"rmsnorm_t{t}",
            op="rmsnorm",
            fn=lambda x, g: (M.rmsnorm(x, g),),
            specs=[f32(t, h), f32(h)],
            grid={"tokens": t},
            flops=4 * t * h,
            bytes=4 * (2 * t * h + h),
        )

    for s in pre_grid:
        yield dict(
            name=f"attn_prefill_s{s}",
            op="attn_prefill",
            fn=lambda q, k, v: (M.attn_prefill(q, k, v),),
            specs=[f32(nh, s, d)] * 3,
            grid={"tokens": s},
            flops=2 * nh * s * s * d * 2,  # QK^T + PV (causal ~/2 ignored)
            bytes=4 * nh * s * d * 4,
        )

    for b in db_grid:
        for c in dc_grid:
            yield dict(
                name=f"attn_decode_b{b}_c{c}",
                op="attn_decode",
                fn=lambda q, kc, vc: (M.attn_decode(q, kc, vc),),
                specs=[f32(b, nh, d), f32(b, nh, c, d), f32(b, nh, c, d)],
                grid={"batch": b, "ctx": c},
                flops=2 * b * nh * c * d * 2,
                bytes=4 * b * nh * (2 * c * d + 2 * d),
            )

    if cfg.is_moe:
        e, fe = cfg.experts, cfg.expert_ffn
        for t in tok_grid:
            yield dict(
                name=f"moe_gate_t{t}",
                op="moe_gate",
                fn=lambda x, wg: (M.moe_gate(x, wg),),
                specs=[f32(t, h), f32(h, e)],
                grid={"tokens": t},
                flops=2 * t * h * e,
                bytes=4 * (t * h + h * e + t * e),
            )
        for t in exp_grid:
            yield dict(
                name=f"expert_ffn_t{t}",
                op="expert_ffn",
                fn=lambda x, w1, w3, w2: (M.expert_ffn(x, w1, w3, w2),),
                specs=[f32(t, h), f32(h, fe), f32(h, fe), f32(fe, h)],
                grid={"tokens": t},
                flops=2 * t * h * fe * 3,
                bytes=4 * (t * h * 2 + 3 * h * fe),
            )


def lower_model(cfg: M.ModelConfig, out_dir: str, quick: bool):
    """Lower every catalogue entry; return manifest op records."""
    op_dir = os.path.join(out_dir, "ops", cfg.name)
    os.makedirs(op_dir, exist_ok=True)
    records = []
    for entry in catalogue(cfg, quick):
        lowered = jax.jit(entry["fn"]).lower(*entry["specs"])
        text = to_hlo_text(lowered)
        rel = os.path.join("ops", cfg.name, entry["name"] + ".hlo.txt")
        with open(os.path.join(out_dir, rel), "w") as fp:
            fp.write(text)
        records.append(
            {
                "name": entry["name"],
                "op": entry["op"],
                "file": rel,
                "params": [_spec_json(s) for s in entry["specs"]],
                "grid": entry["grid"],
                "flops": entry["flops"],
                "bytes": entry["bytes"],
            }
        )
        print(f"  {cfg.name}/{entry['name']}: {len(text)} chars")
    return records


def model_json(cfg: M.ModelConfig):
    return {
        "name": cfg.name,
        "hidden": cfg.hidden,
        "heads": cfg.heads,
        "ffn": cfg.ffn,
        "layers": cfg.layers,
        "vocab": cfg.vocab,
        "experts": cfg.experts,
        "top_k": cfg.top_k,
        "expert_ffn": cfg.expert_ffn,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models", default="tiny-dense,tiny-moe", help="comma-separated presets"
    )
    ap.add_argument(
        "--quick", action="store_true", help="small grids (CI / pytest)"
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 2, "quick": args.quick, "models": []}
    for name in args.models.split(","):
        cfg = M.PRESETS[name.strip()]
        print(f"lowering {cfg.name} ...")
        records = lower_model(cfg, args.out, args.quick)
        manifest["models"].append({"model": model_json(cfg), "ops": records})
    path = os.path.join(args.out, "manifest.json")
    with open(path, "w") as fp:
        json.dump(manifest, fp, indent=1)
    n_ops = sum(len(m["ops"]) for m in manifest["models"])
    print(f"wrote {n_ops} op artifacts + {path}")


if __name__ == "__main__":
    main()
