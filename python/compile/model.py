"""L2 operator zoo: the JAX compute graph of a transformer decoder, split
into the per-operator units that LLMServingSim2.0's trace-driven performance
model is keyed on.

The simulator (Rust, L3) composes end-to-end iteration latency from
*operator* latencies — exactly the granularity the paper's operator-level
profiler hooks measure between LLM layers. Each function here is one such
operator; ``aot.py`` lowers each one at a grid of shapes to HLO text, and
the Rust profiler measures them on the PJRT backend.

All weights are *parameters* (not baked constants) so the HLO stays small
and the Rust side can feed deterministic random weights; activations are the
leading parameters. Layouts:

  qkv_proj     x[T,H], wq[H,H], wk[H,H], wv[H,H]       -> q,k,v  [nh,T,d] / [T,nh,d]
  attn_prefill q,k,v[nh,S,d]                            -> o[nh,S,d]   (Pallas)
  attn_decode  q[B,nh,d], kc,vc[B,nh,C,d]               -> o[B,nh,d]   (Pallas)
  out_proj     a[T,H], wo[H,H]                          -> x[T,H]
  ffn          x[T,H], w1[H,F], w3[H,F], w2[F,H]        -> x[T,H]      (dense SwiGLU)
  moe_gate     x[T,H], wg[H,E]                          -> probs[T,E]
  expert_ffn   x[T,H], w1[H,Fe], w3[H,Fe], w2[Fe,H]     -> x[T,H]      (Pallas)
  lm_head      x[T,H], wl[H,V]                          -> logits[T,V]
  rmsnorm      x[T,H], g[H]                             -> x[T,H]

``dense_layer`` / ``moe_layer`` compose the full decoder layer for the
pytest shape checks and the Fig. 2 ground-truth engine's block mode.
"""

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import attn_prefill as _attn_prefill_kernel
from .kernels import attn_decode as _attn_decode_kernel
from .kernels import expert_ffn as _expert_ffn_kernel


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters for one model preset."""

    name: str
    hidden: int
    heads: int
    ffn: int  # dense FFN inner dim (SwiGLU)
    layers: int
    vocab: int
    experts: int = 0  # 0 => dense model
    top_k: int = 0
    expert_ffn: int = 0  # per-expert inner dim

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    @property
    def is_moe(self) -> bool:
        return self.experts > 0


# Presets. tiny-* are actually executed/profiled on the CPU PJRT backend;
# the paper-scale specs are used by the simulator's calibrated analytical
# extension (see rust/src/perf/). Mirrored in rust/src/model/.
TINY_DENSE = ModelConfig(
    name="tiny-dense", hidden=256, heads=8, ffn=1024, layers=4, vocab=2048
)
TINY_MOE = ModelConfig(
    name="tiny-moe",
    hidden=256,
    heads=8,
    ffn=1024,
    layers=4,
    vocab=2048,
    experts=8,
    top_k=2,
    expert_ffn=512,
)
PRESETS = {c.name: c for c in (TINY_DENSE, TINY_MOE)}


# --------------------------------------------------------------------------
# Elementary operators
# --------------------------------------------------------------------------

def rmsnorm(x, g, eps=1e-5):
    """RMSNorm over the hidden dimension."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def qkv_proj(x, wq, wk, wv, *, heads):
    """Project activations to per-head Q/K/V.

    Returns q, k, v each shaped ``[nh, T, d]`` (prefill layout).
    """
    t, h = x.shape
    d = h // heads

    def split(y):
        return y.reshape(t, heads, d).transpose(1, 0, 2)

    return split(x @ wq), split(x @ wk), split(x @ wv)


def attn_prefill(q, k, v):
    """Causal prefill attention (Pallas flash kernel)."""
    return _attn_prefill_kernel(q, k, v)


def attn_decode(q, kc, vc):
    """Decode attention against the KV cache (Pallas kernel)."""
    return _attn_decode_kernel(q, kc, vc)


def out_proj(a, wo):
    """Merge heads and apply the output projection.

    Args:
      a: ``[nh, T, d]`` attention output.
    """
    nh, t, d = a.shape
    return a.transpose(1, 0, 2).reshape(t, nh * d) @ wo


def ffn(x, w1, w3, w2):
    """Dense SwiGLU FFN (pure-jnp; XLA fuses this well on its own)."""
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2


def moe_gate(x, wg):
    """Softmax gate probabilities ``[T, E]`` (top-k selection happens in the
    simulator's expert router, which mimics this gate's output statistics)."""
    return jax.nn.softmax(x @ wg, axis=-1)


def expert_ffn(x, w1, w3, w2):
    """One expert's SwiGLU FFN over its routed tokens (Pallas kernel)."""
    return _expert_ffn_kernel(x, w1, w3, w2)


def lm_head(x, wl):
    """Final vocabulary projection."""
    return x @ wl


# --------------------------------------------------------------------------
# Layer compositions (shape checks + ground-truth block mode)
# --------------------------------------------------------------------------

def dense_layer_prefill(x, params, *, heads):
    """One full dense decoder layer over a prompt. ``params`` is a dict with
    wq/wk/wv/wo/w1/w3/w2/g1/g2."""
    h = rmsnorm(x, params["g1"])
    q, k, v = qkv_proj(h, params["wq"], params["wk"], params["wv"], heads=heads)
    a = attn_prefill(q, k, v)
    x = x + out_proj(a, params["wo"])
    h = rmsnorm(x, params["g2"])
    return x + ffn(h, params["w1"], params["w3"], params["w2"])


def moe_layer_prefill(x, params, *, heads, top_k):
    """One MoE decoder layer over a prompt. Dense-equivalent gating: computes
    the gate, then runs every expert over all tokens weighted by its gate
    mass (numerically equals top-k dispatch when the weights are re-zeroed to
    the top-k support, which the test does)."""
    h = rmsnorm(x, params["g1"])
    q, k, v = qkv_proj(h, params["wq"], params["wk"], params["wv"], heads=heads)
    a = attn_prefill(q, k, v)
    x = x + out_proj(a, params["wo"])
    h = rmsnorm(x, params["g2"])
    probs = moe_gate(h, params["wg"])  # [T, E]
    # top-k mask + renormalize
    e = probs.shape[-1]
    thresh = jnp.sort(probs, axis=-1)[:, e - top_k][:, None]
    mask = probs >= thresh
    w = jnp.where(mask, probs, 0.0)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.zeros_like(x)
    for i in range(e):
        y = expert_ffn(
            h, params["we1"][i], params["we3"][i], params["we2"][i]
        )
        out = out + w[:, i : i + 1] * y
    return x + out


def init_params(cfg: ModelConfig, key):
    """Deterministic small-magnitude parameters for one layer."""
    h, f = cfg.hidden, cfg.ffn
    ks = jax.random.split(key, 12)
    scale = 0.02
    p = {
        "wq": jax.random.normal(ks[0], (h, h)) * scale,
        "wk": jax.random.normal(ks[1], (h, h)) * scale,
        "wv": jax.random.normal(ks[2], (h, h)) * scale,
        "wo": jax.random.normal(ks[3], (h, h)) * scale,
        "w1": jax.random.normal(ks[4], (h, f)) * scale,
        "w3": jax.random.normal(ks[5], (h, f)) * scale,
        "w2": jax.random.normal(ks[6], (f, h)) * scale,
        "g1": jnp.ones((h,)),
        "g2": jnp.ones((h,)),
    }
    if cfg.is_moe:
        fe, e = cfg.expert_ffn, cfg.experts
        p["wg"] = jax.random.normal(ks[7], (h, e)) * scale
        p["we1"] = jax.random.normal(ks[8], (e, h, fe)) * scale
        p["we3"] = jax.random.normal(ks[9], (e, h, fe)) * scale
        p["we2"] = jax.random.normal(ks[10], (e, fe, h)) * scale
    return p
