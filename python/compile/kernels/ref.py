"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here, written in
plain ``jax.numpy`` with no tiling, masking tricks, or custom control flow.
``python/tests`` sweeps shapes/dtypes with hypothesis and asserts the Pallas
kernels (interpret=True) match these oracles to float32 tolerance.
"""

import jax.numpy as jnp
from jax import nn


def attn_prefill_ref(q, k, v, scale=None):
    """Causal multi-head attention over a full prompt.

    Args:
      q, k, v: ``[nh, S, d]`` float arrays.
      scale: optional softmax scale; defaults to ``1/sqrt(d)``.

    Returns:
      ``[nh, S, d]`` attention output.
    """
    nh, s, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, -jnp.inf)
    probs = nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def attn_decode_ref(q, k, v, scale=None):
    """Single-token decode attention against a KV cache.

    Args:
      q: ``[B, nh, d]`` — one query token per sequence.
      k, v: ``[B, nh, C, d]`` — KV cache of context length C.

    Returns:
      ``[B, nh, d]`` attention output.
    """
    b, nh, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bhd,bhcd->bhc", q, k) * scale
    probs = nn.softmax(scores, axis=-1)
    return jnp.einsum("bhc,bhcd->bhd", probs, v)


def swiglu_ffn_ref(x, w1, w3, w2):
    """SwiGLU feed-forward: ``(silu(x @ w1) * (x @ w3)) @ w2``.

    Args:
      x: ``[T, H]`` activations.
      w1, w3: ``[H, F]`` up projections.
      w2: ``[F, H]`` down projection.
    """
    a = nn.silu(x @ w1)
    b = x @ w3
    return (a * b) @ w2


def moe_gate_ref(x, wg, top_k):
    """Top-k softmax gate.

    Args:
      x: ``[T, H]``; wg: ``[H, E]``.

    Returns:
      (weights ``[T, top_k]`` normalized over the selected experts,
       indices ``[T, top_k]`` int32)
    """
    logits = x @ wg
    probs = nn.softmax(logits, axis=-1)
    w, idx = jnp.sort(probs, axis=-1)[:, ::-1], jnp.argsort(probs, axis=-1)[:, ::-1]
    w, idx = w[:, :top_k], idx[:, :top_k]
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w, idx.astype(jnp.int32)
