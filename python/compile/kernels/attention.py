"""Pallas attention kernels (the L1 hot-spot).

Two kernels, both written in the flash-attention online-softmax style and
tiled for TPU VMEM via BlockSpec:

* ``attn_prefill`` — causal multi-head attention over a full prompt. The grid
  iterates (head, query-block); each program streams KV blocks through an
  online-softmax ``fori_loop``. On a real TPU the BlockSpec expresses the
  HBM->VMEM schedule that the GPU flash-attention paper expressed with
  threadblocks; the MXU sees (BQ x d) @ (d x BK) tiles.

* ``attn_decode`` — one query token per sequence against a KV cache. The grid
  iterates (batch, head); the context dimension is streamed in BK-sized
  blocks with the same online softmax, bounding VMEM at O(BK * d).

Both kernels MUST run with ``interpret=True`` here: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers the kernel to
plain HLO so the Rust runtime can load the artifact. Real-TPU VMEM/MXU
estimates for these block shapes live in DESIGN.md / EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes. BQ/BK are chosen so that a (BQ, d) query tile, a
# (BK, d) KV tile, and the (BQ, BK) score tile all fit comfortably in VMEM
# (~16 MB/core) with double buffering at paper-scale d (128): that's
# 64*128*4 * 3 buffers * 2 ~= 200 KB, leaving headroom for the accumulator.
DEFAULT_BQ = 64
DEFAULT_BK = 64
NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, scale):
    """One (head, q-block) program of causal flash attention."""
    qi = pl.program_id(1)
    q = q_ref[0] * scale  # [bq, d]
    d = q.shape[-1]
    # Causal: query block qi only attends to KV blocks j <= qi (in bq units;
    # bq == bk is asserted by the wrapper so block-diagonal masking is exact).
    num_kv_blocks = qi + 1

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (0, pl.dslice(j * bk, bk), slice(None)))  # [bk, d]
        v = pl.load(v_ref, (0, pl.dslice(j * bk, bk), slice(None)))  # [bk, d]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]
        # Mask the diagonal block; blocks j < qi are fully visible.
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))  # [bq]
        p = jnp.exp(s - m_new[:, None])  # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)  # [bq]
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, d), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_kv_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def attn_prefill(q, k, v, *, bq=DEFAULT_BQ, bk=DEFAULT_BK, scale=None):
    """Causal flash attention over a prompt.

    Args:
      q, k, v: ``[nh, S, d]``; S must be divisible by ``bq`` (== ``bk``).

    Returns:
      ``[nh, S, d]`` attention output.
    """
    nh, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    if bq != bk:
        raise ValueError(f"bq ({bq}) must equal bk ({bk}) for causal blocking")
    if s % bq != 0:
        raise ValueError(f"seq len {s} not divisible by block {bq}")
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    grid = (nh, s // bq)
    kernel = functools.partial(_prefill_kernel, bq=bq, bk=bk, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, s, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, s, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nh, s, d), q.dtype),
        interpret=True,
    )(q, k, v)


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, *, bk, ctx, scale):
    """One (batch, head) program of decode attention over the KV cache."""
    q = q_ref[0, 0] * scale  # [d]
    d = q.shape[-1]
    num_blocks = ctx // bk

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (0, 0, pl.dslice(j * bk, bk), slice(None)))
        v = pl.load(v_ref, (0, 0, pl.dslice(j * bk, bk), slice(None)))
        s = jnp.dot(k, q, preferred_element_type=jnp.float32)  # [bk]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)  # [bk]
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p)
        acc = acc * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_blocks, body, (m0, l0, acc0))
    o_ref[0, 0] = (acc / l).astype(o_ref.dtype)


def attn_decode(q, k, v, *, bk=DEFAULT_BK, scale=None):
    """Decode attention: one query token per sequence vs. a KV cache.

    Args:
      q: ``[B, nh, d]``; k, v: ``[B, nh, C, d]`` with C divisible by ``bk``.

    Returns:
      ``[B, nh, d]``.
    """
    b, nh, d = q.shape
    ctx = k.shape[2]
    bk = min(bk, ctx)
    if ctx % bk != 0:
        raise ValueError(f"context {ctx} not divisible by block {bk}")
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    grid = (b, nh)
    kernel = functools.partial(_decode_kernel, bk=bk, ctx=ctx, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda i, h: (i, h, 0)),
            pl.BlockSpec((1, 1, ctx, d), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, ctx, d), lambda i, h: (i, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, h: (i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nh, d), q.dtype),
        interpret=True,
    )(q, k, v)
