"""Pallas expert-FFN kernel (the MoE hot-spot).

``expert_ffn`` computes a SwiGLU feed-forward for one expert over a tile of
routed tokens. The grid iterates token blocks; the three weight matrices are
held resident (at paper-scale expert dims H=4096, F=1408 that is
3*4096*1408*2B bf16 ~= 34 MB, which on a real TPU would be further tiled over
F — the BlockSpec below already expresses the F-tiling hook via ``bf``).
The token-block matmuls are MXU-shaped: (BT x H) @ (H x BF).

interpret=True for the same reason as attention.py: the Rust CPU-PJRT
runtime must be able to execute the lowered HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BT = 32


def _expert_ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    """One token-block program: SwiGLU through a single expert's weights."""
    x = x_ref[...]  # [bt, H]
    w1 = w1_ref[...]  # [H, F]
    w3 = w3_ref[...]
    w2 = w2_ref[...]  # [F, H]
    a = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    b = jnp.dot(x, w3, preferred_element_type=jnp.float32)
    h = jax.nn.silu(a) * b  # [bt, F]
    o_ref[...] = jnp.dot(h, w2, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def expert_ffn(x, w1, w3, w2, *, bt=DEFAULT_BT):
    """SwiGLU FFN for one expert over routed tokens.

    Args:
      x: ``[T, H]`` routed-token activations; T divisible by ``bt``.
      w1, w3: ``[H, F]``; w2: ``[F, H]``.

    Returns:
      ``[T, H]``.
    """
    t, hd = x.shape
    f = w1.shape[1]
    bt = min(bt, t)
    if t % bt != 0:
        raise ValueError(f"token count {t} not divisible by block {bt}")

    grid = (t // bt,)
    return pl.pallas_call(
        functools.partial(_expert_ffn_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, hd), lambda i: (i, 0)),
            pl.BlockSpec((hd, f), lambda i: (0, 0)),
            pl.BlockSpec((hd, f), lambda i: (0, 0)),
            pl.BlockSpec((f, hd), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, hd), x.dtype),
        interpret=True,
    )(x, w1, w3, w2)
