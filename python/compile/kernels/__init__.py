"""L1 Pallas kernels: attention (prefill/decode) and MoE expert FFN."""

from .attention import attn_prefill, attn_decode
from .moe import expert_ffn
from . import ref

__all__ = ["attn_prefill", "attn_decode", "expert_ffn", "ref"]
