"""Pallas expert-FFN kernel and gate vs oracles."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import expert_ffn, ref

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def _rand(key, shape, scale=0.1):
    return jax.random.normal(key, shape) * scale


@hypothesis.given(
    t=st.sampled_from([1, 2, 4, 8, 16, 32, 64, 128]),
    h=st.sampled_from([32, 64, 128, 256]),
    f=st.sampled_from([64, 128, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_expert_ffn_matches_ref(t, h, f, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(keys[0], (t, h), 1.0)
    w1, w3 = _rand(keys[1], (h, f)), _rand(keys[2], (h, f))
    w2 = _rand(keys[3], (f, h))
    out = expert_ffn(x, w1, w3, w2)
    exp = ref.swiglu_ffn_ref(x, w1, w3, w2)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


def test_expert_ffn_block_invariance():
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    t, h, f = 128, 64, 128
    x = _rand(keys[0], (t, h), 1.0)
    w1, w3, w2 = _rand(keys[1], (h, f)), _rand(keys[2], (h, f)), _rand(keys[3], (f, h))
    a = expert_ffn(x, w1, w3, w2, bt=16)
    b = expert_ffn(x, w1, w3, w2, bt=128)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_expert_ffn_rejects_indivisible_tokens():
    x = jnp.zeros((48, 32))
    w = jnp.zeros((32, 64))
    w2 = jnp.zeros((64, 32))
    with pytest.raises(ValueError):
        expert_ffn(x, w, w, w2, bt=32)


@hypothesis.given(
    t=st.sampled_from([1, 4, 16, 64]),
    e=st.sampled_from([4, 8, 16]),
    top_k=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gate_topk_properties(t, e, top_k, seed):
    """Gate weights: normalized, top-k indices are the argmax set."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    h = 64
    x = _rand(keys[0], (t, h), 1.0)
    wg = _rand(keys[1], (h, e))
    w, idx = ref.moe_gate_ref(x, wg, top_k)
    assert w.shape == (t, top_k) and idx.shape == (t, top_k)
    np.testing.assert_allclose(np.sum(np.asarray(w), axis=-1), 1.0, rtol=1e-5)
    # indices must be distinct per token
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == top_k
    # gate probs of selected experts dominate unselected ones
    probs = np.asarray(jax.nn.softmax(x @ wg, axis=-1))
    for i in range(t):
        sel = set(np.asarray(idx)[i].tolist())
        unsel = [probs[i, j] for j in range(e) if j not in sel]
        if unsel:
            assert min(probs[i, j] for j in sel) >= max(unsel) - 1e-6


def test_moe_layer_composition():
    """MoE layer (gate + Pallas expert FFNs) runs and keeps shape/finiteness."""
    cfg = M.TINY_MOE
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    x = _rand(jax.random.PRNGKey(1), (32, cfg.hidden), 1.0)
    y = M.moe_layer_prefill(x, params, heads=cfg.heads, top_k=cfg.top_k)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    # MoE layer must actually transform the input
    assert not np.allclose(np.asarray(y), np.asarray(x))
