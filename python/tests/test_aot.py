"""AOT pipeline: HLO-text emission + manifest integrity."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


def test_to_hlo_text_roundtrippable():
    def fn(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert text.startswith("HloModule")
    assert "parameter(0)" in text and "parameter(1)" in text
    # The xla crate's text parser needs the entry computation marker.
    assert "ENTRY" in text


def test_catalogue_covers_all_ops_dense():
    ops = {e["op"] for e in aot.catalogue(M.TINY_DENSE, quick=True)}
    assert ops == {
        "qkv_proj",
        "out_proj",
        "ffn",
        "lm_head",
        "rmsnorm",
        "attn_prefill",
        "attn_decode",
    }


def test_catalogue_covers_all_ops_moe():
    ops = {e["op"] for e in aot.catalogue(M.TINY_MOE, quick=True)}
    assert "moe_gate" in ops and "expert_ffn" in ops


def test_catalogue_flops_monotone_in_tokens():
    entries = [
        e for e in aot.catalogue(M.TINY_DENSE, quick=False) if e["op"] == "ffn"
    ]
    toks = [e["grid"]["tokens"] for e in entries]
    flops = [e["flops"] for e in entries]
    assert toks == sorted(toks)
    assert flops == sorted(flops)
    # FLOPs linear in tokens for GEMMs
    assert flops[-1] * toks[0] == flops[0] * toks[-1]


def test_catalogue_names_unique():
    names = [e["name"] for e in aot.catalogue(M.TINY_MOE, quick=False)]
    assert len(names) == len(set(names))


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out",
            str(out),
            "--quick",
            "--models",
            "tiny-dense",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    return out


def test_manifest_schema(quick_artifacts):
    manifest = json.loads((quick_artifacts / "manifest.json").read_text())
    assert manifest["version"] == 2
    (m,) = manifest["models"]
    assert m["model"]["name"] == "tiny-dense"
    assert m["model"]["hidden"] == 256
    for op in m["ops"]:
        assert set(op) >= {"name", "op", "file", "params", "grid", "flops", "bytes"}
        f = quick_artifacts / op["file"]
        assert f.exists(), op["file"]
        text = f.read_text()
        assert text.startswith("HloModule")
        # every declared param appears in the HLO signature
        assert text.count("parameter(") >= len(op["params"])


def test_manifest_param_shapes_match_hlo(quick_artifacts):
    manifest = json.loads((quick_artifacts / "manifest.json").read_text())
    (m,) = manifest["models"]
    for op in m["ops"][:10]:
        text = (quick_artifacts / op["file"]).read_text()
        for p in op["params"]:
            dims = ",".join(str(d) for d in p["shape"])
            token = f"f32[{dims}]"
            assert token in text, f"{op['name']}: {token} not in HLO"
