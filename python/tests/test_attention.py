"""Pallas attention kernels vs pure-jnp oracles (hypothesis shape sweeps)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import attn_prefill, attn_decode, ref

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=dtype)


@hypothesis.given(
    nh=st.sampled_from([1, 2, 4, 8]),
    s=st.sampled_from([16, 32, 64, 128, 256]),
    d=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_matches_ref(nh, s, d, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (_rand(kk, (nh, s, d)) for kk in keys)
    out = attn_prefill(q, k, v)
    exp = ref.attn_prefill_ref(q, k, v)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


@hypothesis.given(
    b=st.sampled_from([1, 2, 4, 8, 16]),
    nh=st.sampled_from([1, 4, 8]),
    ctx=st.sampled_from([64, 128, 256]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_matches_ref(b, nh, ctx, d, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(keys[0], (b, nh, d))
    k = _rand(keys[1], (b, nh, ctx, d))
    v = _rand(keys[2], (b, nh, ctx, d))
    out = attn_decode(q, k, v)
    exp = ref.attn_decode_ref(q, k, v)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


def test_prefill_causality():
    """Output at position i must not depend on tokens > i."""
    key = jax.random.PRNGKey(0)
    nh, s, d = 2, 64, 16
    keys = jax.random.split(key, 3)
    q, k, v = (_rand(kk, (nh, s, d)) for kk in keys)
    base = attn_prefill(q, k, v)
    # Perturb the last token's K/V; earlier outputs must be bit-identical.
    k2 = k.at[:, -1, :].add(100.0)
    v2 = v.at[:, -1, :].add(100.0)
    pert = attn_prefill(q, k2, v2)
    np.testing.assert_array_equal(np.asarray(base[:, :-1]), np.asarray(pert[:, :-1]))
    assert not np.allclose(base[:, -1], pert[:, -1])


def test_prefill_block_size_invariance():
    """Different BlockSpec tilings must give identical math."""
    key = jax.random.PRNGKey(1)
    nh, s, d = 4, 128, 32
    keys = jax.random.split(key, 3)
    q, k, v = (_rand(kk, (nh, s, d)) for kk in keys)
    a = attn_prefill(q, k, v, bq=32, bk=32)
    b = attn_prefill(q, k, v, bq=128, bk=128)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_decode_block_size_invariance():
    key = jax.random.PRNGKey(2)
    b_, nh, ctx, d = 4, 4, 256, 32
    keys = jax.random.split(key, 3)
    q = _rand(keys[0], (b_, nh, d))
    k = _rand(keys[1], (b_, nh, ctx, d))
    v = _rand(keys[2], (b_, nh, ctx, d))
    a = attn_decode(q, k, v, bk=32)
    b = attn_decode(q, k, v, bk=256)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_prefill_softmax_scale():
    """Custom scale must match the oracle with the same scale."""
    key = jax.random.PRNGKey(3)
    nh, s, d = 2, 32, 16
    keys = jax.random.split(key, 3)
    q, k, v = (_rand(kk, (nh, s, d)) for kk in keys)
    out = attn_prefill(q, k, v, scale=0.5)
    exp = ref.attn_prefill_ref(q, k, v, scale=0.5)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


def test_prefill_rejects_indivisible_seq():
    nh, s, d = 2, 48, 16
    q = jnp.zeros((nh, s, d))
    with pytest.raises(ValueError):
        attn_prefill(q, q, q, bq=64, bk=64) if s % 64 else None
        attn_prefill(q, q, q, bq=32, bk=32)


def test_decode_rejects_indivisible_ctx():
    q = jnp.zeros((1, 2, 16))
    k = jnp.zeros((1, 2, 96, 16))
    with pytest.raises(ValueError):
        attn_decode(q, k, k, bk=64)


def test_prefill_numerical_stability_large_logits():
    """Online softmax must survive large score magnitudes (no inf/nan)."""
    key = jax.random.PRNGKey(4)
    nh, s, d = 2, 64, 32
    keys = jax.random.split(key, 3)
    q, k, v = (_rand(kk, (nh, s, d)) * 30.0 for kk in keys)
    out = attn_prefill(q, k, v)
    assert bool(jnp.all(jnp.isfinite(out)))
    exp = ref.attn_prefill_ref(q, k, v)
    # At 30-sigma logits the softmax saturates; exp/online-rescale rounding
    # differences are amplified, so the check here is stability + loose match.
    np.testing.assert_allclose(out, exp, rtol=2e-2, atol=2e-2)
