"""Operator zoo shape/consistency checks."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M


def _x(t, h, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (t, h)) * 0.5


def test_qkv_proj_shapes_and_values():
    cfg = M.TINY_DENSE
    h, nh, d = cfg.hidden, cfg.heads, cfg.head_dim
    t = 16
    x = _x(t, h)
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    wq, wk, wv = (jax.random.normal(k, (h, h)) * 0.05 for k in keys)
    q, k, v = M.qkv_proj(x, wq, wk, wv, heads=nh)
    assert q.shape == (nh, t, d)
    # head 0 of q equals first d columns of x @ wq
    np.testing.assert_allclose(q[0], (x @ wq)[:, :d], rtol=1e-5, atol=1e-5)


def test_out_proj_inverts_head_split():
    cfg = M.TINY_DENSE
    h, nh, d = cfg.hidden, cfg.heads, cfg.head_dim
    t = 8
    a = jax.random.normal(jax.random.PRNGKey(0), (nh, t, d))
    out = M.out_proj(a, jnp.eye(h))
    merged = a.transpose(1, 0, 2).reshape(t, h)
    np.testing.assert_allclose(out, merged, rtol=1e-6, atol=1e-6)


def test_rmsnorm_unit_scale():
    x = _x(4, 64)
    y = M.rmsnorm(x, jnp.ones((64,)))
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_dense_layer_prefill_composes():
    cfg = M.TINY_DENSE
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    x = _x(64, cfg.hidden)
    y = M.dense_layer_prefill(x, params, heads=cfg.heads)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_dense_layer_is_deterministic():
    cfg = M.TINY_DENSE
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    x = _x(32, cfg.hidden)
    y1 = M.dense_layer_prefill(x, params, heads=cfg.heads)
    y2 = M.dense_layer_prefill(x, params, heads=cfg.heads)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_presets_consistency():
    for cfg in M.PRESETS.values():
        assert cfg.hidden % cfg.heads == 0
        if cfg.is_moe:
            assert 0 < cfg.top_k <= cfg.experts
            assert cfg.expert_ffn > 0


def test_moe_gate_probabilities():
    cfg = M.TINY_MOE
    x = _x(16, cfg.hidden)
    wg = jax.random.normal(jax.random.PRNGKey(2), (cfg.hidden, cfg.experts)) * 0.1
    p = M.moe_gate(x, wg)
    assert p.shape == (16, cfg.experts)
    np.testing.assert_allclose(np.sum(np.asarray(p), axis=-1), 1.0, rtol=1e-5)
