//! Heterogeneous multi-instance fleet (§II-B / Fig. 1a): a GPU-like
//! instance, a TPU-like instance, and a tensor-parallel pair serve the same
//! model behind the global router; compares routing policies on the mixed
//! fleet.
//!
//! This exercises the paper's core flexibility claim: per-instance hardware
//! types, device counts, parallelism schemes, and topologies in one
//! deployment.
//!
//! Run: `cargo run --release --example heterogeneous_fleet`

use llmservingsim::config::{presets, InstanceConfig, SimConfig, TopoKind};
use llmservingsim::coordinator::run_config;
use llmservingsim::util::bench::Table;
use llmservingsim::workload::Traffic;

fn fleet(router: &str) -> SimConfig {
    let mut cfg = presets::single_dense("llama3.1-8b", "rtx3090");
    cfg.name = format!("fleet/{router}");
    // instance 0: single GPU
    // instance 1: TPU-like, ring fabric (much faster device)
    let mut tpu = InstanceConfig::basic("tpu0", "llama3.1-8b", "tpu-v6e");
    tpu.topology = TopoKind::Ring;
    // instance 2: 2-way tensor-parallel GPU pair
    let mut tp2 = InstanceConfig::basic("gpu-tp2", "llama3.1-8b", "rtx3090");
    tp2.devices = 2;
    tp2.tp = 2;
    cfg.instances.push(tpu);
    cfg.instances.push(tp2);
    cfg.router = router.to_string();
    cfg.workload.num_requests = 150;
    cfg.workload.traffic = Traffic::poisson(2.0);
    cfg
}

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(&[
        "router policy",
        "TTFT p99 ms",
        "ITL mean ms",
        "tok/s",
        "util i0/i1/i2 %",
    ]);
    for router in [
        "round-robin",
        "least-outstanding",
        "least-kv",
        "session-affinity",
    ] {
        let name = router.to_string();
        let (r, _) = run_config(fleet(router))?;
        let util = |i: usize| r.utilization.get(&i).copied().unwrap_or(0.0) * 100.0;
        t.row(&[
            name,
            format!("{:.2}", r.ttft_ns.p99 / 1e6),
            format!("{:.3}", r.itl_ns.mean / 1e6),
            format!("{:.0}", r.throughput_tps),
            format!("{:.0}/{:.0}/{:.0}", util(0), util(1), util(2)),
        ]);
    }
    t.print();
    println!(
        "\nexpected shape: load-aware policies beat round-robin on a \
         heterogeneous fleet because instance speeds differ (TPU-like and \
         TP-2 instances absorb more load)."
    );
    Ok(())
}
