//! MoE expert-offloading study (§II-C): serve a MoE model on a
//! memory-constrained device under each offloading strategy.
//!
//! The device memory is overridden so that only ~40% of the expert weights
//! fit after the dense parameters and KV cache — the regime Pre-gated MoE
//! and Duplex target. Expected shape: on-demand blocks on every layer's
//! expert fetch; prefetch hides most of it; PIM executes experts in memory
//! and ships activations instead.
//!
//! Run: `cargo run --release --example moe_offloading`

use llmservingsim::config::{presets, GateKind, OffloadPolicy, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::model::ModelSpec;
use llmservingsim::util::bench::Table;

fn constrained(policy: OffloadPolicy, gate: GateKind) -> SimConfig {
    // Phi-mini-MoE (paper's MoE model) on a 24 GB RTX3090-like card is
    // naturally memory-constrained: the full expert set (~80 GB) cannot be
    // resident, the regime Pre-gated MoE and Duplex target.
    let mut cfg = presets::single_moe("phi-mini-moe", "rtx3090");
    let model = ModelSpec::phi_mini_moe();
    let expert_total = model.moe_layers() * model.experts * model.expert_bytes();
    assert!(expert_total > 24 * (1 << 30), "expected memory pressure");
    if policy == OffloadPolicy::None {
        // All-resident reference needs a device that actually fits the
        // model: an idealized 128 GB card (labelled as such below).
        cfg.instances[0].mem_capacity = Some(128 << 30);
    }
    cfg.instances[0].offload = policy;
    cfg.instances[0].gate = gate;
    cfg.workload.num_requests = 60;
    cfg.workload.traffic = llmservingsim::workload::Traffic::poisson(0.5);
    cfg
}

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(&[
        "gate",
        "offload",
        "TTFT mean ms",
        "TPOT mean ms",
        "tok/s",
        "makespan s",
    ]);
    for gate in [GateKind::Uniform, GateKind::Zipf { s: 1.2 }] {
        for policy in [
            OffloadPolicy::None,
            OffloadPolicy::OnDemand,
            OffloadPolicy::Prefetch,
            OffloadPolicy::Pim,
        ] {
            let gate_name = match gate {
                GateKind::Uniform => "uniform",
                GateKind::Zipf { .. } => "zipf-1.2",
            };
            let (r, _) = run_config(constrained(policy, gate.clone()))?;
            t.row(&[
                gate_name.into(),
                policy.as_str().into(),
                format!("{:.2}", r.ttft_ns.mean / 1e6),
                format!("{:.3}", r.tpot_ns.mean / 1e6),
                format!("{:.0}", r.throughput_tps),
                format!("{:.2}", r.makespan as f64 / 1e9),
            ]);
        }
    }
    t.print();
    println!(
        "\nNOTE: 'none' keeps all experts resident (memory permitting) and is \
         the upper bound; on-demand exposes every fetch; prefetch overlaps \
         fetches with the previous layer's compute; pim moves expert compute \
         to the memory device."
    );
    Ok(())
}
