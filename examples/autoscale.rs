//! Autoscaling walkthrough (DESIGN.md §9): drive a bursty multi-tenant
//! workload through the stepped `SimDriver`, watching the `queue-threshold`
//! controller grow and shrink the fleet between slices, then print the
//! controller timeline from the final report.
//!
//! Run with: `cargo run --example autoscale`

use llmservingsim::config::presets;
use llmservingsim::coordinator::Simulation;
use llmservingsim::sim::MILLI;

fn main() -> anyhow::Result<()> {
    // The bursty autoscale scenario: MMPP bursts far above one instance's
    // service rate, quiet phases long enough to drain, `queue-threshold`
    // controller on a 10 ms tick.
    let cfg = presets::autoscale_bursty();
    println!(
        "scenario '{}': {} requests, controller '{}', tick {} ms, fleet {}..{}",
        cfg.name,
        cfg.workload.num_requests,
        cfg.cluster.controller,
        cfg.cluster.tick_ms,
        cfg.cluster.min_instances,
        cfg.cluster.max_instances,
    );

    let mut sim = Simulation::new(cfg)?;
    let mut driver = sim.driver();

    // Step the simulation in 50 ms slices; the driver exposes a read-only
    // ClusterView between slices — the same snapshot the controller sees.
    println!("\n  t (ms) | active | waiting | in-flight | finished");
    let mut t = 0;
    while !driver.is_done() {
        t += 50 * MILLI;
        driver.run_until(t);
        let view = driver.view();
        println!(
            "  {:>6.0} | {:>6} | {:>7} | {:>9} | {:>8}",
            t as f64 / 1e6,
            view.active(),
            view.total_waiting(),
            view.in_flight,
            view.finished,
        );
    }
    let report = driver.finish();

    println!("\ncontroller timeline (actions only):");
    for e in report.timeline.iter().filter(|e| e.kind != "sample") {
        println!(
            "  t={:>7.1} ms  {:<13} instance={:<3} active={} {}",
            e.at as f64 / 1e6,
            e.kind,
            e.instance.map(|i| i.to_string()).unwrap_or_default(),
            e.active,
            e.detail,
        );
    }

    println!(
        "\nfinished {}/{} requests; fleet peaked at {} instances, ended with {} active",
        report.num_finished,
        report.num_requests,
        sim.peak_instances(),
        sim.num_active_instances(),
    );
    println!(
        "throughput {:.1} tok/s, goodput {:.1} tok/s, controller '{}'",
        report.throughput_tps, report.goodput_tps, report.controller
    );
    Ok(())
}
