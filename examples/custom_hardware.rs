//! Onboard a new accelerator with zero core edits (the paper's §II-A
//! headline): build a hardware bundle — spec + profiled trace samples +
//! derived calibration — register it, and the device immediately resolves
//! *by name* in presets, heterogeneous fleets, and sweep axes.
//!
//! On real hardware the bundle comes from one command
//! (`llmservingsim profile --model tiny-dense --hardware-tag my-npu
//! --emit-bundle my-npu.json`); this example synthesizes the profile so it
//! runs anywhere, then walks the same import path the CLI uses.
//!
//! Run: `cargo run --release --example custom_hardware`

use llmservingsim::config::presets;
use llmservingsim::coordinator::run_config;
use llmservingsim::model::OpKind;
use llmservingsim::perf::hardware::{self, HardwareBundle};
use llmservingsim::perf::trace::TraceDb;
use llmservingsim::perf::HardwareSpec;
use llmservingsim::sweep::{render_table, run_sweep, summarize, SweepSpec};
use llmservingsim::util::bench::Table;

/// Stand-in for `profile --emit-bundle`: a trace DB as the operator-level
/// profiler would emit for a device ~2x faster than the CPU-PJRT baseline.
fn synthetic_profile(tag: &str) -> TraceDb {
    let mut db = TraceDb::new(tag, "tiny-dense");
    for kind in [
        OpKind::QkvProj,
        OpKind::AttnPrefill,
        OpKind::OutProj,
        OpKind::Ffn,
        OpKind::LmHead,
        OpKind::RmsNorm,
    ] {
        for t in [1u64, 4, 16, 64, 256] {
            db.add_tokens(kind, t, 400 * t + 2_000);
        }
    }
    for b in [1u64, 2, 4, 8] {
        for c in [64u64, 256, 1024] {
            db.add_batch_ctx(OpKind::AttnDecode, b, c, 12 * b * c + 2_000);
        }
    }
    db
}

fn main() -> anyhow::Result<()> {
    // 1. "Profile": spec + trace -> bundle file (what --emit-bundle writes).
    let spec = HardwareSpec {
        name: "example-npu".into(),
        peak_flops: 4.0e11,
        mem_bw: 4.0e10,
        mem_capacity: 16 * (1 << 30),
        host_bw: 2.0e10,
        kernel_overhead: 10_000,
    };
    let bundle = HardwareBundle::from_trace(spec, synthetic_profile("example-npu"))?;
    let path = std::env::temp_dir().join("example-npu.json");
    bundle.save(&path)?;
    println!("bundle written to {}", path.display());

    // 2. Import: one call (the CLI's `import-hardware --bundle FILE`).
    let imported = hardware::import_bundle_file(&path)?;
    println!(
        "registered '{}' ({} profiled op kinds, {} calibration factors)",
        imported.spec.name,
        imported.trace.as_ref().map(|db| db.kinds().count()).unwrap_or(0),
        imported.calibration.len()
    );

    // 3. The new name works everywhere a built-in preset does.
    let mut t = Table::new(&["hardware", "TTFT mean ms", "tok/s"]);
    for hw in ["cpu-pjrt", "example-npu"] {
        let mut cfg = presets::single_dense("tiny-dense", hw);
        cfg.name = format!("S(D)@{hw}");
        cfg.workload.num_requests = 40;
        let (report, _) = run_config(cfg)?;
        t.row(&[
            hw.to_string(),
            format!("{:.3}", report.ttft_ns.mean / 1e6),
            format!("{:.1}", report.throughput_tps),
        ]);
    }
    t.print();

    // 4. ... including the sweep engine's hardware axis.
    let mut spec = SweepSpec {
        num_requests: 20,
        quick: true,
        ..SweepSpec::default()
    };
    spec.axes.hardware = vec!["rtx3090".into(), "example-npu".into()];
    let cfgs = spec.expand()?;
    let outcome = run_sweep(&cfgs, 2)?;
    let summary = summarize(&outcome, None)?;
    render_table(&outcome, &summary).print();

    let _ = std::fs::remove_file(&path);
    println!(
        "\nprofile -> bundle -> import -> simulate/sweep: no simulator code \
         was edited to add this device."
    );
    Ok(())
}
