//! Million-request multi-tenant bursty scenario in bounded memory.
//!
//! The workload engine streams requests into the coordinator one at a time
//! (no upfront `Vec<Request>`), and the metrics pipeline folds finished
//! requests into bounded reservoirs — so a 1,000,000-request MMPP on/off
//! workload over three SLO-tiered tenants runs in memory proportional to
//! the *in-flight* state, not the request count.
//!
//! Run: `cargo run --release --example multi_tenant`
//! Env: `LLMSS_REQUESTS=100000` to shrink (or grow) the stream.

use llmservingsim::config::presets;
use llmservingsim::coordinator::run_config;
use llmservingsim::workload::LengthDist;

fn main() -> anyhow::Result<()> {
    let requests: usize = std::env::var("LLMSS_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);

    // M(D) fleet, bursty traffic at ~200 req/s average (peaks at 800),
    // three tenants with alternating interactive/batch SLO classes, and
    // the SLO-deadline scheduler on every instance.
    let mut cfg = presets::multi_tenant_bursty(
        presets::multi_dense("tiny-dense", "rtx3090"),
        3,
        200.0,
    );
    cfg.workload.num_requests = requests;
    cfg.workload.lengths = LengthDist::short();

    println!(
        "streaming {requests} requests ({}) over {} tenants ...",
        cfg.workload.traffic.kind_name(),
        cfg.workload.tenants.len()
    );
    let t0 = std::time::Instant::now();
    let (report, summary) = run_config(cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "finished {}/{} requests | makespan {:.1} s (simulated) | {} engine \
         steps | {:.1} s wall-clock",
        report.num_finished,
        report.num_requests,
        report.makespan as f64 / 1e9,
        summary.steps,
        wall
    );
    println!(
        "throughput {:.0} tok/s | goodput {:.0} tok/s | TTFT p99 {:.2} ms",
        report.throughput_tps,
        report.goodput_tps,
        report.ttft_ns.p99 / 1e6
    );
    for c in &report.per_class {
        println!(
            "  class {:<11} finished {:>8} | SLO attainment {:>5.1} % | \
             goodput {:>8.0} tok/s",
            c.class.as_str(),
            c.num_finished,
            c.slo_attainment * 100.0,
            c.goodput_tps
        );
    }
    for t in &report.per_tenant {
        println!(
            "  tenant {:<10} finished {:>8} | {:>8.0} tok/s | SLO {:>5.1} % | \
             TTFT mean {:.2} ms",
            t.name,
            t.num_finished,
            t.throughput_tps,
            t.slo_attainment * 100.0,
            t.ttft_ns_mean / 1e6
        );
    }
    Ok(())
}
