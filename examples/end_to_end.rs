//! End-to-end driver: exercises the FULL three-layer stack on a real small
//! workload, proving all layers compose (EXPERIMENTS.md §E2E).
//!
//! 1. Loads the JAX/Pallas-lowered HLO operator artifacts (`make artifacts`)
//!    into the Rust PJRT runtime and executes them — Layer 1/2 numerics run
//!    for real on the CPU PJRT client.
//! 2. Runs the **operator-level profiler** over the grid, producing the
//!    latency-trace DB (the paper's single-command hardware integration).
//! 3. Serves a batched request workload on the **ground-truth execution
//!    engine** (every iteration's cost = real measured operator wall-clock)
//!    — this is the "real system" of Fig. 2, reporting latency/throughput.
//! 4. Replays the same workload on the **trace-driven simulator** and
//!    reports the validation error, the paper's headline metric.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use std::path::PathBuf;
use std::sync::Arc;

use llmservingsim::config::{presets, PerfBackend};
use llmservingsim::coordinator::{run_config, Simulation};
use llmservingsim::groundtruth::ExecPerfModel;
use llmservingsim::runtime::profiler::{profile_to_file, ProfileOptions};
use llmservingsim::runtime::{Manifest, Runtime};
use llmservingsim::util::bench::Table;
use llmservingsim::workload::{LengthDist, Traffic};

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from("artifacts");
    if !root.join("manifest.json").exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    if !Runtime::backend_available() {
        anyhow::bail!(
            "no real PJRT backend compiled in (xla stub) — see \
             rust/src/runtime/xla.rs for enabling real execution"
        );
    }

    // ---- 1. Layer 1/2 artifacts execute on PJRT --------------------------
    let manifest = Manifest::load(&root)?;
    let mut rt = Runtime::cpu(&root)?;
    println!("PJRT platform: {}", rt.platform());
    let dense = manifest.model("tiny-dense").unwrap();
    let attn = dense
        .ops
        .iter()
        .find(|o| o.kind == llmservingsim::model::OpKind::AttnPrefill)
        .unwrap();
    let loaded = rt.load(attn)?;
    let out = loaded.execute()?;
    let vals = out.to_tuple1()?.to_vec::<f32>()?;
    anyhow::ensure!(
        vals.iter().all(|v| v.is_finite()),
        "Pallas attention kernel produced non-finite output"
    );
    println!(
        "executed Pallas flash-attention artifact '{}' -> {} finite outputs",
        attn.name,
        vals.len()
    );

    // ---- 2. operator-level profiler --------------------------------------
    let trace_path = root.join("traces/cpu-pjrt-tiny-dense.json");
    if !trace_path.exists() {
        println!("profiling tiny-dense operator grid ...");
        let outcome = profile_to_file(
            &root,
            "tiny-dense",
            &trace_path,
            &ProfileOptions::default(),
        )?;
        println!(
            "profiled {} ops in {:.1} s",
            outcome.ops_profiled,
            outcome.wall_ns as f64 / 1e9
        );
    } else {
        println!("using existing trace {}", trace_path.display());
    }

    // ---- 3. ground-truth serving run (real execution) --------------------
    let mut cfg = presets::single_dense("tiny-dense", "cpu-pjrt");
    cfg.workload.num_requests = 40;
    cfg.workload.traffic = Traffic::poisson(10.0);
    cfg.workload.lengths = LengthDist::short();

    println!("\nserving {} requests on the ground-truth engine ...", 40);
    let gt = Arc::new(ExecPerfModel::new(&root, "tiny-dense")?);
    let gt2 = gt.clone();
    let mut gt_sim = Simulation::builder(cfg.clone())
        .with_perf_factory(move |_, _, _| {
            Ok(gt2.clone() as Arc<dyn llmservingsim::perf::PerfModel>)
        })
        .build()?;
    let t0 = std::time::Instant::now();
    let gt_report = gt_sim.run();
    println!(
        "ground truth: {} operator executions, {:.1} s real compute",
        gt.executions.get(),
        gt.exec_ns.get() as f64 / 1e9
    );
    let gt_wall = t0.elapsed();

    // ---- 4. trace-driven simulation + validation -------------------------
    cfg.perf = PerfBackend::Trace {
        path: trace_path.to_string_lossy().into_owned(),
    };
    let t1 = std::time::Instant::now();
    let (sim_report, summary) = run_config(cfg)?;
    let sim_wall = t1.elapsed();

    let err = sim_report.error_vs(&gt_report);
    let mut t = Table::new(&["metric", "ground truth", "simulated", "error %"]);
    t.row(&[
        "TTFT mean (ms)".into(),
        format!("{:.3}", gt_report.ttft_ns.mean / 1e6),
        format!("{:.3}", sim_report.ttft_ns.mean / 1e6),
        format!("{:.2}", err.ttft_pct),
    ]);
    t.row(&[
        "TPOT mean (ms)".into(),
        format!("{:.3}", gt_report.tpot_ns.mean / 1e6),
        format!("{:.3}", sim_report.tpot_ns.mean / 1e6),
        format!("{:.2}", err.tpot_pct),
    ]);
    t.row(&[
        "ITL mean (ms)".into(),
        format!("{:.3}", gt_report.itl_ns.mean / 1e6),
        format!("{:.3}", sim_report.itl_ns.mean / 1e6),
        format!("{:.2}", err.itl_pct),
    ]);
    t.row(&[
        "throughput (tok/s)".into(),
        format!("{:.1}", gt_report.throughput_tps),
        format!("{:.1}", sim_report.throughput_tps),
        format!("{:.2}", err.throughput_pct),
    ]);
    t.print();
    println!(
        "mean validation error: {:.2} %   (paper: 1.9% avg, <5% per config)",
        err.mean()
    );
    println!(
        "wall-clock: ground truth {:.2} s vs simulator {:.3} s ({} sim steps)",
        gt_wall.as_secs_f64(),
        sim_wall.as_secs_f64(),
        summary.steps
    );
    anyhow::ensure!(
        err.mean() < 15.0,
        "validation error unexpectedly high: {:.2}%",
        err.mean()
    );
    println!("END-TO-END OK: all three layers compose.");
    Ok(())
}
