//! Parallel scenario sweep: expand a 2x2x2 grid (serving preset x request
//! rate x router policy), run it on a worker pool, and print the
//! comparative summary — the design-space-exploration workflow the paper
//! positions LLMServingSim2.0 for.
//!
//! Also demonstrates the determinism contract: per-config reports are
//! byte-identical whether the grid runs on 1 worker or many.
//!
//! Run: `cargo run --release --example sweep`

use llmservingsim::sweep::{
    render_table, run_sweep, summarize, sweep_json, SweepSpec,
};

fn main() -> anyhow::Result<()> {
    let mut spec = SweepSpec {
        num_requests: 60,
        quick: true,
        ..SweepSpec::default()
    };
    spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
    spec.axes.rates = vec![10.0, 40.0];
    spec.axes.routers = vec!["round-robin".into(), "least-outstanding".into()];

    let cfgs = spec.expand()?;
    println!("expanded {} grid points:", cfgs.len());
    for c in &cfgs {
        println!("  {}", c.name);
    }

    // One worker (reference), then a pool: identical per-config reports,
    // different wall-clock.
    let solo = run_sweep(&cfgs, 1)?;
    let pool = run_sweep(&cfgs, 4)?;
    for (a, b) in solo.points.iter().zip(&pool.points) {
        assert_eq!(
            a.report.to_json().to_string(),
            b.report.to_json().to_string(),
            "config '{}' must be byte-identical across worker counts",
            a.name
        );
    }
    println!(
        "\ndeterminism check passed: {} reports byte-identical at 1 and 4 \
         workers\nwall-clock: {:.3} s (1 worker) vs {:.3} s (4 workers)\n",
        pool.points.len(),
        solo.wall_ns as f64 / 1e9,
        pool.wall_ns as f64 / 1e9,
    );

    let summary = summarize(&pool, None)?;
    render_table(&pool, &summary).print();
    println!("baseline: {}", summary.baseline);
    for e in &summary.extremes {
        println!(
            "  {:>16}: best {:>10.3} ({}) | worst {:>10.3} ({})",
            e.metric, e.best, e.best_config, e.worst, e.worst_config
        );
    }

    // The same structure the CLI writes with `--out`.
    let json = sweep_json(&pool, &summary);
    println!(
        "\nJSON report: {} points, {} bytes",
        json.get("points").as_arr().map(|a| a.len()).unwrap_or(0),
        json.to_string().len()
    );
    Ok(())
}
