//! Prefix-caching study (§II-D): session workload with shared system
//! prompts, swept over cache scope (per-instance vs global) and eviction
//! policy, reporting TTFT reduction and hit rates.
//!
//! Run: `cargo run --release --example prefix_caching`

use llmservingsim::config::{presets, CacheScope, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::util::bench::Table;

fn sessions(mut cfg: SimConfig) -> SimConfig {
    // Paper-scale dense model so prefill compute (and thus PC savings) is
    // substantial; long shared system prompts, RAG-agent style.
    cfg.workload.num_requests = 120;
    cfg.workload.sessions = 8;
    cfg.workload.shared_prefix = 384;
    cfg.workload.lengths.prompt_mu = 6.3; // median ~540 tokens
    cfg.workload.traffic = llmservingsim::workload::Traffic::poisson(1.0);
    cfg
}

fn main() -> anyhow::Result<()> {
    // Baseline: same session workload, no prefix cache.
    let (base, _) =
        run_config(sessions(presets::multi_dense("llama3.1-8b", "rtx3090")))?;

    let mut t = Table::new(&[
        "scope",
        "evict",
        "hit rate %",
        "TTFT mean ms",
        "TTFT vs no-PC",
        "tok/s",
    ]);
    t.row(&[
        "(no cache)".into(),
        "-".into(),
        "0.0".into(),
        format!("{:.2}", base.ttft_ns.mean / 1e6),
        "1.00x".into(),
        format!("{:.0}", base.throughput_tps),
    ]);

    // enumerate eviction policies from the registry — a user-registered
    // policy would join this sweep automatically
    let evictions = llmservingsim::policy::snapshot().evict_names();
    for scope in [CacheScope::PerInstance, CacheScope::Global] {
        for policy in &evictions {
            let mut cfg = sessions(presets::with_prefix_cache(
                presets::multi_dense("llama3.1-8b", "rtx3090"),
                scope,
            ));
            for i in &mut cfg.instances {
                if let Some(pc) = &mut i.prefix_cache {
                    pc.policy = policy.clone();
                    // small device tier so eviction policy actually matters
                    pc.device_fraction = 0.05;
                }
            }
            let (r, summary) = run_config(cfg)?;
            let hits: f64 = {
                let total_q: u64 = summary
                    .cache_stats
                    .iter()
                    .map(|c| c.queried_tokens)
                    .sum();
                let total_h: u64 = summary
                    .cache_stats
                    .iter()
                    .map(|c| c.hit_tokens_device + c.hit_tokens_host)
                    .sum();
                if total_q == 0 {
                    0.0
                } else {
                    total_h as f64 / total_q as f64 * 100.0
                }
            };
            t.row(&[
                match scope {
                    CacheScope::PerInstance => "per-instance".into(),
                    CacheScope::Global => "global".into(),
                },
                policy.clone(),
                format!("{hits:.1}"),
                format!("{:.2}", r.ttft_ns.mean / 1e6),
                format!("{:.2}x", base.ttft_ns.mean / r.ttft_ns.mean.max(1.0)),
                format!("{:.0}", r.throughput_tps),
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected shape: global scope + prefix-aware routing concentrates \
         session prefixes, raising hit rate; TTFT improves with hit rate \
         (the paper's motivation for modeling PC system-level)."
    );
    Ok(())
}
