//! Chaos & resilience walkthrough (DESIGN.md §12): soak the multi-tenant
//! bursty scenario under the seeded `chaos` fault injector — correlated
//! zone outages, fabric partitions, stragglers, link degradations — then
//! print the fault timeline and the resilience section of the report
//! (SLO attainment inside vs outside fault windows, per-zone
//! availability). The whole fault schedule is seeded: re-running prints a
//! byte-identical report.
//!
//! Run with: `cargo run --example chaos`

use llmservingsim::config::presets;
use llmservingsim::coordinator::run_config;

fn main() -> anyhow::Result<()> {
    let cfg = presets::chaos_soak();
    println!(
        "scenario '{}': {} requests over {} instances in {} zones, \
         chaos profile: {:.1} faults/s for {} ms",
        cfg.name,
        cfg.workload.num_requests,
        cfg.instances.len(),
        {
            let zones: std::collections::BTreeSet<&str> =
                cfg.instances.iter().map(|i| i.zone.as_str()).collect();
            zones.len()
        },
        cfg.cluster.chaos.fault_rate,
        cfg.cluster.chaos.horizon_ms,
    );

    let (report, summary) = run_config(cfg)?;

    println!("\nfault timeline (injected actions and recoveries):");
    for e in report.timeline.iter().filter(|e| e.kind != "sample") {
        println!(
            "  t={:>7.1} ms  {:<14} instance={:<3} active={} {}",
            e.at as f64 / 1e6,
            e.kind,
            e.instance.map(|i| i.to_string()).unwrap_or_default(),
            e.active,
            e.detail,
        );
    }

    println!(
        "\nfinished {}/{} requests under controller '{}'",
        report.num_finished, report.num_requests, summary.controller
    );
    println!(
        "throughput {:.1} tok/s, goodput {:.1} tok/s",
        report.throughput_tps, report.goodput_tps
    );

    match &report.resilience {
        None => println!("no faults fired inside the horizon"),
        Some(res) => {
            println!(
                "resilience: {} fault windows totaling {:.1} ms \
                 ({} requests finished inside one)",
                res.faults,
                res.fault_ns as f64 / 1e6,
                res.finished_in_fault
            );
            println!(
                "SLO attainment: {:.1} % inside fault windows vs {:.1} % clear",
                res.slo_in_fault * 100.0,
                res.slo_clear * 100.0
            );
            for d in &res.domains {
                println!(
                    "  zone {:<8} {} instance(s): availability {:.2} % \
                     (downtime {:.1} ms)",
                    d.zone,
                    d.instances,
                    d.availability * 100.0,
                    d.downtime_ns as f64 / 1e6
                );
            }
        }
    }
    Ok(())
}
