//! Quickstart: simulate a single-instance dense deployment serving 100
//! ShareGPT-like requests at 10 req/s (the paper's §III-A workload) and
//! print the serving metrics.
//!
//! Run: `cargo run --release --example quickstart`

use llmservingsim::config::presets;
use llmservingsim::coordinator::run_config;
use llmservingsim::util::bench::Table;

fn main() -> anyhow::Result<()> {
    // S(D) from Table II: 1 instance, 1x RTX3090-like device.
    let cfg = presets::single_dense("tiny-dense", "rtx3090");
    println!(
        "simulating '{}': {} requests, Poisson 10 req/s, model={} hw={}",
        cfg.name, cfg.workload.num_requests, cfg.instances[0].model,
        cfg.instances[0].hardware
    );

    let t0 = std::time::Instant::now();
    let (report, summary) = run_config(cfg)?;
    let wall = t0.elapsed();

    let mut t = Table::new(&["metric", "value"]);
    t.row(&["requests finished".into(), report.num_finished.to_string()]);
    t.row(&[
        "makespan".into(),
        format!("{:.2} s", report.makespan as f64 / 1e9),
    ]);
    t.row(&[
        "TTFT  mean / p99".into(),
        format!(
            "{:.2} / {:.2} ms",
            report.ttft_ns.mean / 1e6,
            report.ttft_ns.p99 / 1e6
        ),
    ]);
    t.row(&[
        "TPOT  mean".into(),
        format!("{:.3} ms", report.tpot_ns.mean / 1e6),
    ]);
    t.row(&[
        "ITL   mean / p99".into(),
        format!(
            "{:.3} / {:.3} ms",
            report.itl_ns.mean / 1e6,
            report.itl_ns.p99 / 1e6
        ),
    ]);
    t.row(&[
        "throughput".into(),
        format!("{:.1} tok/s", report.throughput_tps),
    ]);
    t.row(&["engine steps".into(), summary.steps.to_string()]);
    t.row(&[
        "simulation wall-clock".into(),
        format!("{:.3} s", wall.as_secs_f64()),
    ]);
    t.print();
    Ok(())
}
