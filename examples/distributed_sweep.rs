//! Distributed, resumable sweep (DESIGN.md §13): capture a sweep grid +
//! seed-replication axis as an `experiment-manifest-v1` file, run its
//! shards as if they were separate machines, interrupt half-way, resume,
//! and merge — proving the merged aggregate is byte-identical to a
//! single-process run of the same manifest.
//!
//! Run: `cargo run --release --example distributed_sweep`

use std::path::PathBuf;

use llmservingsim::sweep::{
    merge_files, render_aggregate_table, run_all_shards, run_manifest,
    run_shard_to_file, ExperimentManifest, ShardOutcome, SweepSpec,
};

fn main() -> anyhow::Result<()> {
    // 1. An experiment manifest: the sweep axes, the base seed, R
    //    replicates per grid point, and the intended shard count. The
    //    file is the entire experiment definition — every worker runs
    //    from the same bytes, and its hash ties shard results to it.
    let mut spec = SweepSpec {
        num_requests: 30,
        quick: true,
        seed: 0x5EED,
        ..SweepSpec::default()
    };
    spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
    spec.axes.rates = vec![10.0, 40.0];
    spec.axes.routers = vec!["round-robin".into(), "least-outstanding".into()];
    let mut manifest = ExperimentManifest::new(spec);
    manifest.replication = 2; // run every grid point twice, derived seeds
    manifest.shards = 3; // 8 points over 3 shards: slices of 3/3/2

    let dir = PathBuf::from("target/example-distributed-sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let manifest_path = dir.join("experiment.json");
    manifest.save(&manifest_path)?;
    println!(
        "manifest: {} grid points x {} replicate(s), {} shards, hash {}\n",
        manifest.spec.grid_size(),
        manifest.replication,
        manifest.shards,
        manifest.hash()
    );

    // 2. Single-process reference: the bytes every distributed run of
    //    this manifest must reproduce.
    let reference = run_manifest(&manifest, 4)?;

    // 3. "Machine A" runs shard 1/3, "machine B" runs shard 2/3 — then
    //    the experiment is interrupted before shard 3/3 runs.
    let shard_dir = dir.join("shards");
    for shard in 0..2 {
        let out = run_shard_to_file(&manifest, shard, 3, 2, &shard_dir, false)?;
        println!("ran shard {}/3 -> {}", shard + 1, out.path().display());
    }

    // 4. Resume: the driver proves the existing shard files belong to
    //    this exact manifest + partition (content hashes, slice names)
    //    and skips them; only the missing shard actually runs.
    let outcomes = run_all_shards(&manifest, 3, 2, &shard_dir, false)?;
    let skipped = outcomes
        .iter()
        .filter(|o| matches!(o, ShardOutcome::Skipped(_)))
        .count();
    println!(
        "\nresume: {} shard(s) skipped (already complete), {} run",
        skipped,
        outcomes.len() - skipped
    );
    assert_eq!(skipped, 2, "the interrupted shards must be reused");

    // 5. Merge the shard result files into the aggregate and check the
    //    distributed-determinism contract.
    let files: Vec<PathBuf> =
        outcomes.iter().map(|o| o.path().to_path_buf()).collect();
    let merged = merge_files(&manifest, &files)?;
    assert_eq!(
        merged.to_string(),
        reference.to_string(),
        "merge of 3 shards must be byte-identical to the single-process run"
    );
    println!(
        "merge check passed: 3-shard aggregate is byte-identical to the \
         single-process run\n"
    );

    // 6. The aggregate table: with replication > 1 each row carries the
    //    95% CI half-width on its mean throughput over the replicates.
    render_aggregate_table(&merged).print();
    let summary = merged.get("summary");
    println!("baseline: {}", summary.get("baseline").as_str().unwrap_or("?"));
    Ok(())
}
