//! P/D disaggregation study (§II-B): compare a colocated 2-instance
//! deployment against prefill/decode disaggregation across arrival rates,
//! under both KV-transfer policies.
//!
//! The expected shape (Splitwise/DistServe): disaggregation trades a KV
//! transfer per request for phase isolation — decode latency (ITL) stops
//! being polluted by long prefills, at some TTFT cost at low rates.
//!
//! Run: `cargo run --release --example pd_disaggregation`

use llmservingsim::config::{presets, KvTransferPolicy, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::util::bench::Table;
use llmservingsim::workload::Traffic;

fn at_rate(mut cfg: SimConfig, rate: f64) -> SimConfig {
    cfg.workload.traffic = Traffic::poisson(rate);
    cfg.workload.num_requests = 100;
    cfg
}

fn main() -> anyhow::Result<()> {
    // Paper-scale: Llama3.1-8B on RTX3090-like devices (the §III-A setup),
    // priced by the analytical backend. Rates chosen around the knee where
    // prefill interference becomes visible.
    let mut t = Table::new(&[
        "rate req/s",
        "system",
        "TTFT p99 ms",
        "ITL mean ms",
        "ITL p99 ms",
        "tok/s",
    ]);
    for rate in [0.25, 0.5, 1.0, 2.0] {
        let colocated = at_rate(presets::multi_dense("llama3.1-8b", "rtx3090"), rate);
        let (co, _) = run_config(colocated)?;

        let pd = at_rate(presets::pd_dense("llama3.1-8b", "rtx3090"), rate);
        let (pd_block, _) = run_config(pd.clone())?;

        let mut pd_layered = pd;
        for i in &mut pd_layered.instances {
            i.kv_transfer = KvTransferPolicy::Layered;
        }
        let (pd_lay, _) = run_config(pd_layered)?;

        for (name, r) in [
            ("colocated 2x", &co),
            ("P/D blocking", &pd_block),
            ("P/D layered", &pd_lay),
        ] {
            t.row(&[
                format!("{rate}"),
                name.into(),
                format!("{:.2}", r.ttft_ns.p99 / 1e6),
                format!("{:.3}", r.itl_ns.mean / 1e6),
                format!("{:.3}", r.itl_ns.p99 / 1e6),
                format!("{:.0}", r.throughput_tps),
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected shape: P/D isolates decode from prefill interference \
         (lower ITL tail under load); layered KV transfer recovers most of \
         the blocking transfer's TTFT cost."
    );
    Ok(())
}
