//! Custom serving policies in ONE file, zero core edits — the software
//! analogue of the paper's "single command" hardware integration story.
//!
//! Every serving decision point (request routing, wait-queue scheduling,
//! prefix-cache eviction) is an object-safe trait behind a name registry:
//!
//! 1. implement the trait(s) below;
//! 2. register under a name (`policy::register_*_policy`) so configs,
//!    presets, the CLI, and sweep axes can refer to it — or inject an
//!    instance directly with `Simulation::builder` and skip registration;
//! 3. sweep it against the built-ins like any other grid axis.
//!
//! Run: `cargo run --release --example custom_policy`


use llmservingsim::config::presets;
use llmservingsim::coordinator::Simulation;
use llmservingsim::instance::SeqMap;
use llmservingsim::policy::{self, CacheLeaf, EvictionPolicy, SchedulePolicy};
use llmservingsim::router::{
    InstanceView, RoundRobin, RoutePolicy, SessionAffinity,
};
use llmservingsim::sim::Nanos;
use llmservingsim::sweep::{render_table, run_sweep, summarize, SweepSpec};
use llmservingsim::workload::Request;

// ---------------------------------------------------------------------------
// 1. Implement the traits
// ---------------------------------------------------------------------------

/// Routing: prefer the emptiest KV pool, break ties toward fewer
/// outstanding requests (a blend of the built-in `least-kv` and
/// `least-outstanding`).
struct CoolestKv;

impl RoutePolicy for CoolestKv {
    fn choose(&mut self, _req: &Request, candidates: &[InstanceView]) -> usize {
        candidates
            .iter()
            .min_by(|a, b| {
                a.kv_utilization
                    .partial_cmp(&b.kv_utilization)
                    .unwrap()
                    .then((a.outstanding, a.id).cmp(&(b.outstanding, b.id)))
            })
            .unwrap()
            .id
    }
    fn name(&self) -> &str {
        "coolest-kv"
    }
}

/// Scheduling: strict deadline-style aging — order purely by time spent
/// waiting (oldest first), ignoring prompt length.
struct OldestFirst;

impl SchedulePolicy for OldestFirst {
    fn name(&self) -> &str {
        "oldest-first"
    }
    fn order(&mut self, wait: &mut [u64], seqs: &SeqMap, _now: Nanos) {
        wait.sort_by_key(|id| {
            let s = &seqs[id];
            (s.enqueued_at, s.req.id)
        });
    }
}

/// Eviction: drop the coldest leaf, but protect anything accessed at least
/// 3 times (a crude "pinned hot set" on top of LRU).
struct LruWithPin;

impl EvictionPolicy for LruWithPin {
    fn name(&self) -> &str {
        "lru-pinned"
    }
    fn pick(&mut self, leaves: &[CacheLeaf]) -> Option<usize> {
        let unpinned = leaves.iter().filter(|l| l.access_count < 3);
        match unpinned.min_by_key(|l| (l.last_access, l.id)) {
            Some(l) => Some(l.id),
            // everything is hot: fall back to plain LRU rather than refuse
            None => leaves.iter().min_by_key(|l| (l.last_access, l.id)).map(|l| l.id),
        }
    }
}

fn main() -> anyhow::Result<()> {
    // -----------------------------------------------------------------------
    // 2a. Register by name: configs/CLI/sweeps can now say "coolest-kv".
    //     Wrappers compose — a sticky round-robin is one line.
    // -----------------------------------------------------------------------
    policy::register_route_policy("coolest-kv", || Box::new(CoolestKv));
    policy::register_route_policy("sticky-round-robin", || {
        Box::new(SessionAffinity::wrapping(Box::new(RoundRobin::default())))
    });
    policy::register_sched_policy("oldest-first", || Box::new(OldestFirst));
    policy::register_evict_policy("lru-pinned", || Box::new(LruWithPin));

    let registry = policy::snapshot();
    println!("registered routers: {}", registry.route_names().join(", "));
    println!("registered scheds:  {}", registry.sched_names().join(", "));
    println!("registered evicts:  {}\n", registry.evict_names().join(", "));

    // Plain config referring to the customs by name.
    let mut cfg = presets::with_prefix_cache(
        presets::multi_dense("tiny-dense", "rtx3090"),
        llmservingsim::config::CacheScope::PerInstance,
    );
    cfg.router = "coolest-kv".to_string();
    for i in &mut cfg.instances {
        i.sched = "oldest-first".to_string();
        i.prefix_cache.as_mut().unwrap().policy = "lru-pinned".to_string();
    }
    cfg.workload.num_requests = 60;
    let mut sim = Simulation::new(cfg)?;
    println!(
        "by-name resolution: router={}, sched={}",
        sim.router_policy_name(),
        sim.instance(0).sched_name()
    );
    let report = sim.run();
    println!(
        "custom-policy run: {} finished, {:.1} tok/s, TTFT mean {:.2} ms\n",
        report.num_finished,
        report.throughput_tps,
        report.ttft_ns.mean / 1e6
    );

    // -----------------------------------------------------------------------
    // 2b. Or inject without registering: per-simulation overrides.
    // -----------------------------------------------------------------------
    let mut cfg2 = presets::single_dense("tiny-dense", "rtx3090");
    cfg2.workload.num_requests = 30;
    let mut sim2 = Simulation::builder(cfg2)
        .with_route_policy(Box::new(CoolestKv))
        .with_sched_policy(|| Box::new(OldestFirst))
        .build()?;
    let r2 = sim2.run();
    println!(
        "builder injection (no registration): {} finished via '{}'\n",
        r2.num_finished,
        sim2.router_policy_name()
    );

    // -----------------------------------------------------------------------
    // 3. Sweep the custom policies against the built-ins by name.
    // -----------------------------------------------------------------------
    let mut spec = SweepSpec {
        num_requests: 40,
        quick: true,
        ..SweepSpec::default()
    };
    spec.axes.presets = vec!["M(D)+PC".into()];
    spec.axes.routers = vec!["least-outstanding".into(), "coolest-kv".into()];
    spec.axes.scheds = vec!["fcfs".into(), "oldest-first".into()];
    spec.axes.evictions = vec!["lru".into(), "lru-pinned".into()];
    let cfgs = spec.expand()?;
    println!("sweeping {} points (customs x built-ins):", cfgs.len());
    let outcome = run_sweep(&cfgs, 4)?;
    let summary = summarize(&outcome, None)?;
    render_table(&outcome, &summary).print();
    Ok(())
}
