//! Table I reproduction: the feature matrix of LLM serving simulators.
//!
//! Unlike the paper's static table, every "supported" cell here is
//! *demonstrated*: the bench actually configures and runs a simulation
//! exercising that feature and reports ✓ only if the run completes with
//! the feature observably active.
//!
//! Run: `cargo bench --bench table1_features`

use llmservingsim::config::{
    presets, CacheScope, GateKind, InstanceConfig, OffloadPolicy, Role, SimConfig,
};
use llmservingsim::coordinator::run_config;
use llmservingsim::util::bench::Table;
use llmservingsim::workload::{LengthDist, Traffic};

fn small(mut cfg: SimConfig) -> SimConfig {
    cfg.workload.num_requests = 15;
    cfg.workload.lengths = LengthDist::short();
    cfg
}

fn check(name: &str, result: anyhow::Result<bool>) -> (String, String) {
    match result {
        Ok(true) => (name.to_string(), "yes".to_string()),
        Ok(false) => (name.to_string(), "ran, not observed".to_string()),
        Err(e) => (name.to_string(), format!("FAILED: {e}")),
    }
}

fn main() -> anyhow::Result<()> {
    let mut rows = vec![];

    // PD: prefill/decode disaggregation with real KV movement.
    rows.push(check("PD  (prefill/decode disagg.)", {
        let cfg = small(presets::pd_dense("tiny-dense", "rtx3090"));
        let mut sim = llmservingsim::coordinator::Simulation::new(cfg)?;
        let r = sim.run();
        Ok(r.num_finished == 15 && sim.inter_instance_bytes() > 0)
    }));

    // AF: attention/FFN disaggregation.
    rows.push(check("AF  (attention/FFN disagg.)", {
        let mut plain = small(presets::single_dense("tiny-dense", "rtx3090"));
        plain.workload.traffic = Traffic::burst();
        let mut af = plain.clone();
        af.instances[0].af_disagg = true;
        let (p, _) = run_config(plain)?;
        let (a, _) = run_config(af)?;
        // AF must complete and change timing (attention priced on PIM + hops)
        Ok(a.num_finished == 15 && (a.makespan != p.makespan))
    }));

    // PP/TP: pipeline and tensor parallelism.
    rows.push(check("PP/TP (pipeline/tensor par.)", {
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.instances[0].devices = 4;
        cfg.instances[0].tp = 2;
        cfg.instances[0].pp = 2;
        let (r, _) = run_config(cfg)?;
        Ok(r.num_finished == 15)
    }));

    // DP: data parallelism (multiple replicas behind the router).
    rows.push(check("DP  (data parallelism)", {
        let mut cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        cfg.workload.traffic = Traffic::burst();
        let (r, _) = run_config(cfg)?;
        Ok(r.num_finished == 15
            && r.utilization.values().filter(|&&u| u > 0.0).count() == 2)
    }));

    // EP: expert parallelism.
    rows.push(check("EP  (expert parallelism)", {
        let mut cfg = small(presets::single_moe("tiny-moe", "rtx3090"));
        cfg.instances[0].devices = 4;
        cfg.instances[0].tp = 4;
        cfg.instances[0].ep = 4;
        let (r, _) = run_config(cfg)?;
        Ok(r.num_finished == 15)
    }));

    // PA: PagedAttention (block-granular KV with preemption/recompute).
    rows.push(check("PA  (PagedAttention memory)", {
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        // small KV pool (fits any one request, not the burst) forces block
        // recycling + preemption/recompute
        cfg.instances[0].mem_capacity =
            Some(llmservingsim::model::ModelSpec::tiny_dense().param_bytes() + (3 << 20));
        cfg.workload.traffic = Traffic::burst();
        let mut sim = llmservingsim::coordinator::Simulation::new(cfg)?;
        let r = sim.run();
        Ok(r.num_finished == 15 && sim.instance(0).blocks.total_blocks() > 0)
    }));

    // PC: prefix caching.
    rows.push(check("PC  (prefix caching)", {
        let cfg = small(presets::with_prefix_cache(
            presets::single_dense("tiny-dense", "rtx3090"),
            CacheScope::PerInstance,
        ));
        let (r, s) = run_config(cfg)?;
        Ok(r.num_finished == 15 && s.cache_stats[0].hit_rate() > 0.0)
    }));

    // EO: expert offloading.
    rows.push(check("EO  (expert offloading)", {
        let mut cfg = small(presets::single_moe("tiny-moe", "rtx3090"));
        cfg.instances[0].offload = OffloadPolicy::Prefetch;
        cfg.instances[0].gate = GateKind::Zipf { s: 1.0 };
        // memory pressure so offloading is active
        let m = llmservingsim::model::ModelSpec::tiny_moe();
        cfg.instances[0].mem_capacity =
            Some(m.param_bytes() - m.expert_bytes() * 16 + (1 << 20));
        let (r, _) = run_config(cfg)?;
        Ok(r.num_finished == 15)
    }));

    // Heterogeneous multi-instance (Fig. 1a flexibility).
    rows.push(check("Heterogeneous instances", {
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.instances
            .push(InstanceConfig::basic("tpu", "tiny-dense", "tpu-v6e"));
        let mut moe = InstanceConfig::basic("moe", "tiny-moe", "rtx3090");
        moe.role = Role::Unified;
        cfg.instances.push(moe);
        cfg.workload.traffic = Traffic::burst();
        let (r, _) = run_config(cfg)?;
        Ok(r.num_finished == 15)
    }));

    let mut t = Table::new(&["feature (Table I column)", "LLMServingSim2.0 (ours)"]);
    let mut all_ok = true;
    for (f, s) in rows {
        all_ok &= s == "yes";
        t.row(&[f, s]);
    }
    println!("\nTable I: serving-technique support matrix (demonstrated live)");
    t.print();
    println!(
        "\nreference (paper): LLMServingSim lacks PD/DP/EP/PC/EO; Vidur lacks \
         PD/AF/EP/PC/EO; APEX lacks PD/AF/PA/PC/EO; TokenSim lacks AF/EP/EO."
    );
    assert!(all_ok, "some Table I features failed to demonstrate");
    Ok(())
}
