//! Ablation (§II-C): expert-offloading strategies for Phi-mini-MoE on a
//! memory-constrained 24 GB device, under uniform vs skewed gates.
//!
//! Run: `cargo bench --bench ablation_offload`

use llmservingsim::config::{presets, GateKind, OffloadPolicy, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::util::bench::Table;
use llmservingsim::workload::Traffic;

fn cfg(policy: OffloadPolicy, gate: GateKind) -> SimConfig {
    let mut cfg = presets::single_moe("phi-mini-moe", "rtx3090");
    if policy == OffloadPolicy::None {
        cfg.instances[0].mem_capacity = Some(128 << 30); // idealized reference
    }
    cfg.instances[0].offload = policy;
    cfg.instances[0].gate = gate;
    cfg.workload.num_requests = 40;
    cfg.workload.traffic = Traffic::poisson(0.5);
    cfg
}

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(&[
        "gate",
        "offload",
        "TTFT mean ms",
        "TPOT mean ms",
        "tok/s",
        "vs all-resident",
    ]);
    for gate in [GateKind::Uniform, GateKind::Zipf { s: 1.2 }] {
        let gate_name = match gate {
            GateKind::Uniform => "uniform",
            GateKind::Zipf { .. } => "zipf-1.2",
        };
        let (reference, _) = run_config(cfg(OffloadPolicy::None, gate.clone()))?;
        for policy in [
            OffloadPolicy::None,
            OffloadPolicy::OnDemand,
            OffloadPolicy::Prefetch,
            OffloadPolicy::Pim,
        ] {
            let (r, _) = run_config(cfg(policy, gate.clone()))?;
            t.row(&[
                gate_name.into(),
                if policy == OffloadPolicy::None {
                    "none (128GB ref)".into()
                } else {
                    policy.as_str().into()
                },
                format!("{:.1}", r.ttft_ns.mean / 1e6),
                format!("{:.2}", r.tpot_ns.mean / 1e6),
                format!("{:.0}", r.throughput_tps),
                format!(
                    "{:.2}x thpt",
                    r.throughput_tps / reference.throughput_tps.max(1e-9)
                ),
            ]);
        }
    }
    println!("\nAblation: expert offloading, Phi-mini-MoE on 24 GB (experts ~80 GB)");
    t.print();
    println!(
        "expected: on-demand worst (blocking fetches); prefetch hides what \
         overlap allows; PIM avoids weight movement entirely (Duplex)."
    );
    Ok(())
}
