//! Fig. 3 reproduction: wall-clock simulation time across nine serving
//! configurations, comparing three simulator generations:
//!
//! * **LLMServingSim** — cycle-level hardware simulation per operator
//!   invocation (`perf::cycle`, walking the systolic tile schedule);
//! * **LLMServingSim+** — the same with computation reuse (`perf::replay`);
//! * **LLMServingSim2.0** — trace-driven lookups (`perf::trace`).
//!
//! Expected shape (paper): cycle sim slowest by orders of magnitude
//! (509x vs 2.0 in Table III); 2.0 fastest; runtime grows single < P/D <
//! multi, MoE > dense; prefix caching can go either way.
//!
//! Run: `cargo bench --bench fig3_simtime`
//! Env: LLMSS_REQUESTS=100 for the paper's full request count.

use std::path::PathBuf;
use std::time::Instant;

use llmservingsim::config::{presets, PerfBackend, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::runtime::profiler::{profile_to_file, ProfileOptions};
use llmservingsim::util::bench::Table;
use llmservingsim::workload::LengthDist;

fn requests() -> usize {
    std::env::var("LLMSS_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50)
}

fn prep(mut cfg: SimConfig, perf: PerfBackend) -> SimConfig {
    cfg.workload.num_requests = requests();
    cfg.workload.lengths = LengthDist::short();
    cfg.perf = perf;
    cfg
}

fn time_run(cfg: SimConfig) -> anyhow::Result<f64> {
    let t0 = Instant::now();
    let (report, _) = run_config(cfg)?;
    let dt = t0.elapsed().as_secs_f64();
    assert!(report.num_finished > 0);
    Ok(dt)
}

fn ensure_trace(root: &PathBuf, model: &str) -> anyhow::Result<String> {
    let p = root.join(format!("traces/cpu-pjrt-{model}.json"));
    if !p.exists() {
        eprintln!("profiling {model} (first run) ...");
        profile_to_file(root, model, &p, &ProfileOptions::default())?;
    }
    Ok(p.to_string_lossy().into_owned())
}

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from("artifacts");
    let have_artifacts = root.join("manifest.json").exists()
        && llmservingsim::runtime::Runtime::backend_available();

    let mut t = Table::new(&[
        "config",
        "LLMServingSim (cycle) s",
        "LLMServingSim+ (replay) s",
        "2.0 (trace) s",
        "cycle/2.0",
        "replay/2.0",
    ]);

    for cfg in presets::fig3_configs("tiny-dense", "tiny-moe", "rtx3090") {
        let name = cfg.name.clone();
        eprintln!("[{name}] ...");
        let cycle = time_run(prep(cfg.clone(), PerfBackend::Cycle))?;
        let replay = time_run(prep(cfg.clone(), PerfBackend::CycleReplay))?;
        // 2.0: trace-driven if artifacts exist; otherwise the calibrated
        // analytical path exercises the same lookup-cost structure.
        let trace_backend = if have_artifacts {
            let model = if cfg.instances[0].model.contains("moe") {
                "tiny-moe"
            } else {
                "tiny-dense"
            };
            PerfBackend::Trace {
                path: ensure_trace(&root, model)?,
            }
        } else {
            PerfBackend::Analytical
        };
        let trace = time_run(prep(cfg.clone(), trace_backend))?;
        t.row(&[
            name,
            format!("{cycle:.3}"),
            format!("{replay:.3}"),
            format!("{trace:.3}"),
            format!("{:.1}x", cycle / trace.max(1e-9)),
            format!("{:.1}x", replay / trace.max(1e-9)),
        ]);
    }
    println!(
        "\nFig. 3: simulation wall-clock for {} ShareGPT-like requests",
        requests()
    );
    t.print();
    println!(
        "\nexpected shape: cycle >> replay >= trace; single < P/D < multi; \
         MoE > dense (per-layer expert routing overhead)."
    );

    // Paper-scale datapoint: the cycle/trace gap grows with model size
    // (the paper's 509x is for full-size models on the NPU simulator).
    eprintln!("[paper-scale S(D), llama3.1-8b, 3 requests] ...");
    let mut big = presets::single_dense("llama3.1-8b", "rtx3090");
    big.workload.num_requests = 3;
    big.workload.lengths = LengthDist::short();
    let mut c = big.clone();
    c.perf = PerfBackend::Cycle;
    let cyc = time_run(c)?;
    let mut a = big.clone();
    a.perf = PerfBackend::Analytical; // same O(1)-lookup cost class as trace
    let tr = time_run(a)?;
    println!(
        "\npaper-scale extrapolation (Llama3.1-8B, 3 requests): cycle {cyc:.2} s \
         vs O(1)-model {tr:.4} s -> {:.0}x (paper: 509x for the full run)",
        cyc / tr.max(1e-9)
    );
    Ok(())
}
