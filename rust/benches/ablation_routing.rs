//! Ablation (§II-B): global request-router policies on a heterogeneous
//! fleet under skewed session load — the study the paper's customizable
//! routing interface exists for.
//!
//! Run: `cargo bench --bench ablation_routing`

use llmservingsim::config::{presets, InstanceConfig, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::util::bench::Table;
use llmservingsim::workload::Traffic;

fn fleet(router: &str) -> SimConfig {
    let mut cfg = presets::single_dense("llama3.1-8b", "rtx3090");
    let mut fast = InstanceConfig::basic("tpu0", "llama3.1-8b", "tpu-v6e");
    fast.topology = llmservingsim::config::TopoKind::Ring;
    cfg.instances.push(fast);
    cfg.router = router.to_string();
    cfg.workload.num_requests = 120;
    cfg.workload.traffic = Traffic::poisson(1.5);
    cfg.workload.sessions = 6; // Zipf sessions => skewed affinity load
    cfg.workload.shared_prefix = 32;
    cfg
}

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(&[
        "router policy",
        "TTFT mean ms",
        "TTFT p99 ms",
        "ITL mean ms",
        "tok/s",
        "util gpu/tpu %",
    ]);
    // enumerate the registry: custom registered routers join the ablation
    for router in llmservingsim::policy::snapshot().route_names() {
        let name = router.clone();
        let (r, _) = run_config(fleet(&router))?;
        let u = |i: usize| r.utilization.get(&i).copied().unwrap_or(0.0) * 100.0;
        t.row(&[
            name,
            format!("{:.2}", r.ttft_ns.mean / 1e6),
            format!("{:.2}", r.ttft_ns.p99 / 1e6),
            format!("{:.3}", r.itl_ns.mean / 1e6),
            format!("{:.0}", r.throughput_tps),
            format!("{:.0}/{:.0}", u(0), u(1)),
        ]);
    }
    println!("\nAblation: routing policies, heterogeneous 2-instance fleet");
    t.print();
    println!("expected: load-aware policies shift work to the faster instance.");
    Ok(())
}
