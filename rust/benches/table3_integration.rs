//! Table III reproduction: the cost of integrating a NEW hardware backend
//! into the simulator, two ways:
//!
//! * **LLMServingSim route** — write/port a cycle-level hardware simulator
//!   and wire it into the framework (here: `perf/cycle.rs` + `perf/replay.rs`
//!   + the backend plumbing in `coordinator::build_perf`). LoC counted from
//!   the actual sources; simulation runs through the cycle model; error
//!   measured against the ground-truth execution engine.
//! * **LLMServingSim2.0 route** — run the operator-level profiler once
//!   (`runtime/profiler.rs` invocation glue only; the profiler itself is
//!   backend-agnostic). Offline profiling time measured live; simulation
//!   runs trace-driven; error measured against the same ground truth.
//!
//! Paper numbers for the TPU backend: 4764 vs 258 LoC, 1524.7 vs 3.0 min
//! sim time (509x), 14.7% vs 2.25% error. Expected *shape* here: an order
//! of magnitude fewer LoC, orders faster simulation, lower error.
//!
//! Run: `cargo bench --bench table3_integration` (needs `make artifacts`)

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use llmservingsim::config::{presets, PerfBackend, SimConfig};
use llmservingsim::coordinator::{run_config, Simulation};
use llmservingsim::groundtruth::ExecPerfModel;
use llmservingsim::metrics::Report;
use llmservingsim::runtime::profiler::{profile_to_file, ProfileOptions};
use llmservingsim::util::bench::Table;
use llmservingsim::workload::LengthDist;

/// Non-blank, non-comment lines (the paper's LoC metric).
fn loc(src: &str) -> usize {
    src.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with("//") && !l.starts_with("//!"))
        .count()
}

fn cfg_base() -> SimConfig {
    let mut cfg = presets::single_dense("tiny-dense", "cpu-pjrt");
    cfg.workload.num_requests = std::env::var("LLMSS_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(25);
    cfg.workload.lengths = LengthDist::short();
    cfg
}

fn ground_truth(root: &PathBuf) -> anyhow::Result<Report> {
    let gt = Arc::new(ExecPerfModel::new(root, "tiny-dense")?);
    let mut sim = Simulation::builder(cfg_base())
        .with_perf_factory(move |_, _, _| {
            Ok(gt.clone() as Arc<dyn llmservingsim::perf::PerfModel>)
        })
        .build()?;
    Ok(sim.run())
}

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from("artifacts");
    if !root.join("manifest.json").exists()
        || !llmservingsim::runtime::Runtime::backend_available()
    {
        eprintln!(
            "SKIP: needs `make artifacts` and a real PJRT backend \
             (built with the xla stub?)"
        );
        return Ok(());
    }

    // ---- LoC accounting (from the real sources in this repo) -------------
    let cycle_loc = loc(include_str!("../src/perf/cycle.rs"))
        + loc(include_str!("../src/perf/replay.rs"));
    // Trace route: the per-backend work is the profiler *invocation* — the
    // CLI glue in main.rs (cmd_profile) plus the ProfileOptions struct.
    // Counted here as the profiler's public entry surface:
    let profiler_glue_loc = 60; // cmd_profile + ProfileOptions (see main.rs)

    // ---- ground truth ------------------------------------------------------
    eprintln!("running ground truth ...");
    let gt = ground_truth(&root)?;

    // ---- LLMServingSim route: cycle-level simulation -----------------------
    eprintln!("running cycle-level simulation ...");
    let t0 = Instant::now();
    let mut cyc_cfg = cfg_base();
    cyc_cfg.perf = PerfBackend::Cycle;
    let (cyc_report, _) = run_config(cyc_cfg)?;
    let cyc_time = t0.elapsed().as_secs_f64();
    let cyc_err = cyc_report.error_vs(&gt).mean();

    // ---- LLMServingSim2.0 route: profile once, simulate trace-driven -------
    eprintln!("profiling (offline phase) ...");
    let trace_path = std::env::temp_dir().join("llmss_t3_trace.json");
    let t1 = Instant::now();
    let outcome = profile_to_file(
        &root,
        "tiny-dense",
        &trace_path,
        &ProfileOptions::default(),
    )?;
    let prof_time = t1.elapsed().as_secs_f64();

    eprintln!("running trace-driven simulation ...");
    let t2 = Instant::now();
    let mut tr_cfg = cfg_base();
    tr_cfg.perf = PerfBackend::Trace {
        path: trace_path.to_string_lossy().into_owned(),
    };
    let (tr_report, _) = run_config(tr_cfg)?;
    let tr_time = t2.elapsed().as_secs_f64();
    let tr_err = tr_report.error_vs(&gt).mean();

    let mut t = Table::new(&[
        "integration route",
        "LoC",
        "offline prof.",
        "sim time s",
        "error %",
    ]);
    t.row(&[
        "LLMServingSim (cycle sim)".into(),
        cycle_loc.to_string(),
        "-".into(),
        format!("{cyc_time:.3}"),
        format!("{cyc_err:.2}"),
    ]);
    t.row(&[
        "LLMServingSim2.0 (profiler)".into(),
        profiler_glue_loc.to_string(),
        format!("{prof_time:.1} s ({} ops)", outcome.ops_profiled),
        format!("{tr_time:.3}"),
        format!("{tr_err:.2}"),
    ]);
    println!("\nTable III: hardware-backend integration cost");
    t.print();
    println!(
        "\nLoC ratio {:.1}x (paper 18.5x)   sim-time ratio {:.0}x (paper 509x)   \
         error {:.2}% -> {:.2}% (paper 14.7% -> 2.25%)",
        cycle_loc as f64 / profiler_glue_loc as f64,
        cyc_time / tr_time.max(1e-9),
        cyc_err,
        tr_err,
    );
    let _ = std::fs::remove_file(&trace_path);
    Ok(())
}
