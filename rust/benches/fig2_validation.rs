//! Fig. 2 reproduction: latency (a: TPOT, ITL) and throughput (b) of the
//! trace-driven simulator vs the real (ground-truth execution) system,
//! across the five Table II configurations SD, SM, MD, MM, PDD.
//!
//! Paper setup: vLLM on 4x RTX 3090 is the real system. Here the real
//! system is the same serving stack executing its compiled HLO operators on
//! the CPU PJRT client (DESIGN.md §1); the simulator predicts it from
//! profiled traces. Expected shape: error within single-digit percent;
//! single-instance < multi-instance < PDD/MoE error ordering.
//!
//! Run: `cargo bench --bench fig2_validation`
//! (needs `make artifacts`; profiles on first run)
//! Env: LLMSS_REQUESTS=100 for the paper's full request count.

use std::path::PathBuf;
use std::sync::Arc;

use llmservingsim::config::{presets, PerfBackend, SimConfig};
use llmservingsim::coordinator::{run_config, Simulation};
use llmservingsim::groundtruth::ExecPerfModel;
use llmservingsim::metrics::Report;
use llmservingsim::runtime::profiler::{profile_to_file, ProfileOptions};
use llmservingsim::util::bench::Table;
use llmservingsim::workload::LengthDist;

fn requests() -> usize {
    std::env::var("LLMSS_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30)
}

fn ensure_trace(root: &PathBuf, model: &str) -> anyhow::Result<String> {
    // Always re-profile: on a shared machine, traces must be contemporaneous
    // with the ground-truth runs they are validated against.
    let p = root.join(format!("traces/cpu-pjrt-{model}.json"));
    eprintln!("profiling {model} ...");
    profile_to_file(root, model, &p, &ProfileOptions::default())?;
    Ok(p.to_string_lossy().into_owned())
}

fn prep(mut cfg: SimConfig) -> SimConfig {
    for i in &mut cfg.instances {
        i.hardware = "cpu-pjrt".into();
    }
    cfg.workload.num_requests = requests();
    cfg.workload.lengths = LengthDist::short();
    // The paper's arrival process: Poisson at 10 req/s (§III-A). With
    // device-resident inputs the CPU-PJRT testbed sustains this at moderate
    // utilization, like the paper's GPU testbed.
    cfg.workload.traffic = llmservingsim::workload::Traffic::poisson(10.0);
    cfg
}

fn ground_truth(
    cfg: &SimConfig,
    engines: &[(String, Arc<ExecPerfModel>)],
) -> anyhow::Result<Report> {
    let engines = engines.to_vec();
    let mut sim = Simulation::builder(cfg.clone())
        .with_perf_factory(move |_, model, _| {
            let found = engines
                .iter()
                .find(|(m, _)| m == &model.name)
                .expect("engine prepared in main");
            Ok(found.1.clone() as Arc<dyn llmservingsim::perf::PerfModel>)
        })
        .build()?;
    Ok(sim.run())
}

fn main() -> anyhow::Result<()> {
    let root = PathBuf::from("artifacts");
    if !root.join("manifest.json").exists()
        || !llmservingsim::runtime::Runtime::backend_available()
    {
        eprintln!(
            "SKIP: needs `make artifacts` and a real PJRT backend \
             (built with the xla stub?)"
        );
        return Ok(());
    }
    // Shared, pre-warmed ground-truth engines (compile cost excluded from
    // serving measurements, as with any warmed-up real serving stack).
    // Warm-up happens BEFORE profiling so the profiler measures in the same
    // process memory state (hundreds of resident executables) the ground
    // truth will execute in.
    eprintln!("warming ground-truth engines ...");
    let engines: Vec<(String, Arc<ExecPerfModel>)> = vec![
        (
            "tiny-dense".into(),
            Arc::new(ExecPerfModel::new(&root, "tiny-dense")?),
        ),
        (
            "tiny-moe".into(),
            Arc::new(ExecPerfModel::new(&root, "tiny-moe")?),
        ),
    ];
    let dense_trace = ensure_trace(&root, "tiny-dense")?;
    let moe_trace = ensure_trace(&root, "tiny-moe")?;

    let configs = presets::fig2_configs("tiny-dense", "tiny-moe", "cpu-pjrt");
    let mut t2a = Table::new(&[
        "config",
        "TPOT real ms",
        "TPOT sim ms",
        "err %",
        "ITL real ms",
        "ITL sim ms",
        "err %",
    ]);
    let mut t2b = Table::new(&["config", "thpt real tok/s", "thpt sim tok/s", "err %"]);
    let mut errs = vec![];

    for cfg in configs {
        let cfg = prep(cfg);
        let name = cfg.name.clone();
        eprintln!("[{name}] ground truth ({} requests) ...", requests());
        let gt = ground_truth(&cfg, &engines)?;

        let mut sim_cfg = cfg.clone();
        let is_moe = sim_cfg.instances[0].model.contains("moe");
        sim_cfg.perf = PerfBackend::Trace {
            path: if is_moe {
                moe_trace.clone()
            } else {
                dense_trace.clone()
            },
        };
        let (sim, _) = run_config(sim_cfg)?;

        let e = sim.error_vs(&gt);
        errs.push((name.clone(), e.mean()));
        t2a.row(&[
            name.clone(),
            format!("{:.3}", gt.tpot_ns.mean / 1e6),
            format!("{:.3}", sim.tpot_ns.mean / 1e6),
            format!("{:.2}", e.tpot_pct),
            format!("{:.3}", gt.itl_ns.mean / 1e6),
            format!("{:.3}", sim.itl_ns.mean / 1e6),
            format!("{:.2}", e.itl_pct),
        ]);
        t2b.row(&[
            name,
            format!("{:.1}", gt.throughput_tps),
            format!("{:.1}", sim.throughput_tps),
            format!("{:.2}", e.throughput_pct),
        ]);
    }

    println!("\nFig. 2(a): TPOT and ITL, real vs simulated");
    t2a.print();
    println!("\nFig. 2(b): token generation throughput, real vs simulated");
    t2b.print();
    let mean = errs.iter().map(|(_, e)| e).sum::<f64>() / errs.len() as f64;
    println!(
        "\nmean validation error across configs: {:.2} %  (paper: 1.9 % avg, \
         <5 % per config)",
        mean
    );
    for (n, e) in &errs {
        println!("  {n}: {e:.2} %");
    }
    Ok(())
}
