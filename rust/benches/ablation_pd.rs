//! Ablation (§II-B): P/D disaggregation vs colocated serving across
//! arrival rates, and the KV-transfer policy's effect — the design space
//! Splitwise/DistServe explore, run through the simulator.
//!
//! Run: `cargo bench --bench ablation_pd`

use llmservingsim::config::{presets, KvTransferPolicy, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::util::bench::Table;
use llmservingsim::workload::Traffic;

fn at(mut cfg: SimConfig, rate: f64) -> SimConfig {
    cfg.workload.num_requests = 80;
    cfg.workload.traffic = Traffic::poisson(rate);
    cfg
}

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(&[
        "rate",
        "system",
        "TTFT p99 ms",
        "ITL mean ms",
        "ITL p99 ms",
        "tok/s",
        "KV moved MB",
    ]);
    for rate in [0.5, 1.0, 2.0] {
        // colocated pair
        let (co, _) = run_config(at(presets::multi_dense("llama3.1-8b", "rtx3090"), rate))?;
        t.row(&[
            format!("{rate}"),
            "colocated 2x".into(),
            format!("{:.1}", co.ttft_ns.p99 / 1e6),
            format!("{:.3}", co.itl_ns.mean / 1e6),
            format!("{:.3}", co.itl_ns.p99 / 1e6),
            format!("{:.0}", co.throughput_tps),
            "0".into(),
        ]);
        for policy in [KvTransferPolicy::Blocking, KvTransferPolicy::Layered] {
            let mut cfg = at(presets::pd_dense("llama3.1-8b", "rtx3090"), rate);
            for i in &mut cfg.instances {
                i.kv_transfer = policy;
            }
            let mut sim = llmservingsim::coordinator::Simulation::new(cfg)?;
            let r = sim.run();
            t.row(&[
                format!("{rate}"),
                format!("P/D {}", policy.as_str()),
                format!("{:.1}", r.ttft_ns.p99 / 1e6),
                format!("{:.3}", r.itl_ns.mean / 1e6),
                format!("{:.3}", r.itl_ns.p99 / 1e6),
                format!("{:.0}", r.throughput_tps),
                format!("{:.1}", sim.inter_instance_bytes() as f64 / 1e6),
            ]);
        }
    }
    println!("\nAblation: P/D disaggregation and KV-transfer policy");
    t.print();
    println!(
        "expected: under load, P/D shields decode ITL from prefill \
         interference; layered transfer exposes ~1/layers of the KV bytes."
    );
    Ok(())
}
