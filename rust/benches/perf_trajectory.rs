//! Perf trajectory: the committed-speed ladder behind `BENCH_<n>.json`.
//!
//! Times a fixed scenario ladder (large poisson runs, a 1M-request
//! multi-tenant bursty day, an autoscaling controller run, and a
//! radix-heavy multi-turn sessions workload) and writes machine-readable
//! results to `BENCH_6.json` at the repo root so every PR leaves a perf
//! datapoint to beat. See DESIGN.md §10.
//!
//! Run: `cargo bench --bench perf_trajectory`
//! Env:
//!   LLMSS_BENCH_QUICK=1   tiny request counts + 3 iters (CI smoke)
//!   LLMSS_BENCH_OUT=path  write the JSON somewhere else
//!
//! The previous file's measured scenarios (or carried `baseline`) become
//! the new file's `baseline`, so refreshing the trajectory keeps the
//! before/after pair in one document.

use std::time::Duration;

use llmservingsim::config::{presets, CacheScope, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::util::bench::{Bencher, Table};
use llmservingsim::util::json::{self, Value};
use llmservingsim::workload::{LengthDist, Traffic};

struct Scenario {
    name: &'static str,
    cfg: SimConfig,
}

fn scenarios(quick: bool) -> Vec<Scenario> {
    // Quick mode shrinks request counts ~50-200x: same code paths, CI-sized.
    let n = |full: usize, q: usize| if quick { q } else { full };
    let mut out = vec![];

    // Steady poisson load on a 2-instance fleet, no cache: the pure
    // event-core + scheduler hot loop.
    let mut c = presets::multi_dense("tiny-dense", "rtx3090");
    c.workload.traffic = Traffic::poisson(2000.0);
    c.workload.lengths = LengthDist::short();
    c.workload.num_requests = n(100_000, 2_000);
    out.push(Scenario {
        name: "poisson_100k",
        cfg: c,
    });

    let mut c = presets::multi_dense("tiny-dense", "rtx3090");
    c.workload.traffic = Traffic::poisson(2000.0);
    c.workload.lengths = LengthDist::short();
    c.workload.num_requests = n(1_000_000, 5_000);
    out.push(Scenario {
        name: "poisson_1m",
        cfg: c,
    });

    // The headline scenario: 1M requests, 4 tenants, MMPP bursts, SLO
    // scheduling (the acceptance criterion's >= 2x target lives here).
    let mut c = presets::multi_tenant_bursty(
        presets::multi_dense("tiny-dense", "rtx3090"),
        4,
        2_000.0,
    );
    c.workload.lengths = LengthDist::short();
    c.workload.num_requests = n(1_000_000, 5_000);
    out.push(Scenario {
        name: "multi_tenant_bursty_1m",
        cfg: c,
    });

    // Controller path: scale-ups/downs, warmups, parked requests.
    let mut c = presets::autoscale_bursty();
    c.workload.num_requests = n(20_000, 500);
    out.push(Scenario {
        name: "autoscale_bursty",
        cfg: c,
    });

    // Radix-heavy: multi-turn sessions re-sending growing prefixes into
    // per-instance prefix caches (insert/lookup/evict churn).
    let mut c = presets::with_prefix_cache(
        presets::multi_dense("tiny-dense", "rtx3090"),
        CacheScope::PerInstance,
    );
    c.workload.traffic = Traffic::sessions(50.0, 6, 0.2);
    c.workload.lengths = LengthDist::short();
    c.workload.num_requests = n(50_000, 1_000);
    out.push(Scenario {
        name: "radix_sessions",
        cfg: c,
    });

    out
}

/// Peak resident set (VmHWM) in bytes, where the OS exposes it.
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// The previous output's measured scenarios (or its carried baseline) — the
/// comparison point CI regresses against.
fn carry_baseline(prior: &Value) -> Option<Value> {
    let provisional = prior.get("provisional").as_bool() == Some(true);
    if !provisional && prior.get("scenarios").as_obj().is_some() {
        return Some(prior.get("scenarios").clone());
    }
    if prior.get("baseline").as_obj().is_some() {
        return Some(prior.get("baseline").clone());
    }
    None
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("LLMSS_BENCH_QUICK").is_ok_and(|v| v != "0");
    let out_path = std::env::var("LLMSS_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_6.json")
        });
    let bencher = if quick {
        Bencher::quick()
    } else {
        Bencher {
            warmup_iters: 1,
            measure_iters: 5,
            max_total: Duration::from_secs(180),
        }
    };

    let baseline = json::load_file(&out_path)
        .ok()
        .as_ref()
        .and_then(carry_baseline);

    let mut table = Table::new(&[
        "scenario",
        "requests",
        "wall median (s)",
        "events",
        "events/s",
    ]);
    let mut doc_scenarios: Vec<(&str, Value)> = vec![];

    for sc in scenarios(quick) {
        eprintln!(
            "[{}] {} requests ...",
            sc.name, sc.cfg.workload.num_requests
        );
        // One metadata run: deterministic counters (events/steps) and a
        // sanity check that the scenario actually completes work.
        let (report, summary) = run_config(sc.cfg.clone())?;
        assert!(report.num_finished > 0, "{}: nothing finished", sc.name);
        let r = bencher.run(sc.name, || {
            run_config(sc.cfg.clone()).expect("scenario ran once already")
        });
        let wall = r.median_secs();
        let eps = summary.events as f64 / wall.max(1e-12);
        table.row(&[
            sc.name.to_string(),
            sc.cfg.workload.num_requests.to_string(),
            format!("{wall:.4}"),
            summary.events.to_string(),
            format!("{eps:.0}"),
        ]);
        let rss = match peak_rss_bytes() {
            Some(b) => Value::int(b as i64),
            None => Value::Null,
        };
        doc_scenarios.push((
            sc.name,
            Value::obj(vec![
                ("requests", Value::int(sc.cfg.workload.num_requests as i64)),
                ("wall_secs_median", Value::float(wall)),
                ("events_processed", Value::int(summary.events as i64)),
                ("events_per_sec", Value::float(eps)),
                ("steps", Value::int(summary.steps as i64)),
                ("peak_rss_bytes", rss),
            ]),
        ));
    }

    let mut doc = vec![
        ("bench", Value::str("perf_trajectory")),
        ("quick", Value::Bool(quick)),
        ("scenarios", Value::obj(doc_scenarios)),
    ];
    if let Some(b) = baseline {
        doc.push(("baseline", b));
    }
    json::save_file(&out_path, &Value::obj(doc))?;

    println!(
        "\nPerf trajectory ({} mode):",
        if quick { "quick" } else { "full" }
    );
    table.print();
    println!("\nwrote {}", out_path.display());
    Ok(())
}
