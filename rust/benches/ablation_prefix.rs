//! Ablation (§II-D): prefix-cache eviction policy x scope x capacity on a
//! session workload, reporting hit rate and TTFT.
//!
//! Run: `cargo bench --bench ablation_prefix`

use llmservingsim::config::{presets, CacheScope, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::util::bench::Table;
use llmservingsim::workload::Traffic;

fn base() -> SimConfig {
    let mut cfg = presets::multi_dense("llama3.1-8b", "rtx3090");
    cfg.workload.num_requests = 100;
    cfg.workload.sessions = 8;
    cfg.workload.shared_prefix = 384;
    cfg.workload.lengths.prompt_mu = 6.3;
    cfg.workload.traffic = Traffic::poisson(1.0);
    cfg
}

fn main() -> anyhow::Result<()> {
    let (no_pc, _) = run_config(base())?;
    let mut t = Table::new(&[
        "scope",
        "evict",
        "device frac",
        "hit %",
        "TTFT mean ms",
        "speedup",
    ]);
    t.row(&[
        "(none)".into(),
        "-".into(),
        "-".into(),
        "0.0".into(),
        format!("{:.1}", no_pc.ttft_ns.mean / 1e6),
        "1.00x".into(),
    ]);
    let evictions = llmservingsim::policy::snapshot().evict_names();
    for scope in [CacheScope::PerInstance, CacheScope::Global] {
        for policy in &evictions {
            for frac in [0.01, 0.05, 0.3] {
                let mut cfg = presets::with_prefix_cache(base(), scope);
                cfg.workload = base().workload;
                for i in &mut cfg.instances {
                    if let Some(pc) = &mut i.prefix_cache {
                        pc.policy = policy.clone();
                        pc.device_fraction = frac;
                    }
                }
                let (r, s) = run_config(cfg)?;
                let (q, h) = s.cache_stats.iter().fold((0u64, 0u64), |(q, h), c| {
                    (q + c.queried_tokens, h + c.hit_tokens_device + c.hit_tokens_host)
                });
                t.row(&[
                    match scope {
                        CacheScope::PerInstance => "per-inst".into(),
                        CacheScope::Global => "global".into(),
                    },
                    policy.clone(),
                    format!("{frac}"),
                    format!("{:.1}", h as f64 / q.max(1) as f64 * 100.0),
                    format!("{:.1}", r.ttft_ns.mean / 1e6),
                    format!("{:.2}x", no_pc.ttft_ns.mean / r.ttft_ns.mean.max(1.0)),
                ]);
            }
        }
    }
    println!("\nAblation: prefix caching (policy x scope x device capacity)");
    t.print();
    println!(
        "expected: hit rate (and TTFT speedup) grows with capacity; global \
         scope beats per-instance at equal capacity; LRU/LFU diverge only \
         when capacity-pressured."
    );
    Ok(())
}
