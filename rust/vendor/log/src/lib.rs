//! Minimal, dependency-free implementation of the `log` facade.
//!
//! The offline crate registry for this environment is not guaranteed to
//! carry the real `log` crate, so the simulator vendors the small subset of
//! its API it actually uses: the five leveled macros, [`Level`] /
//! [`LevelFilter`], the [`Log`] trait, and the global logger registry
//! (`set_logger` / `set_max_level` / `max_level`). The surface is drop-in
//! compatible with `log 0.4`, so swapping the real crate back in is a
//! one-line `Cargo.toml` change.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a log record. Ordered `Error < Warn < ... < Trace`.
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        })
    }
}

/// Verbosity ceiling installed with [`set_max_level`]; `Off` disables all.
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record (level + target), checked by [`Log::enabled`].
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, passed to [`Log::log`].
#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }
    pub fn level(&self) -> Level {
        self.metadata.level
    }
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink. Implementations must be thread-safe: records can arrive from
/// any thread (e.g. the parallel sweep engine's workers).
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Returned by [`set_logger`] when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global verbosity ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: build a record and dispatch to the installed logger.
/// Public because the exported macros expand to it; not part of the API.
/// `target` is `&'static str` (always `module_path!()` in practice) so the
/// record's lifetime unifies with the `Arguments` temporary.
#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &'static str, args: fmt::Arguments) {
    if let Some(logger) = LOGGER.get() {
        let record = Record {
            metadata: Metadata { level, target },
            args,
        };
        logger.log(&record);
    }
}

#[macro_export]
macro_rules! log {
    (target: $target:expr, $lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_api_log(lvl, $target, format_args!($($arg)+));
        }
    }};
    ($lvl:expr, $($arg:tt)+) => {
        $crate::log!(target: module_path!(), $lvl, $($arg)+)
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    static CAPTURED: Mutex<Vec<String>> = Mutex::new(Vec::new());

    struct Capture;
    impl Log for Capture {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }
        fn log(&self, record: &Record) {
            if self.enabled(record.metadata()) {
                CAPTURED
                    .lock()
                    .unwrap()
                    .push(format!("{} {}", record.level(), record.args()));
            }
        }
        fn flush(&self) {}
    }

    #[test]
    fn level_filter_ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Warn <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Warn));
        assert!(Level::Error > LevelFilter::Off);
    }

    #[test]
    fn logger_roundtrip() {
        static SINK: Capture = Capture;
        let _ = set_logger(&SINK);
        set_max_level(LevelFilter::Info);
        info!("hello {}", 42);
        debug!("suppressed {}", 1);
        let got = CAPTURED.lock().unwrap();
        assert!(got.iter().any(|l| l == "INFO hello 42"));
        assert!(!got.iter().any(|l| l.contains("suppressed")));
        // second install fails but does not panic
        assert!(set_logger(&SINK).is_err());
    }
}
