//! Full-pipeline integration: AOT artifacts -> PJRT runtime -> profiler ->
//! trace DB -> trace-driven simulation -> validation vs real execution.
//!
//! These tests need `make artifacts`; they skip (with a message) otherwise.

use std::path::PathBuf;
use std::sync::Arc;

use llmservingsim::config::{presets, PerfBackend};
use llmservingsim::coordinator::{run_config, Simulation};
use llmservingsim::groundtruth::ExecPerfModel;
use llmservingsim::perf::trace::TraceDb;
use llmservingsim::runtime::profiler::{profile_model, ProfileOptions};
use llmservingsim::runtime::{Manifest, Runtime};
use llmservingsim::workload::LengthDist;

fn root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Artifacts on disk AND a real PJRT backend compiled in — with the
/// in-repo xla stub, `Runtime::cpu` always errors, so these must skip.
fn have_artifacts() -> bool {
    root().join("manifest.json").exists()
        && llmservingsim::runtime::Runtime::backend_available()
}

fn quick_profile(model: &str) -> TraceDb {
    let manifest = Manifest::load(&root()).unwrap();
    let mut rt = Runtime::cpu(&root()).unwrap();
    let opts = ProfileOptions {
        warmup: 1,
        reps: 3,
        hardware_tag: "cpu-pjrt".into(),
    };
    profile_model(&manifest, &mut rt, model, &opts).unwrap().db
}

#[test]
fn profile_then_simulate_trace_driven() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let db = quick_profile("tiny-dense");
    let path = std::env::temp_dir().join("llmss_it_trace.json");
    db.save(&path).unwrap();

    let mut cfg = presets::single_dense("tiny-dense", "cpu-pjrt");
    cfg.workload.num_requests = 10;
    cfg.workload.lengths = LengthDist::short();
    cfg.perf = PerfBackend::Trace {
        path: path.to_string_lossy().into_owned(),
    };
    let (report, _) = run_config(cfg).unwrap();
    assert_eq!(report.num_finished, 10);
    assert!(report.tpot_ns.mean > 0.0);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn trace_extends_to_unprofiled_model_via_calibration() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Profile the tiny model, then simulate the paper-scale model on the
    // same "hardware": build_perf must fall back to calibrated-analytical.
    let db = quick_profile("tiny-dense");
    let path = std::env::temp_dir().join("llmss_it_cal.json");
    db.save(&path).unwrap();

    let mut cfg = presets::single_dense("llama3.1-8b", "cpu-pjrt");
    cfg.workload.num_requests = 3;
    cfg.workload.lengths = LengthDist::short();
    cfg.perf = PerfBackend::Trace {
        path: path.to_string_lossy().into_owned(),
    };
    let (report, _) = run_config(cfg).unwrap();
    assert_eq!(report.num_finished, 3);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn sim_vs_real_execution_error_within_bounds() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut cfg = presets::single_dense("tiny-dense", "cpu-pjrt");
    cfg.workload.num_requests = 10;
    cfg.workload.lengths = LengthDist::short();

    let gt = Arc::new(ExecPerfModel::new(&root(), "tiny-dense").unwrap());
    let gt2 = gt.clone();
    let mut gt_sim = Simulation::builder(cfg.clone())
        .with_perf_factory(move |_, _, _| {
            Ok(gt2.clone() as Arc<dyn llmservingsim::perf::PerfModel>)
        })
        .build()
        .unwrap();
    let gt_report = gt_sim.run();

    let db = quick_profile("tiny-dense");
    let path = std::env::temp_dir().join("llmss_it_val.json");
    db.save(&path).unwrap();
    cfg.perf = PerfBackend::Trace {
        path: path.to_string_lossy().into_owned(),
    };
    let (sim_report, _) = run_config(cfg).unwrap();
    let err = sim_report.error_vs(&gt_report);
    // generous CI bound; the paper reports <5%, we typically see 2-7% with
    // the quick 3-rep profile used here
    assert!(
        err.mean() < 25.0,
        "trace-driven sim error vs real execution too high: {:?}",
        err
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn moe_artifacts_profile_and_simulate() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let db = quick_profile("tiny-moe");
    assert!(db.has(llmservingsim::model::OpKind::ExpertFfn));
    assert!(db.has(llmservingsim::model::OpKind::MoeGate));
    let path = std::env::temp_dir().join("llmss_it_moe.json");
    db.save(&path).unwrap();

    let mut cfg = presets::single_moe("tiny-moe", "cpu-pjrt");
    cfg.workload.num_requests = 5;
    cfg.workload.lengths = LengthDist::short();
    cfg.perf = PerfBackend::Trace {
        path: path.to_string_lossy().into_owned(),
    };
    let (report, _) = run_config(cfg).unwrap();
    assert_eq!(report.num_finished, 5);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn second_backend_persona_is_one_command() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // The Table III claim: integrating another backend is re-running the
    // profiler with a different tag — zero simulator changes. Simulate the
    // persona by profiling under a different hardware tag and verifying the
    // simulator consumes it unchanged.
    let manifest = Manifest::load(&root()).unwrap();
    let mut rt = Runtime::cpu(&root()).unwrap();
    let opts = ProfileOptions {
        warmup: 1,
        reps: 2,
        hardware_tag: "tpu-v6e-persona".into(),
    };
    let outcome = profile_model(&manifest, &mut rt, "tiny-dense", &opts).unwrap();
    assert_eq!(outcome.db.hardware, "tpu-v6e-persona");
    let path = std::env::temp_dir().join("llmss_it_tpu.json");
    outcome.db.save(&path).unwrap();

    let mut cfg = presets::single_dense("tiny-dense", "tpu-v6e");
    cfg.workload.num_requests = 5;
    cfg.workload.lengths = LengthDist::short();
    cfg.perf = PerfBackend::Trace {
        path: path.to_string_lossy().into_owned(),
    };
    let (report, _) = run_config(cfg).unwrap();
    assert_eq!(report.num_finished, 5);
    let _ = std::fs::remove_file(&path);
}
