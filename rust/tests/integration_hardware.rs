//! Hardware-onboarding round trip (ISSUE 4 acceptance): profile-style
//! bundle emission → import (`--hardware-dir` / `import-hardware`) → the
//! new device resolves by name in `simulate` and in `sweep --hardware all`,
//! with byte-identical sweep reports at 1 and 8 workers.
//!
//! The profile step is synthesized (no PJRT backend in CI): the bundle is
//! built through the same `HardwareBundle::from_trace` +
//! `profiler::emit_bundle` path the `profile --emit-bundle` command uses,
//! then written to disk and loaded back exactly like the CLI does.
//!
//! Bundle files land under `target/test-hardware-bundles/` so CI can
//! upload them as artifacts on failure.

use std::path::PathBuf;

use llmservingsim::config::{presets, PerfBackend};
use llmservingsim::coordinator::{build_perf, run_config};
use llmservingsim::model::{ModelSpec, OpKind};
use llmservingsim::perf::hardware::{self, HardwareBundle};
use llmservingsim::perf::trace::TraceDb;
use llmservingsim::perf::{HardwareSpec, PerfModel};
use llmservingsim::runtime::profiler::emit_bundle;
use llmservingsim::sweep::{run_sweep, SweepSpec};

/// Where emitted bundles go (kept after the run; CI uploads on failure).
fn bundle_dir(sub: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target/test-hardware-bundles")
        .join(sub);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A synthetic operator-level profile for `tag`, shaped like the real
/// profiler's output for `tiny-dense` (1-D grids + decode batch/ctx grid).
fn synthetic_profile(tag: &str) -> TraceDb {
    let mut db = TraceDb::new(tag, "tiny-dense");
    for (kind, per_token) in [
        (OpKind::QkvProj, 900u64),
        (OpKind::AttnPrefill, 1_500),
        (OpKind::OutProj, 700),
        (OpKind::Ffn, 2_100),
        (OpKind::LmHead, 4_000),
        (OpKind::RmsNorm, 120),
    ] {
        for t in [1u64, 4, 16, 64, 256] {
            db.add_tokens(kind, t, per_token * t + 5_000);
        }
    }
    for b in [1u64, 2, 4, 8] {
        for c in [64u64, 256, 1024] {
            db.add_batch_ctx(OpKind::AttnDecode, b, c, 30 * b * c + 5_000);
        }
    }
    db
}

fn spec_named(name: &str) -> HardwareSpec {
    HardwareSpec {
        name: name.to_string(),
        ..HardwareSpec::cpu_pjrt()
    }
}

#[test]
fn one_command_roundtrip_profile_import_simulate_sweep() {
    let name = "it-npu-roundtrip";
    let dir = bundle_dir(name);

    // 1. "profile --emit-bundle": trace + spec -> one bundle file.
    let db = synthetic_profile(name);
    let emitted =
        emit_bundle(&db, spec_named(name), &dir.join(format!("{name}.json"))).unwrap();
    assert!(emitted.has_perf_data());
    assert!(!emitted.calibration.is_empty());

    // 2. "--hardware-dir DIR": the bundle registers under its device name.
    let loaded = hardware::load_bundle_dir(&dir).unwrap();
    assert!(loaded.contains(&name.to_string()), "loaded: {loaded:?}");
    assert!(hardware::registered_names().contains(&name.to_string()));

    // 3. The name resolves wherever a built-in preset would.
    let spec = HardwareSpec::resolve(name).unwrap();
    assert_eq!(spec.name, name);

    // 3a. simulate: a preset config on the new device completes, priced
    // through the bundle (trace + calibrated-roofline fallback).
    let model = ModelSpec::tiny_dense();
    let perf = build_perf(&PerfBackend::Analytical, &model, &spec).unwrap();
    assert!(
        perf.name().starts_with(&format!("bundle[{name}/")),
        "expected bundle pricing, got '{}'",
        perf.name()
    );
    let mut cfg = presets::single_dense("tiny-dense", name);
    cfg.workload.num_requests = 25;
    cfg.workload.lengths = llmservingsim::workload::LengthDist::short();
    let (report, _) = run_config(cfg).unwrap();
    assert_eq!(report.num_finished, 25);

    // 3b. sweep --hardware all: the device is a grid point alongside the
    // built-ins, and reports are byte-identical at 1 and 8 workers.
    let mut sweep = SweepSpec {
        num_requests: 12,
        quick: true,
        seed: 0x4A4D,
        ..SweepSpec::default()
    };
    sweep.axes = sweep.axes.with_all_hardware(&hardware::snapshot());
    assert!(sweep.axes.hardware.contains(&name.to_string()));
    for builtin in HardwareSpec::preset_names() {
        assert!(sweep.axes.hardware.contains(&builtin.to_string()));
    }
    let cfgs = sweep.expand().unwrap();
    assert!(cfgs.iter().any(|c| c.name == format!("S(D)|hw={name}")));

    let solo = run_sweep(&cfgs, 1).unwrap();
    let pool = run_sweep(&cfgs, 8).unwrap();
    assert_eq!(solo.points.len(), pool.points.len());
    for (a, b) in solo.points.iter().zip(&pool.points) {
        assert_eq!(a.name, b.name, "slot order must follow expansion");
        assert_eq!(
            a.report.to_json().to_string(),
            b.report.to_json().to_string(),
            "point '{}' diverged between 1 and 8 workers",
            a.name
        );
    }
    // the custom device's point actually finished its work
    let custom = solo
        .points
        .iter()
        .find(|p| p.name.contains(name))
        .expect("custom hardware point present");
    assert_eq!(custom.report.num_finished, 12);
}

#[test]
fn import_bundle_file_registers_and_validates() {
    let name = "it-npu-import";
    let dir = bundle_dir(name);
    let path = dir.join(format!("{name}.json"));
    HardwareBundle::from_trace(spec_named(name), synthetic_profile(name))
        .unwrap()
        .save(&path)
        .unwrap();

    let bundle = hardware::import_bundle_file(&path).unwrap();
    assert_eq!(bundle.spec.name, name);
    assert!(HardwareSpec::resolve(name).is_ok());

    // corrupt files are rejected with the path in the error
    let bad = dir.join("corrupt.json");
    std::fs::write(&bad, "{\"schema\": \"hardware-bundle-v1\"}").unwrap();
    let e = hardware::import_bundle_file(&bad).unwrap_err().to_string();
    assert!(e.contains("corrupt.json"), "{e}");
    std::fs::remove_file(&bad).unwrap();
}

#[test]
fn unknown_hardware_everywhere_reports_candidates() {
    // config resolution
    let cfg = presets::single_dense("tiny-dense", "it-npu-not-registered");
    let e = run_config(cfg).unwrap_err().to_string();
    assert!(
        e.contains("it-npu-not-registered") && e.contains("rtx3090"),
        "{e}"
    );
    // sweep axis, rejected at expand (not mid-sweep)
    let mut sweep = SweepSpec {
        quick: true,
        ..SweepSpec::default()
    };
    sweep.axes.hardware = vec!["it-npu-not-registered".into()];
    let e = sweep.expand().unwrap_err().to_string();
    assert!(
        e.contains("it-npu-not-registered") && e.contains("tpu-v6e"),
        "{e}"
    );
    // direct resolution mentions the import pathway
    let e = HardwareSpec::resolve("it-npu-not-registered")
        .unwrap_err()
        .to_string();
    assert!(e.contains("import-hardware") || e.contains("hardware-dir"), "{e}");
}

#[test]
fn heterogeneous_fleet_mixes_builtin_and_imported_hardware() {
    let name = "it-npu-fleet";
    let db = synthetic_profile(name);
    let bundle = HardwareBundle::from_trace(spec_named(name), db).unwrap();
    hardware::register_hardware(bundle).unwrap();

    // one built-in GPU instance + one imported-device instance behind the
    // router; both must serve traffic.
    let mut cfg = presets::multi_dense("tiny-dense", "rtx3090");
    cfg.instances[1] =
        llmservingsim::config::InstanceConfig::basic("npu0", "tiny-dense", name);
    cfg.workload.num_requests = 30;
    cfg.workload.lengths = llmservingsim::workload::LengthDist::short();
    cfg.workload.traffic = llmservingsim::workload::Traffic::burst();
    let (report, _) = run_config(cfg).unwrap();
    assert_eq!(report.num_finished, 30);
    assert!(report.utilization.get(&0).copied().unwrap_or(0.0) > 0.0);
    assert!(report.utilization.get(&1).copied().unwrap_or(0.0) > 0.0);
}

#[test]
fn registered_hardware_simulation_is_reproducible() {
    let name = "it-npu-repro";
    let bundle =
        HardwareBundle::from_trace(spec_named(name), synthetic_profile(name)).unwrap();
    hardware::register_hardware(bundle).unwrap();
    let mut cfg = presets::single_dense("tiny-dense", name);
    cfg.workload.num_requests = 20;
    cfg.workload.lengths = llmservingsim::workload::LengthDist::short();
    let (a, _) = run_config(cfg.clone()).unwrap();
    let (b, _) = run_config(cfg).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
}
