//! Driver + cluster-controller determinism (ISSUE 5 acceptance contract):
//!
//! * `run_until`-stepped execution is byte-identical to one-shot `run()`
//!   under the `static` controller, standalone and through the sweep
//!   engine at 1 and 8 workers;
//! * the `queue-threshold` autoscaler on the bursty multi-tenant scenario
//!   scales the fleet up and back down, with a monotone-then-decreasing
//!   (unimodal up to re-bursts) fleet-size timeline, deterministically
//!   across 1/2/8 sweep workers;
//! * unknown controller names fail with the candidate list, everywhere a
//!   name can be spelled (config build, sweep axis).
//!
//! The autoscale test also writes the controller timeline to
//! `target/controller_timeline.json` so CI can upload it as an artifact
//! when something fails.

use std::path::PathBuf;

use llmservingsim::config::{presets, SimConfig};
use llmservingsim::coordinator::{run_config, Simulation};
use llmservingsim::sim::MILLI;
use llmservingsim::sweep::{run_sweep, SweepSpec};
use llmservingsim::util::json::Value;

fn small_static(preset: &str) -> SimConfig {
    let mut cfg =
        presets::by_name(preset, "tiny-dense", "tiny-moe", "rtx3090").unwrap();
    cfg.workload.num_requests = 20;
    cfg.workload.lengths = llmservingsim::workload::LengthDist::short();
    cfg
}

#[test]
fn run_until_stepping_matches_one_shot_across_presets() {
    // Slice widths chosen to land both on and between event timestamps.
    for preset in ["S(D)", "M(D)", "PD(D)", "M(D)+PC"] {
        let cfg = small_static(preset);
        let (oneshot, _) = run_config(cfg.clone()).unwrap();

        let mut sim = Simulation::new(cfg).unwrap();
        let mut driver = sim.driver();
        let mut t = 0;
        while !driver.is_done() {
            t += 3 * MILLI;
            driver.run_until(t);
            // the driver can observe the cluster between slices
            assert!(driver.view().active() >= 1);
        }
        let stepped = driver.finish();
        assert_eq!(
            oneshot.to_json().to_string(),
            stepped.to_json().to_string(),
            "stepped vs one-shot diverged for preset '{preset}'"
        );
    }
}

#[test]
fn stepped_reports_match_sweep_at_1_and_8_workers() {
    // The same configs through the sweep engine (which uses one-shot
    // `run()`): per-point reports must equal the stepped references at
    // any worker count.
    let mut spec = SweepSpec {
        num_requests: 15,
        quick: true,
        seed: 0xD21,
        ..SweepSpec::default()
    };
    spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
    spec.axes.rates = vec![8.0, 30.0];
    spec.axes.routers = vec!["round-robin".into(), "least-outstanding".into()];
    spec.axes.controllers = vec!["static".into()];
    let cfgs = spec.expand().unwrap();
    assert_eq!(cfgs.len(), 8, "2 presets x 2 rates x 2 routers x 1 controller");

    let stepped: Vec<(String, String)> = cfgs
        .iter()
        .map(|cfg| {
            let mut sim = Simulation::new(cfg.clone()).unwrap();
            let mut driver = sim.driver();
            while driver.step().is_some() {}
            (cfg.name.clone(), driver.finish().to_json().to_string())
        })
        .collect();

    for threads in [1, 8] {
        let swept: Vec<(String, String)> = run_sweep(&cfgs, threads)
            .unwrap()
            .points
            .into_iter()
            .map(|p| (p.name, p.report.to_json().to_string()))
            .collect();
        assert_eq!(
            swept, stepped,
            "sweep at {threads} workers diverged from stepped execution"
        );
    }
}

#[test]
fn static_controller_leaves_reports_byte_identical() {
    // `cluster.controller = "static"` (explicit) must not change a single
    // byte relative to the default config.
    let base = small_static("M(D)");
    let mut explicit = base.clone();
    explicit.cluster.controller = "static".to_string();
    let (a, sa) = run_config(base).unwrap();
    let (b, sb) = run_config(explicit).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert_eq!(sa.events, sb.events, "static schedules no extra events");
    assert_eq!(sb.peak_instances, 2);
    assert_eq!(sb.controller, "static");
}

fn timeline_json(report: &llmservingsim::metrics::Report) -> Value {
    Value::arr(report.timeline.iter().map(|e| e.to_json()).collect())
}

#[test]
fn autoscale_scenario_is_deterministic_and_unimodal() {
    let cfg = presets::autoscale_bursty();
    let (report, summary) = run_config(cfg.clone()).unwrap();

    // Leave the timeline on disk for CI to upload on failure.
    let out = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target/controller_timeline.json");
    std::fs::create_dir_all(out.parent().unwrap()).unwrap();
    std::fs::write(&out, timeline_json(&report).to_string()).unwrap();

    assert_eq!(report.num_finished, 200, "autoscaling must not drop requests");
    assert_eq!(report.controller, "queue-threshold");
    assert!(summary.peak_instances > 1, "peak {}", summary.peak_instances);
    assert!(
        summary.peak_instances <= cfg.cluster.max_instances,
        "fleet exceeded max_instances"
    );

    // Every action lands in the timeline, time-ordered.
    let ats: Vec<u64> = report.timeline.iter().map(|e| e.at).collect();
    assert!(ats.windows(2).all(|w| w[0] <= w[1]), "timeline out of order");
    let kinds: Vec<&str> = report.timeline.iter().map(|e| e.kind.as_str()).collect();
    assert!(kinds.contains(&"scale-up"));
    assert!(kinds.contains(&"ready"));
    assert!(kinds.contains(&"scale-down"), "{kinds:?}");

    // Fleet-size samples: monotone non-decreasing up to the peak before
    // they first fall — the fleet never flaps during a single burst.
    let samples: Vec<usize> = report
        .timeline
        .iter()
        .filter(|e| e.kind == "sample")
        .map(|e| e.active)
        .collect();
    assert!(!samples.is_empty());
    let peak = *samples.iter().max().unwrap();
    assert!(peak > 1, "samples never saw the scaled-up fleet");
    let first_peak = samples.iter().position(|&a| a == peak).unwrap();
    assert!(
        samples[..=first_peak].windows(2).all(|w| w[0] <= w[1]),
        "fleet size must grow monotonically up to its first peak: {samples:?}"
    );
    // ... and it comes back down by the end of the run.
    assert!(
        *samples.last().unwrap() < peak,
        "fleet never scaled back down: {samples:?}"
    );

    // Byte-determinism: rerun standalone, then push a 4-seed grid of the
    // scenario through the sweep engine at 1/2/8 workers (a single-point
    // grid would clamp the worker count to 1 and prove nothing).
    let (again, _) = run_config(cfg.clone()).unwrap();
    assert_eq!(
        report.to_json().to_string(),
        again.to_json().to_string()
    );
    let grid: Vec<SimConfig> = (0..4)
        .map(|i| {
            let mut c = cfg.clone();
            c.name = format!("autoscale-{i}");
            c.seed += i;
            c.workload.seed += i;
            c
        })
        .collect();
    let reference: Vec<String> = grid
        .iter()
        .map(|c| run_config(c.clone()).unwrap().0.to_json().to_string())
        .collect();
    for threads in [1, 2, 8] {
        let swept: Vec<String> = run_sweep(&grid, threads)
            .unwrap()
            .points
            .into_iter()
            .map(|p| p.report.to_json().to_string())
            .collect();
        assert_eq!(
            swept, reference,
            "autoscale grid diverged at {threads} sweep workers"
        );
    }
}

#[test]
fn unknown_controller_names_error_with_candidates_everywhere() {
    // config build
    let mut cfg = small_static("S(D)");
    cfg.cluster.controller = "chaos-monkey".to_string();
    let e = Simulation::new(cfg).unwrap_err().to_string();
    assert!(e.contains("chaos-monkey"), "{e}");
    assert!(
        e.contains("static") && e.contains("queue-threshold"),
        "candidate list missing: {e}"
    );

    // sweep axis (rejected at expand, before anything runs)
    let mut spec = SweepSpec {
        quick: true,
        ..SweepSpec::default()
    };
    spec.axes.controllers = vec!["chaos-monkey".into()];
    let e = spec.expand().unwrap_err().to_string();
    assert!(e.contains("chaos-monkey") && e.contains("failure-replay"), "{e}");
}

#[test]
fn failure_replay_scenario_survives_and_records_the_fault() {
    use llmservingsim::config::FailureSpec;
    let mut cfg = small_static("M(D)");
    cfg.workload.num_requests = 40;
    cfg.cluster.controller = "failure-replay".to_string();
    cfg.cluster.tick_ms = 10;
    cfg.cluster.warmup_ms = 50;
    cfg.cluster.failures = vec![FailureSpec {
        instance: 0,
        at_ms: 100,
        recover_ms: Some(600),
    }];
    let (report, _) = run_config(cfg.clone()).unwrap();
    assert_eq!(report.num_finished, 40, "fault injection must not lose work");
    let fail = report.timeline.iter().find(|e| e.kind == "fail").unwrap();
    assert_eq!(fail.instance, Some(0));
    assert_eq!(fail.at, 100 * MILLI, "failure lands nanosecond-exact");
    assert!(
        report.timeline.iter().any(|e| e.kind == "recover"),
        "scripted recovery missing"
    );
    // deterministic at any worker count (2-point grid so threads > 1)
    let mut cfg2 = cfg.clone();
    cfg2.name = "failure-replay-b".to_string();
    cfg2.seed += 1;
    cfg2.workload.seed += 1;
    let grid = vec![cfg, cfg2];
    let a = run_sweep(&grid, 1).unwrap();
    let b = run_sweep(&grid, 8).unwrap();
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(
            pa.report.to_json().to_string(),
            pb.report.to_json().to_string()
        );
    }
}
