//! simlint self-tests + the tree gate.
//!
//! Two layers:
//! * **Fixture tests** — every rule is demonstrated to fire on a fixture
//!   under `tests/lint_fixtures/` (scanned with virtual in-core paths; the
//!   fixtures are never compiled), and every suppression path (inline
//!   allow, malformed allow, `// simlint: cold` hot-set opt-out,
//!   `#[cfg(test)]` region, non-core exemption, baseline) is demonstrated
//!   to behave. Flow-aware rules (H01/H02/P01) go through
//!   `analyze_sources`, the same entry point the CLI uses.
//! * **The gate** — `src/` must produce zero findings beyond the committed
//!   `simlint.allow` baseline, through the full flow-aware analysis
//!   (`scan_tree` → `analyze_paths`, which also discovers README/DESIGN
//!   for P01). This runs under plain `cargo test`, so the tier-1 suite
//!   itself enforces the determinism rules.

use llmservingsim::lint::baseline::{format_baseline, Baseline};
use llmservingsim::lint::{analyze_sources, report_json, scan_source, scan_tree, Finding, RuleId};
use std::path::Path;

const D01_SRC: &str = include_str!("lint_fixtures/d01_std_hash.rs");
const D02_SRC: &str = include_str!("lint_fixtures/d02_wall_clock.rs");
const D03_SRC: &str = include_str!("lint_fixtures/d03_entropy.rs");
const D04_SRC: &str = include_str!("lint_fixtures/d04_hash_iteration.rs");
const S01_SRC: &str = include_str!("lint_fixtures/s01_panics.rs");
const ALLOW_OK_SRC: &str = include_str!("lint_fixtures/allow_suppresses.rs");
const ALLOW_BAD_SRC: &str = include_str!("lint_fixtures/allow_malformed.rs");
const TEST_REGION_SRC: &str = include_str!("lint_fixtures/test_region.rs");
const H01_SRC: &str = include_str!("lint_fixtures/h01_hot_alloc.rs");
const H02_SRC: &str = include_str!("lint_fixtures/h02_hot_clone.rs");
const E01_SRC: &str = include_str!("lint_fixtures/e01_wildcard.rs");
const P01_SRC: &str = include_str!("lint_fixtures/p01_registry.rs");

/// Virtual path that makes every core-scoped rule applicable.
const CORE: &str = "coordinator/mod.rs";

fn rules_fired(path: &str, src: &str) -> Vec<RuleId> {
    scan_source(path, src).iter().map(|f| f.rule).collect()
}

/// Run the full (flow-aware) analysis over one fixture under a core path.
fn analyze_fixture(src: &str, docs: &[(String, String)]) -> Vec<Finding> {
    analyze_sources(&[(CORE.to_string(), src.to_string())], docs)
}

#[test]
fn d01_fires_on_std_hash_in_core() {
    let fired = rules_fired(CORE, D01_SRC);
    assert_eq!(fired.len(), 4, "{fired:?}"); // 2 use lines + 2 field types
    assert!(fired.iter().all(|r| *r == RuleId::D01));
}

#[test]
fn d01_is_scoped_to_core_modules() {
    assert!(rules_fired("util/helpers.rs", D01_SRC).is_empty());
    assert!(rules_fired("lint/rules.rs", D01_SRC).is_empty());
}

#[test]
fn d02_fires_on_ambient_clocks() {
    let fired = rules_fired(CORE, D02_SRC);
    // SystemTime in the use, Instant::now(), SystemTime::now().
    assert_eq!(fired, vec![RuleId::D02, RuleId::D02, RuleId::D02]);
    // D02 applies outside core modules too…
    assert_eq!(rules_fired("util/json.rs", D02_SRC).len(), 3);
    // …but not in the sanctioned wall-clock homes.
    assert!(rules_fired("util/bench.rs", D02_SRC).is_empty());
    assert!(rules_fired("util/logging.rs", D02_SRC).is_empty());
    assert!(rules_fired("benches/perf_trajectory.rs", D02_SRC).is_empty());
}

#[test]
fn d03_fires_on_entropy_sources() {
    let fired = rules_fired(CORE, D03_SRC);
    assert_eq!(fired.len(), 3, "{fired:?}");
    assert!(fired.iter().all(|r| *r == RuleId::D03));
    // util/rng.rs is the sanctioned seeded-RNG home.
    assert!(rules_fired("util/rng.rs", D03_SRC).is_empty());
}

#[test]
fn d04_fires_on_hash_iteration_including_multiline_chains() {
    let findings = scan_source("metrics/mod.rs", D04_SRC);
    assert_eq!(findings.len(), 2, "{findings:?}");
    // The `.iter()` sits on its own line inside a split method chain — a
    // line-based scanner cannot see `busy` and `iter` together.
    assert!(findings.iter().any(|f| f.line_text == ".iter()"));
    // The `for … in &self.busy` loop is the second form.
    assert!(findings
        .iter()
        .any(|f| f.line_text.starts_with("for (_, v)")));
}

#[test]
fn s01_fires_on_unjustified_aborts() {
    let fired = rules_fired(CORE, S01_SRC);
    // unwrap ×2, expect, panic!, unreachable!
    assert_eq!(fired.len(), 5, "{fired:?}");
    assert!(fired.iter().all(|r| *r == RuleId::S01));
    // S01 is a core-library rule; the same source is clean elsewhere.
    assert!(rules_fired("cli/mod.rs", S01_SRC).is_empty());
}

#[test]
fn well_formed_allows_suppress() {
    assert!(rules_fired(CORE, ALLOW_OK_SRC).is_empty());
}

#[test]
fn malformed_allows_do_not_suppress() {
    let fired = rules_fired(CORE, ALLOW_BAD_SRC);
    // Reasonless allow(D01), unknown-rule allow(D99), paren-less allow.
    assert_eq!(fired, vec![RuleId::D01, RuleId::D01, RuleId::S01]);
}

#[test]
fn cfg_test_regions_are_exempt_and_bounded() {
    let findings = scan_source(CORE, TEST_REGION_SRC);
    // The HashMap + unwrap inside `#[cfg(test)] mod tests` are skipped;
    // the unwrap *after* the module still fires.
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RuleId::S01);
    assert!(findings[0].line_text.contains("x.unwrap()"));
}

#[test]
fn h01_fires_only_on_hot_reachable_allocation() {
    let findings = analyze_fixture(H01_SRC, &[]);
    // One allocation in the hot-reachable helper fires; the inline-allowed
    // `format!`, the `cold`-marked refresh, and the unreachable free
    // function do not.
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RuleId::H01);
    assert!(findings[0].line_text.contains("Vec::new"));
}

#[test]
fn h02_fires_on_hot_request_clone_only() {
    let findings = analyze_fixture(H02_SRC, &[]);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RuleId::H02);
    assert!(findings[0].line_text.contains("self.req.clone()"));
}

#[test]
fn e01_fires_on_core_enum_wildcard_in_core_modules_only() {
    // E01 is per-file and core-scoped, so it runs through scan_source.
    let findings = scan_source(CORE, E01_SRC);
    // The bare `_ =>` over `Event` fires; the guarded `_ if` arm and the
    // non-enum match are exempt.
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RuleId::E01);
    assert!(rules_fired("util/json.rs", E01_SRC).is_empty());
}

#[test]
fn p01_flags_registered_name_missing_from_docs() {
    let docs = vec![(
        "README.md".to_string(),
        "route policies: `fixture-documented`".to_string(),
    )];
    let findings = analyze_fixture(P01_SRC, &docs);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RuleId::P01);
    assert!(findings[0].message.contains("fixture-ghost"));
    assert!(findings[0].message.contains("README.md"));
    // With the name documented, the family is clean.
    let docs = vec![(
        "README.md".to_string(),
        "`fixture-documented`, `fixture-ghost`".to_string(),
    )];
    assert!(analyze_fixture(P01_SRC, &docs).is_empty());
}

#[test]
fn json_report_is_stable_and_round_trips() {
    let findings = analyze_fixture(H01_SRC, &[]);
    let report = report_json(&findings);
    let parsed = llmservingsim::util::json::parse(&report).expect("report must parse");
    assert_eq!(parsed.to_string(), report, "JSON report must round-trip");
    assert_eq!(parsed.get("schema").as_str(), Some("simlint/v2"));
    assert_eq!(parsed.get("finding_count").as_u64(), Some(1));
}

#[test]
fn baseline_suppresses_exactly_its_entries() {
    let findings = scan_source(CORE, D01_SRC);
    let baseline = Baseline::parse(&format_baseline(&findings));
    assert!(findings.iter().all(|f| baseline.contains(f)));
    // A finding from another file is not covered.
    let other = scan_source(CORE, S01_SRC);
    assert!(other.iter().all(|f| !baseline.contains(f)));
}

#[test]
fn update_baseline_round_trips_byte_identically() {
    let findings = scan_source(CORE, D01_SRC);
    let once = format_baseline(&findings);
    let twice = Baseline::parse(&once).render();
    assert_eq!(once, twice);
    // And an empty finding set renders the committed header-only form.
    let empty = format_baseline(&[]);
    assert_eq!(Baseline::parse(&empty).render(), empty);
}

#[test]
fn committed_baseline_is_canonical() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("simlint.allow");
    let text = std::fs::read_to_string(&path).expect("committed simlint.allow must exist");
    assert_eq!(
        Baseline::parse(&text).render(),
        text,
        "simlint.allow is not in canonical --update-baseline form"
    );
}

/// The gate: the library source tree is clean modulo the committed
/// baseline. Runs under plain `cargo test`, so tier-1 enforces the rules.
#[test]
fn src_tree_is_clean_modulo_baseline() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = scan_tree(&manifest.join("src")).expect("scanning src/ must succeed");
    let baseline_text =
        std::fs::read_to_string(manifest.join("simlint.allow")).unwrap_or_default();
    let baseline = Baseline::parse(&baseline_text);
    let fresh: Vec<String> = findings
        .iter()
        .filter(|f| !baseline.contains(f))
        .map(|f| f.render())
        .collect();
    assert!(
        fresh.is_empty(),
        "unbaselined simlint findings in src/:\n{}",
        fresh.join("\n")
    );
}
