//! Distributed-sweep acceptance (ISSUE 9): merging the shard results of
//! ANY partition of a manifest reproduces the single-process aggregate
//! byte-for-byte; resumes skip completed shard files; foreign, corrupt,
//! or tampered shard files are rejected with named errors; and seed
//! replication is deterministic, with R=1 byte-compatible with the
//! replication-free path.

use std::path::PathBuf;

use llmservingsim::sweep::{
    merge, merge_files, run_all_shards, run_manifest, run_shard,
    run_shard_to_file, shard_file_name, ExperimentManifest, ShardOutcome,
    SweepSpec,
};
use llmservingsim::util::json::Value;

/// The 2 presets x 2 rates x 2 routers CI grid (8 points) from
/// `integration_sweep.rs`, wrapped in a manifest. 7 shards deliberately
/// do not divide 8 points.
fn grid_manifest() -> ExperimentManifest {
    let mut spec = SweepSpec {
        num_requests: 12,
        quick: true,
        seed: 0xDE75,
        baseline: Some("S(D)|rate=10|router=round-robin".into()),
        ..SweepSpec::default()
    };
    spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
    spec.axes.rates = vec![10.0, 40.0];
    spec.axes.routers = vec!["round-robin".into(), "least-outstanding".into()];
    ExperimentManifest::new(spec)
}

/// A 2-point manifest for the replication tests (each point runs R times).
fn small_manifest(replication: usize) -> ExperimentManifest {
    let mut spec = SweepSpec {
        num_requests: 10,
        quick: true,
        seed: 0xC0FE,
        ..SweepSpec::default()
    };
    spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
    let mut m = ExperimentManifest::new(spec);
    m.replication = replication;
    m
}

/// Fresh per-test scratch directory under target/.
fn test_dir(sub: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target/test-sweep-shards/integration")
        .join(sub);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn merge_of_any_partition_is_byte_identical_to_single_process() {
    let m = grid_manifest();
    assert_eq!(m.spec.grid_size(), 8, "the CI grid is 2x2x2");
    let reference = run_manifest(&m, 4).unwrap().to_string();

    // N = 1 (trivial), 2 (even), 7 (does not divide 8 — sizes [2,1,..,1]).
    for shards in [1usize, 2, 7] {
        for threads in [1usize, 8] {
            let mut results: Vec<_> = (0..shards)
                .map(|s| run_shard(&m, s, shards, threads).unwrap())
                .collect();
            // Merge must not care about arrival order of the results.
            results.reverse();
            let merged = merge(&m, &results).unwrap().to_string();
            assert_eq!(
                merged, reference,
                "merge of {shards} shard(s) at {threads} worker(s) \
                 diverged from the single-process aggregate"
            );
        }
    }
}

#[test]
fn resume_skips_completed_shards_and_reproduces_the_aggregate() {
    let m = grid_manifest();
    let dir = test_dir("resume");
    let shards = 3;
    let reference = run_manifest(&m, 4).unwrap().to_string();

    // "Interrupt" after 2 of 3 shards: only their result files exist.
    for s in 0..2 {
        let out = run_shard_to_file(&m, s, shards, 2, &dir, false).unwrap();
        assert!(matches!(out, ShardOutcome::Completed(_)));
    }
    assert!(!dir.join(shard_file_name(2, shards)).exists());

    // Resume: the completed shards are skipped, the missing one runs.
    let outcomes = run_all_shards(&m, shards, 2, &dir, false).unwrap();
    let skipped = outcomes
        .iter()
        .filter(|o| matches!(o, ShardOutcome::Skipped(_)))
        .count();
    assert_eq!(skipped, 2, "resume must reuse the completed shard files");
    assert!(matches!(outcomes[2], ShardOutcome::Completed(_)));

    let files: Vec<PathBuf> =
        outcomes.iter().map(|o| o.path().to_path_buf()).collect();
    let merged = merge_files(&m, &files).unwrap().to_string();
    assert_eq!(
        merged, reference,
        "resumed run diverged from the uninterrupted aggregate"
    );

    // A second resume finds everything complete and runs nothing.
    let again = run_all_shards(&m, shards, 2, &dir, false).unwrap();
    assert!(
        again.iter().all(|o| matches!(o, ShardOutcome::Skipped(_))),
        "a fully completed directory must be a pure skip"
    );

    // --force re-runs despite valid files, and still reproduces the bytes.
    let forced = run_all_shards(&m, shards, 2, &dir, true).unwrap();
    assert!(forced.iter().all(|o| matches!(o, ShardOutcome::Completed(_))));
    let files: Vec<PathBuf> =
        forced.iter().map(|o| o.path().to_path_buf()).collect();
    assert_eq!(merge_files(&m, &files).unwrap().to_string(), reference);
}

#[test]
fn merge_rejects_foreign_missing_duplicate_and_mixed_partitions() {
    let m = grid_manifest();
    let s0 = run_shard(&m, 0, 2, 2).unwrap();
    let s1 = run_shard(&m, 1, 2, 2).unwrap();

    // Foreign manifest: same axes, different seed → different hash.
    let mut foreign = grid_manifest();
    foreign.spec.seed += 1;
    let f0 = run_shard(&foreign, 0, 2, 2).unwrap();
    let err = merge(&m, &[f0, s1.clone()]).unwrap_err().to_string();
    assert!(
        err.contains("different manifest"),
        "foreign-manifest error should name the cause, got: {err}"
    );

    // Missing shard 2/2.
    let err = merge(&m, &[s0.clone()]).unwrap_err().to_string();
    assert!(
        err.contains("missing shard result(s) 2/2"),
        "missing-shard error should name the gap, got: {err}"
    );

    // Duplicate shard 1/2.
    let err = merge(&m, &[s0.clone(), s0.clone()]).unwrap_err().to_string();
    assert!(
        err.contains("claim shard 1/2"),
        "duplicate-shard error should name the shard, got: {err}"
    );

    // Results from two different partitions (…/2 and …/3).
    let t0 = run_shard(&m, 0, 3, 2).unwrap();
    let err = merge(&m, &[t0, s1.clone()]).unwrap_err().to_string();
    assert!(
        err.contains("different partitions"),
        "mixed-partition error should name the cause, got: {err}"
    );

    // Tampered slice hash on an otherwise valid result.
    let mut bad = s0;
    bad.slice_hash = "0".repeat(16);
    let err = merge(&m, &[bad, s1]).unwrap_err().to_string();
    assert!(
        err.contains("slice hash mismatch"),
        "tampered result should fail the slice-hash recheck, got: {err}"
    );

    // Nothing at all.
    let err = merge(&m, &[]).unwrap_err().to_string();
    assert!(err.contains("no shard results"), "got: {err}");
}

#[test]
fn merge_files_rejects_truncated_and_edited_shard_files() {
    let m = grid_manifest();
    let dir = test_dir("corrupt");
    let outcomes = run_all_shards(&m, 2, 2, &dir, false).unwrap();
    let files: Vec<PathBuf> =
        outcomes.iter().map(|o| o.path().to_path_buf()).collect();

    // Truncate the first file mid-JSON: the error must carry the path.
    let bytes = std::fs::read(&files[0]).unwrap();
    std::fs::write(&files[0], &bytes[..bytes.len() / 2]).unwrap();
    let err = merge_files(&m, &files).unwrap_err().to_string();
    assert!(
        err.contains(files[0].file_name().unwrap().to_str().unwrap()),
        "truncated-file error should carry the path, got: {err}"
    );

    // An edited-but-parseable file fails the slice hash, not the parser;
    // swap one hex digit of the recorded slice hash.
    let text = String::from_utf8(bytes).unwrap();
    let edited = if text.contains("\"slice_hash\": \"a") {
        text.replace("\"slice_hash\": \"a", "\"slice_hash\": \"b")
    } else {
        text.replace("\"slice_hash\": \"", "\"slice_hash\": \"a")
    };
    std::fs::write(&files[0], edited).unwrap();
    let err = merge_files(&m, &files).unwrap_err().to_string();
    assert!(
        err.contains("slice hash mismatch") || err.contains("corrupt"),
        "edited file should fail the slice-hash recheck, got: {err}"
    );

    // The resumable driver refuses to trust the bad file: it re-runs the
    // shard (with a warning) instead of skipping.
    let out = run_shard_to_file(&m, 0, 2, 2, &dir, false).unwrap();
    assert!(
        matches!(out, ShardOutcome::Completed(_)),
        "a corrupt file must be re-run, not reused"
    );
    assert_eq!(
        merge_files(&m, &files).unwrap().to_string(),
        run_manifest(&m, 4).unwrap().to_string(),
        "after the repair re-run the aggregate must match single-process"
    );
}

#[test]
fn replication_is_deterministic_and_reports_spread_statistics() {
    let m3 = small_manifest(3);

    // Property: same manifest + seed ⇒ byte-identical aggregate, at any
    // worker count (replicates are scheduled like grid points).
    let a = run_manifest(&m3, 2).unwrap().to_string();
    let b = run_manifest(&m3, 8).unwrap().to_string();
    assert_eq!(a, b, "replicated aggregate must not depend on threads");

    let agg = run_manifest(&m3, 2).unwrap();
    assert_eq!(agg.to_string(), a, "replicated aggregate must be stable");
    assert_eq!(agg.get("replication").as_i64(), Some(3));

    let points = agg.get("points").as_arr().unwrap();
    assert_eq!(points.len(), 2);
    for p in points {
        let rep = p.get("replication");
        assert_eq!(rep.get("r").as_i64(), Some(3));
        for key in ["ttft_mean_ms", "tpot_mean_ms", "itl_mean_ms", "throughput_tps", "makespan_s"] {
            let s = rep.get("metrics").get(key);
            let mean = s.get("mean").as_f64().unwrap();
            let std = s.get("std").as_f64().unwrap();
            let ci = s.get("ci95").as_f64().unwrap();
            let (min, max) = (
                s.get("min").as_f64().unwrap(),
                s.get("max").as_f64().unwrap(),
            );
            assert!(mean.is_finite() && std >= 0.0 && ci >= 0.0);
            assert!(min <= mean + 1e-9 && mean <= max + 1e-9);
            // ci95 = 1.96 * std / sqrt(r)
            let want = 1.96 * std / (3f64).sqrt();
            assert!((ci - want).abs() <= 1e-9 * want.max(1.0));
            assert!(
                s.get("p50").as_f64().unwrap().is_finite(),
                "median must come from the reservoir"
            );
        }
    }
}

#[test]
fn r1_point_records_are_byte_identical_to_replicated_representatives() {
    // Replicate 0 runs on the manifest seed, so stripping the
    // `replication` key from an R=3 point must reproduce the R=1 point
    // bytes exactly.
    let m1 = small_manifest(1);
    let m3 = small_manifest(3);
    let agg1 = run_manifest(&m1, 2).unwrap();
    let agg3 = run_manifest(&m3, 2).unwrap();

    assert!(
        agg1.get("replication").is_null(),
        "R=1 aggregates must not carry a replication key"
    );
    let p1 = agg1.get("points").as_arr().unwrap();
    let p3 = agg3.get("points").as_arr().unwrap();
    assert_eq!(p1.len(), p3.len());
    for (one, three) in p1.iter().zip(p3) {
        assert!(one.get("replication").is_null());
        let mut stripped = three.clone();
        if let Value::Obj(map) = &mut stripped {
            assert!(
                map.remove("replication").is_some(),
                "R=3 points must carry replication statistics"
            );
        }
        assert_eq!(
            stripped.to_string(),
            one.to_string(),
            "replicate 0 must reproduce the replication-free point bytes"
        );
    }
}
