//! Property suite for `perf/trace.rs` interpolation (ISSUE 4 satellite):
//! the trace-driven model is the paper's headline pricing path, so its
//! numerical behaviour is pinned here:
//!
//! * exact at profiled grid points,
//! * monotone along the token / batch / ctx axes for monotone samples,
//! * deterministic across calls and clones,
//! * bounded (linear) extrapolation beyond the last segment,
//! * strict rejection of malformed / empty / unsorted bundle JSON.

use llmservingsim::model::{OpInvocation, OpKind};
use llmservingsim::perf::hardware::HardwareBundle;
use llmservingsim::perf::trace::TraceDb;
use llmservingsim::perf::PerfModel;
use llmservingsim::util::json;
use llmservingsim::util::prop;
use llmservingsim::util::rng::Rng;

/// Random strictly-increasing token grid with values in [1, 10^6].
fn gen_grid(rng: &mut Rng, monotone_values: bool) -> Vec<(u64, u64)> {
    let n = 2 + rng.below(7) as usize;
    let mut x = 0u64;
    let mut y = 1 + rng.below(1_000);
    let mut pts = Vec::with_capacity(n);
    for _ in 0..n {
        x += 1 + rng.below(64);
        if monotone_values {
            y += rng.below(10_000);
        } else {
            y = 1 + rng.below(1_000_000);
        }
        pts.push((x, y));
    }
    pts
}

fn db_from(pts: &[(u64, u64)]) -> TraceDb {
    let mut db = TraceDb::new("prop-hw", "tiny-dense");
    for &(t, ns) in pts {
        db.add_tokens(OpKind::Ffn, t, ns);
    }
    db
}

fn lookup(db: &TraceDb, t: u64) -> f64 {
    db.lookup(OpInvocation::tokens(OpKind::Ffn, t))
        .expect("profiled op kind must price")
}

#[test]
fn prop_exact_at_grid_points() {
    prop::check(
        "trace-exact-at-grid",
        256,
        |rng| gen_grid(rng, false),
        |pts| {
            let db = db_from(pts);
            for &(t, ns) in pts {
                let v = lookup(&db, t);
                let tol = 1e-6 * (ns as f64).max(1.0);
                if (v - ns as f64).abs() > tol {
                    return Err(format!("f({t}) = {v}, profiled {ns}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_monotone_along_token_axis() {
    prop::check(
        "trace-monotone-tokens",
        256,
        |rng| {
            let pts = gen_grid(rng, true);
            let hi = pts.last().unwrap().0 * 2; // include extrapolation range
            let q1 = 1 + rng.below(hi);
            let q2 = 1 + rng.below(hi);
            (pts, q1.min(q2), q1.max(q2))
        },
        |(pts, q1, q2)| {
            let db = db_from(pts);
            let (v1, v2) = (lookup(&db, *q1), lookup(&db, *q2));
            if v1 > v2 + 1e-6 {
                return Err(format!("f({q1})={v1} > f({q2})={v2}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deterministic_across_calls_and_clones() {
    prop::check(
        "trace-deterministic",
        128,
        |rng| {
            let pts = gen_grid(rng, false);
            let q = 1 + rng.below(pts.last().unwrap().0 * 2);
            (pts, q)
        },
        |(pts, q)| {
            let db = db_from(pts);
            let twin = db.clone();
            let a = db.op_latency(OpInvocation::tokens(OpKind::Ffn, *q));
            let b = db.op_latency(OpInvocation::tokens(OpKind::Ffn, *q));
            let c = twin.op_latency(OpInvocation::tokens(OpKind::Ffn, *q));
            if a != b || a != c {
                return Err(format!("latencies diverged: {a} / {b} / {c}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_extrapolation_is_linear_in_last_segment() {
    prop::check(
        "trace-bounded-extrapolation",
        256,
        |rng| {
            let pts = gen_grid(rng, false);
            let last = pts.last().unwrap().0;
            let q = last + 1 + rng.below(last.max(4) * 4);
            (pts, q)
        },
        |(pts, q)| {
            let db = db_from(pts);
            let v = lookup(&db, *q);
            if !v.is_finite() || v < 0.0 {
                return Err(format!("f({q}) = {v} invalid"));
            }
            // beyond the grid, the model extends the LAST segment linearly
            // (clamped at zero) — never a higher-order blowup
            let (x0, y0) = pts[pts.len() - 2];
            let (x1, y1) = pts[pts.len() - 1];
            let slope = (y1 as f64 - y0 as f64) / (x1 as f64 - x0 as f64);
            let expect = (y1 as f64 + slope * (*q - x1) as f64).max(0.0);
            let tol = 1e-6 * expect.abs().max(1.0);
            if (v - expect).abs() > tol {
                return Err(format!("f({q}) = {v}, linear extension {expect}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_decode_grid_exact_and_monotone_in_batch_and_ctx() {
    prop::check(
        "trace-decode-bilinear",
        128,
        |rng| {
            // full (batch, ctx) grid with coefficients making the surface
            // strictly increasing along both axes
            let a = 1 + rng.below(40);
            let b = 1 + rng.below(500);
            let c = 1 + rng.below(500);
            let q_b = 1 + rng.below(16);
            let q_c = 1 + rng.below(2_048);
            (a, b, c, q_b, q_c)
        },
        |&(a, b, c, q_b, q_c)| {
            let mut db = TraceDb::new("prop-hw", "tiny-dense");
            let batches = [1u64, 2, 4, 8, 16];
            let ctxs = [64u64, 256, 1024, 2048];
            for &bb in &batches {
                for &cc in &ctxs {
                    db.add_batch_ctx(OpKind::AttnDecode, bb, cc, a * bb * cc + b * bb + c * cc);
                }
            }
            // exact on every grid point
            for &bb in &batches {
                for &cc in &ctxs {
                    let v = db.lookup(OpInvocation::decode(bb, cc)).unwrap();
                    let want = (a * bb * cc + b * bb + c * cc) as f64;
                    if (v - want).abs() > 1e-9 * want.max(1.0) {
                        return Err(format!("grid ({bb},{cc}): {v} != {want}"));
                    }
                }
            }
            // monotone: raising batch or ctx never lowers the estimate
            let v = db.lookup(OpInvocation::decode(q_b, q_c)).unwrap();
            let v_b = db.lookup(OpInvocation::decode(q_b + 1, q_c)).unwrap();
            let v_c = db.lookup(OpInvocation::decode(q_b, q_c + 64)).unwrap();
            if v_b + 1e-6 < v {
                return Err(format!("batch: f({},{q_c})={v_b} < f({q_b},{q_c})={v}", q_b + 1));
            }
            if v_c + 1e-6 < v {
                return Err(format!("ctx: f({q_b},{})={v_c} < f({q_b},{q_c})={v}", q_c + 64));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Malformed / empty / unsorted input rejection
// ---------------------------------------------------------------------------

fn trace_json_err(src: &str) -> String {
    TraceDb::from_json(&json::parse(src).unwrap())
        .expect_err("malformed trace must be rejected")
        .to_string()
}

#[test]
fn trace_json_rejects_malformed() {
    // missing required fields
    assert!(trace_json_err(r#"{}"#).contains("hardware"));
    assert!(trace_json_err(r#"{"hardware": "hw"}"#).contains("model"));
    assert!(trace_json_err(r#"{"hardware": "hw", "model": "m"}"#).contains("ops"));
    // unknown op kind
    let e = trace_json_err(
        r#"{"hardware": "hw", "model": "m",
            "ops": {"warp_core": {"grid": "tokens", "points": [[1, 10]]}}}"#,
    );
    assert!(e.contains("warp_core"), "{e}");
    // unknown grid kind
    let e = trace_json_err(
        r#"{"hardware": "hw", "model": "m",
            "ops": {"ffn": {"grid": "hexagonal", "points": [[1, 10]]}}}"#,
    );
    assert!(e.contains("hexagonal"), "{e}");
    // non-numeric / truncated points
    let e = trace_json_err(
        r#"{"hardware": "hw", "model": "m",
            "ops": {"ffn": {"grid": "tokens", "points": [["one", 10]]}}}"#,
    );
    assert!(e.contains("ffn"), "{e}");
    let e = trace_json_err(
        r#"{"hardware": "hw", "model": "m",
            "ops": {"attn_decode": {"grid": "batch_ctx", "points": [[1, 64]]}}}"#,
    );
    assert!(e.contains("attn_decode"), "{e}");
    // ops must be an object
    assert!(TraceDb::from_json(
        &json::parse(r#"{"hardware": "hw", "model": "m", "ops": [1, 2]}"#).unwrap()
    )
    .is_err());
    // duplicate grid coordinates: a zero-width segment would make the
    // interpolator divide by zero, so the trace layer itself rejects them
    // (not just the stricter bundle loader)
    let e = trace_json_err(
        r#"{"hardware": "hw", "model": "m",
            "ops": {"ffn": {"grid": "tokens", "points": [[4, 40], [4, 50]]}}}"#,
    );
    assert!(e.contains("duplicate"), "{e}");
    let e = trace_json_err(
        r#"{"hardware": "hw", "model": "m",
            "ops": {"attn_decode": {"grid": "batch_ctx",
                    "points": [[2, 64, 10], [2, 64, 12]]}}}"#,
    );
    assert!(e.contains("duplicate"), "{e}");
}

fn bundle_src(trace_ops: &str) -> String {
    format!(
        r#"{{"schema": "hardware-bundle-v1",
            "hardware": {{"name": "prop-npu", "peak_flops": 1e12,
                          "mem_bw": 1e11, "mem_capacity": 1073741824,
                          "host_bw": 1e10, "kernel_overhead_ns": 5000}},
            "trace": {{"hardware": "prop-npu", "model": "tiny-dense",
                       "ops": {trace_ops}}}}}"#
    )
}

fn bundle_err(src: &str) -> String {
    HardwareBundle::from_json(&json::parse(src).unwrap())
        .expect_err("malformed bundle must be rejected")
        .to_string()
}

#[test]
fn bundle_json_rejects_empty_and_unsorted() {
    // a well-formed bundle parses (control)
    let good = bundle_src(r#"{"ffn": {"grid": "tokens", "points": [[1, 10], [4, 40]]}}"#);
    HardwareBundle::from_json(&json::parse(&good).unwrap()).unwrap();

    // empty trace section
    let e = bundle_err(&bundle_src("{}"));
    assert!(e.contains("no samples"), "{e}");

    // unsorted grid points
    let e = bundle_err(&bundle_src(
        r#"{"ffn": {"grid": "tokens", "points": [[4, 40], [1, 10]]}}"#,
    ));
    assert!(e.contains("out of order"), "{e}");
    let e = bundle_err(&bundle_src(
        r#"{"attn_decode": {"grid": "batch_ctx",
            "points": [[2, 64, 10], [1, 64, 5]]}}"#,
    ));
    assert!(e.contains("out of order"), "{e}");

    // duplicate grid points (ambiguous samples)
    let e = bundle_err(&bundle_src(
        r#"{"ffn": {"grid": "tokens", "points": [[4, 40], [4, 50]]}}"#,
    ));
    assert!(e.contains("out of order") || e.contains("duplicate"), "{e}");

    // spec-level garbage: zero bandwidth
    let e = bundle_err(
        r#"{"schema": "hardware-bundle-v1",
            "hardware": {"name": "prop-npu", "peak_flops": 1e12,
                         "mem_bw": 0, "mem_capacity": 1073741824,
                         "host_bw": 1e10, "kernel_overhead_ns": 5000}}"#,
    );
    assert!(e.contains("mem_bw"), "{e}");
}
