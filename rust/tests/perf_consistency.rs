//! Cross-backend consistency (ISSUE 4 satellite): the four pricing paths
//! (cycle, memoized replay, trace interpolation, calibrated roofline) must
//! agree with each other where their contracts overlap:
//!
//! * `replay` is byte-identical to `cycle` — per invocation (repeated) and
//!   for whole simulation reports;
//! * calibration factors are finite and positive for every `OpKind`;
//! * trace pricing and calibrated-analytical pricing agree within the
//!   calibration factor at profiled shapes.

use llmservingsim::config::{presets, PerfBackend};
use llmservingsim::coordinator::run_config;
use llmservingsim::model::{ModelSpec, OpInvocation, OpKind};
use llmservingsim::perf::analytical::{Calibrated, Roofline};
use llmservingsim::perf::cycle::{CycleSim, SystolicSpec};
use llmservingsim::perf::hardware::HardwareBundle;
use llmservingsim::perf::replay::Replay;
use llmservingsim::perf::trace::TraceDb;
use llmservingsim::perf::{HardwareSpec, PerfModel};

/// Invocation shapes covering every op kind, with deliberate repeats so the
/// replay cache serves hits.
fn shape_sweep() -> Vec<OpInvocation> {
    let mut invs = vec![];
    for &kind in OpKind::all() {
        if kind.is_decode_grid() {
            for (b, c) in [(1u64, 64u64), (4, 256), (8, 1024), (4, 256)] {
                invs.push(OpInvocation::decode(b, c));
            }
        } else if kind == OpKind::AttnPrefill {
            for t in [8u64, 64, 256, 64] {
                invs.push(OpInvocation::prefill(t));
            }
        } else {
            for t in [1u64, 16, 128, 16] {
                invs.push(OpInvocation::tokens(kind, t));
            }
        }
    }
    invs
}

#[test]
fn replay_matches_cycle_on_every_invocation_repeatedly() {
    let model = ModelSpec::tiny_moe();
    let cycle = CycleSim::new(SystolicSpec::default(), model.clone());
    let replay = Replay::new(CycleSim::new(SystolicSpec::default(), model));
    for inv in shape_sweep() {
        let want = cycle.op_latency(inv);
        // first call populates the cache, later calls replay it — all three
        // must be bit-identical to the uncached cycle result
        for round in 0..3 {
            let got = replay.op_latency(inv);
            assert_eq!(got, want, "{inv:?} diverged on round {round}");
        }
    }
    let (hits, misses) = replay.stats();
    assert!(hits > 0, "repeated shapes must hit the replay cache");
    assert!(misses > 0);
}

#[test]
fn replay_and_cycle_simulation_reports_are_byte_identical() {
    let mk = |perf: PerfBackend| {
        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.workload.num_requests = 6;
        cfg.workload.lengths = llmservingsim::workload::LengthDist::short();
        cfg.perf = perf;
        let (report, _) = run_config(cfg).unwrap();
        report.to_json().to_string()
    };
    let cycle = mk(PerfBackend::Cycle);
    let replay_a = mk(PerfBackend::CycleReplay);
    let replay_b = mk(PerfBackend::CycleReplay);
    assert_eq!(cycle, replay_a, "memoization must not change a single byte");
    assert_eq!(replay_a, replay_b, "replay must be reproducible across runs");
}

/// A trace whose every sample is exactly `factor` x the roofline latency of
/// `hw`/`model` at that shape.
fn scaled_trace(hw: &HardwareSpec, model: &ModelSpec, factor: f64) -> TraceDb {
    let roof = Roofline::new(hw.clone(), model.clone());
    let mut db = TraceDb::new(&hw.name, &model.name);
    for &kind in OpKind::all() {
        if kind.is_decode_grid() {
            for b in [1u64, 2, 4, 8] {
                for c in [64u64, 256, 1024] {
                    let inv = OpInvocation::decode(b, c);
                    let ns = (roof.raw_latency(inv) * factor * 1e9).round() as u64;
                    db.add_batch_ctx(kind, b, c, ns.max(1));
                }
            }
        } else {
            for t in [4u64, 16, 64, 256] {
                let inv = if kind == OpKind::AttnPrefill {
                    OpInvocation::prefill(t)
                } else {
                    OpInvocation::tokens(kind, t)
                };
                let ns = (roof.raw_latency(inv) * factor * 1e9).round() as u64;
                db.add_tokens(kind, t, ns.max(1));
            }
        }
    }
    db
}

#[test]
fn calibration_factors_finite_and_positive_for_every_opkind() {
    // tiny-moe exercises the MoE op kinds with real expert dimensions
    let model = ModelSpec::tiny_moe();
    let hw = HardwareSpec::cpu_pjrt();
    let db = scaled_trace(&hw, &model, 3.0);
    let factors = db.calibration(&Roofline::new(hw.clone(), model.clone()));
    for &kind in OpKind::all() {
        let (_, f) = factors
            .iter()
            .find(|(k, _)| *k == kind)
            .unwrap_or_else(|| panic!("no calibration factor for {kind}"));
        assert!(f.is_finite() && *f > 0.0, "{kind}: factor {f}");
        assert!((*f - 3.0).abs() < 0.1, "{kind}: factor {f} should be ~3.0");
    }
    // the Calibrated wrapper keeps every kind finite/positive, measured or
    // not (unmeasured kinds fall back to 1.0)
    let cal = Calibrated::new(Roofline::new(hw, model), factors);
    for &kind in OpKind::all() {
        let f = cal.factor(kind);
        assert!(f.is_finite() && f > 0.0, "{kind}: wrapped factor {f}");
    }
}

#[test]
fn trace_and_calibrated_roofline_agree_at_profiled_shapes() {
    let model = ModelSpec::tiny_dense();
    let hw = HardwareSpec::cpu_pjrt();
    let factor = 2.5;
    let db = scaled_trace(&hw, &model, factor);
    let roof = Roofline::new(hw.clone(), model.clone());
    let cal = Calibrated::new(roof.clone(), db.calibration(&roof));

    for &kind in OpKind::all() {
        let invs: Vec<OpInvocation> = if kind.is_decode_grid() {
            vec![OpInvocation::decode(2, 256), OpInvocation::decode(8, 1024)]
        } else if kind == OpKind::AttnPrefill {
            vec![OpInvocation::prefill(16), OpInvocation::prefill(256)]
        } else {
            vec![
                OpInvocation::tokens(kind, 16),
                OpInvocation::tokens(kind, 256),
            ]
        };
        for inv in invs {
            let traced = db.op_latency(inv) as f64;
            // strip the fixed kernel overhead the analytical family adds;
            // the trace measures it inside its samples by construction
            let calibrated = cal.op_latency(inv).saturating_sub(hw.kernel_overhead) as f64;
            let rel = (traced - calibrated).abs() / traced.max(1.0);
            assert!(
                rel < 0.02,
                "{inv:?}: trace {traced} vs calibrated {calibrated} ({:.2}% off)",
                rel * 100.0
            );
        }
    }
}

#[test]
fn bundle_pricing_is_trace_where_profiled_calibrated_elsewhere() {
    let model = ModelSpec::tiny_dense();
    let hw = HardwareSpec {
        name: "consistency-npu".into(),
        ..HardwareSpec::cpu_pjrt()
    };
    let mut db = scaled_trace(&hw, &model, 2.0);
    // renaming: scaled_trace tags with hw.name already; drop one op kind so
    // the fallback path is exercised
    db = {
        let mut partial = TraceDb::new(&db.hardware, &db.model);
        for kind in db.kinds().collect::<Vec<_>>() {
            if kind == OpKind::LmHead {
                continue;
            }
            for (a, b, ns) in db.samples(kind) {
                if kind.is_decode_grid() {
                    partial.add_batch_ctx(kind, a, b, ns);
                } else {
                    partial.add_tokens(kind, a, ns);
                }
            }
        }
        partial
    };
    let bundle = HardwareBundle::from_trace(hw.clone(), db.clone()).unwrap();
    let perf = bundle.perf_on(&hw, &model);
    // profiled shape: exact trace value
    let inv = OpInvocation::tokens(OpKind::Ffn, 64);
    assert_eq!(perf.op_latency(inv), db.op_latency(inv));
    // unprofiled kind: calibrated roofline value, bit-for-bit
    let cal = Calibrated::new(
        Roofline::new(hw.clone(), model.clone()),
        bundle.calibration.clone(),
    );
    let inv = OpInvocation::tokens(OpKind::LmHead, 64);
    assert_eq!(perf.op_latency(inv), cal.op_latency(inv));
}
