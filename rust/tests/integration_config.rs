//! Config-file integration: JSON round-trips through disk, user-authored
//! configs load, and validation rejects inconsistent deployments.

use llmservingsim::config::{presets, CacheScope, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::util::json;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("llmss_cfg_{name}.json"))
}

#[test]
fn save_load_run_roundtrip() {
    let mut cfg = presets::with_prefix_cache(
        presets::multi_dense("tiny-dense", "rtx3090"),
        CacheScope::Global,
    );
    cfg.workload.num_requests = 10;
    let path = tmp("roundtrip");
    cfg.save(&path).unwrap();
    let loaded = SimConfig::load(&path).unwrap();
    assert_eq!(cfg, loaded);
    let (a, _) = run_config(cfg).unwrap();
    let (b, _) = run_config(loaded).unwrap();
    assert_eq!(a.makespan, b.makespan);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn hand_written_config_loads() {
    let text = r#"{
      "name": "hand-written",
      "seed": 7,
      "router": "prefix-aware",
      "block_size": 32,
      "perf": {"backend": "analytical"},
      "workload": {
        "num_requests": 8,
        "arrival": {"kind": "poisson", "rate": 5.0},
        "sessions": 2,
        "shared_prefix": 16
      },
      "instances": [
        {
          "name": "gpu0",
          "model": "tiny-dense",
          "hardware": "rtx3090",
          "devices": 2,
          "tp": 2,
          "max_batch_tokens": 1024,
          "sched": "sjf",
          "prefix_cache": {"device_fraction": 0.1, "policy": "lfu",
                           "scope": "global"},
          "topology": "ring"
        },
        {
          "name": "tpu0",
          "model": "tiny-dense",
          "hardware": "tpu-v6e",
          "af_disagg": true
        }
      ]
    }"#;
    let path = tmp("hand");
    std::fs::write(&path, text).unwrap();
    let cfg = SimConfig::load(&path).unwrap();
    assert_eq!(cfg.name, "hand-written");
    assert_eq!(cfg.instances.len(), 2);
    assert_eq!(cfg.instances[0].tp, 2);
    assert!(cfg.instances[1].af_disagg);
    let (report, _) = run_config(cfg).unwrap();
    assert_eq!(report.num_finished, 8);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn invalid_configs_rejected_with_clear_errors() {
    let cases = [
        // tp doesn't divide devices
        (
            r#"{"instances": [{"model": "tiny-dense", "hardware": "rtx3090",
                "devices": 4, "tp": 3}]}"#,
            "must divide",
        ),
        // ep on a dense model
        (
            r#"{"instances": [{"model": "tiny-dense", "hardware": "rtx3090",
                "devices": 2, "tp": 2, "ep": 2}]}"#,
            "MoE",
        ),
        // unknown model
        (
            r#"{"instances": [{"model": "gpt-7", "hardware": "rtx3090"}]}"#,
            "unknown model",
        ),
        // prefill without decode
        (
            r#"{"instances": [{"model": "tiny-dense", "hardware": "rtx3090",
                "role": "prefill"}]}"#,
            "prefill and decode",
        ),
        // bad serving-mechanism spellings still fail at parse time
        (
            r#"{"instances": [{"model": "tiny-dense", "hardware": "rtx3090",
                "role": "proxy"}]}"#,
            "unknown role",
        ),
        (
            r#"{"instances": [{"model": "tiny-dense", "hardware": "rtx3090",
                "kv_transfer": "streamed"}]}"#,
            "kv-transfer",
        ),
    ];
    for (text, needle) in cases {
        let v = json::parse(text).unwrap();
        let err = SimConfig::from_json(&v).unwrap_err().to_string();
        assert!(
            err.contains(needle),
            "error '{err}' should mention '{needle}'"
        );
    }
}

#[test]
fn unknown_policy_names_load_but_fail_to_build_with_candidates() {
    // Policy names are registry keys, not config enums: the file parses,
    // and the error surfaces at simulation construction listing what IS
    // registered.
    let text = r#"{"router": "coin-flip",
        "instances": [{"model": "tiny-dense", "hardware": "rtx3090"}]}"#;
    let cfg = SimConfig::from_json(&json::parse(text).unwrap()).unwrap();
    assert_eq!(cfg.router, "coin-flip");
    let err = llmservingsim::coordinator::Simulation::new(cfg)
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("coin-flip") && err.contains("round-robin"),
        "error '{err}' should name the bad policy and the candidates"
    );
}

#[test]
fn workload_trace_files_interoperate_with_cli_schema() {
    // gen-trace writes the same schema load_trace reads
    let reqs = llmservingsim::workload::WorkloadSpec::sharegpt_100(10.0)
        .generate()
        .unwrap();
    let path = tmp("trace");
    llmservingsim::workload::save_trace(&path, &reqs).unwrap();
    let loaded = llmservingsim::workload::load_trace(&path).unwrap();
    assert_eq!(reqs.len(), loaded.len());
    assert_eq!(reqs[0], loaded[0]);
    let _ = std::fs::remove_file(&path);
}
