//! Chaos & resilience acceptance contract (ISSUE 8):
//!
//! * seeded chaos over the multi-tenant bursty scenario is byte-identical
//!   across repeated runs and across 1 vs 8 sweep workers;
//! * a zero-fault chaos profile reproduces the no-controller report
//!   byte-for-byte (keys included);
//! * a correlated zone outage visibly degrades service during the fault
//!   window — per-zone availability drops, SLO attainment inside the
//!   window never beats attainment outside it, mean TTFT worsens vs the
//!   fault-free run — and the fleet recovers after the scripted MTTR;
//! * under admission-controlled overload every arrival is accounted for:
//!   rejected + finished + in-flight == arrivals.
//!
//! The soak test also writes the fault timeline to
//! `target/chaos_timeline.json` so CI can upload it as an artifact when
//! something fails.

use std::path::PathBuf;

use llmservingsim::cluster::{ClusterAction, ClusterController, ClusterView};
use llmservingsim::config::{presets, AdmissionConfig, ChaosConfig, SimConfig};
use llmservingsim::coordinator::{run_config, Simulation};
use llmservingsim::sim::{Nanos, MILLI};
use llmservingsim::sweep::{run_sweep, SweepSpec};
use llmservingsim::util::json::Value;

fn timeline_json(report: &llmservingsim::metrics::Report) -> Value {
    Value::arr(report.timeline.iter().map(|e| e.to_json()).collect())
}

#[test]
fn chaos_soak_is_byte_identical_across_runs_and_worker_counts() {
    let cfg = presets::chaos_soak();
    let (report, summary) = run_config(cfg.clone()).unwrap();

    // Leave the fault timeline on disk for CI to upload on failure.
    let out = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target/chaos_timeline.json");
    std::fs::create_dir_all(out.parent().unwrap()).unwrap();
    std::fs::write(&out, timeline_json(&report).to_string()).unwrap();

    assert_eq!(
        report.num_finished, report.num_requests,
        "chaos must not lose requests"
    );
    assert_eq!(summary.controller, "chaos");
    assert!(
        report.timeline.iter().any(|e| e.kind != "sample"),
        "the heavy profile must inject at least one fault"
    );
    // Injection respects the horizon. Only kinds that are never reused for
    // recovery qualify: perf-scale/degrade-link recoveries (scale back to
    // 1.0) legitimately land after it. Incidents drawn just inside the
    // horizon are applied on the following controller tick, hence the one-
    // tick grace.
    let horizon = (cfg.cluster.chaos.horizon_ms + cfg.cluster.tick_ms) * MILLI;
    for e in report.timeline.iter().filter(|e| {
        matches!(e.kind.as_str(), "fail" | "fail-domain" | "partition")
    }) {
        assert!(
            e.at <= horizon,
            "fault '{}' injected at {} ns, past the {} ns horizon",
            e.kind,
            e.at,
            horizon
        );
    }

    // Repeated standalone run: byte-identical.
    let (again, _) = run_config(cfg.clone()).unwrap();
    assert_eq!(report.to_json().to_string(), again.to_json().to_string());

    // A 4-point grid (distinct seeds) through the sweep engine at 1 and 8
    // workers: every point byte-identical to its standalone reference.
    let grid: Vec<SimConfig> = (0..4)
        .map(|i| {
            let mut c = cfg.clone();
            c.name = format!("chaos-soak-{i}");
            c.seed += i;
            c.workload.seed += i;
            c.cluster.chaos.seed += i;
            c
        })
        .collect();
    let reference: Vec<String> = grid
        .iter()
        .map(|c| run_config(c.clone()).unwrap().0.to_json().to_string())
        .collect();
    for threads in [1, 8] {
        let swept: Vec<String> = run_sweep(&grid, threads)
            .unwrap()
            .points
            .into_iter()
            .map(|p| p.report.to_json().to_string())
            .collect();
        assert_eq!(
            swept, reference,
            "chaos soak diverged at {threads} sweep workers"
        );
    }
}

#[test]
fn zero_fault_chaos_reproduces_the_no_controller_report() {
    let mut base = presets::multi_tenant_bursty(
        presets::multi_dense("tiny-dense", "rtx3090"),
        2,
        40.0,
    );
    base.workload.num_requests = 60;
    base.workload.lengths = llmservingsim::workload::LengthDist::short();
    let (plain, plain_sum) = run_config(base.clone()).unwrap();

    let mut inert = base;
    inert.cluster.controller = "chaos".to_string();
    inert.cluster.chaos = ChaosConfig::profile("none").unwrap();
    let (chaotic, chaos_sum) = run_config(inert).unwrap();

    assert_eq!(
        plain.to_json().to_string(),
        chaotic.to_json().to_string(),
        "an inert chaos profile must leave no trace in the report"
    );
    assert_eq!(plain_sum.controller, "static");
    assert_eq!(
        chaos_sum.controller, "static",
        "a controller that never acts reports as static"
    );
    assert!(plain.resilience.is_none());
    assert!(plain.to_json().get("resilience").is_null());
    assert!(plain.to_json().get("rejected").is_null());
}

/// Scripted (non-random) incident for the recovery test: fail zone
/// `zone-a` at a fixed simulated time, bring its members back a fixed MTTR
/// later. Fixed timestamps keep the test independent of the chaos RNG.
struct ScriptedOutage {
    fail_at: Nanos,
    recover_at: Nanos,
    members: Vec<usize>,
    failed: bool,
    recovered: bool,
}

impl ClusterController for ScriptedOutage {
    fn name(&self) -> &str {
        "scripted-outage"
    }
    fn on_tick(&mut self, now: Nanos, _view: &ClusterView) -> Vec<ClusterAction> {
        if !self.failed && now >= self.fail_at {
            self.failed = true;
            return vec![ClusterAction::FailDomain {
                zone: "zone-a".to_string(),
                at: now,
            }];
        }
        if self.failed && !self.recovered && now >= self.recover_at {
            self.recovered = true;
            return self
                .members
                .iter()
                .map(|&instance| ClusterAction::Recover { instance })
                .collect();
        }
        vec![]
    }
    // Keep the tick train alive until the recovery has been issued even if
    // the event queue drains mid-outage.
    fn has_pending(&self, _now: Nanos) -> bool {
        !self.recovered
    }
}

#[test]
fn zone_outage_degrades_slo_in_window_and_recovers_after_mttr() {
    let mut cfg = presets::chaos_soak();
    // Replace the random injector with the scripted outage: zone-a (two of
    // three instances) down from 150 ms to 450 ms.
    cfg.cluster.controller = "static".to_string();
    cfg.cluster.chaos = ChaosConfig::default();
    let members: Vec<usize> = cfg
        .instances
        .iter()
        .enumerate()
        .filter(|(_, i)| i.zone == "zone-a")
        .map(|(idx, _)| idx)
        .collect();
    assert_eq!(members, vec![0, 1], "chaos_soak racks inst0/inst1 in zone-a");

    let (clear, _) = run_config(cfg.clone()).unwrap();
    assert!(clear.resilience.is_none(), "fault-free run has no windows");

    let mut sim = Simulation::builder(cfg.clone())
        .with_controller(Box::new(ScriptedOutage {
            fail_at: 150 * MILLI,
            recover_at: 450 * MILLI,
            members,
            failed: false,
            recovered: false,
        }))
        .build()
        .unwrap();
    let report = sim.run();

    assert_eq!(
        report.num_finished, report.num_requests,
        "the outage must not lose requests"
    );
    let kinds: Vec<&str> = report.timeline.iter().map(|e| e.kind.as_str()).collect();
    assert!(kinds.contains(&"fail-domain"), "{kinds:?}");
    assert!(kinds.contains(&"recover"));
    assert!(
        kinds.contains(&"ready"),
        "failed instances must rejoin after the MTTR: {kinds:?}"
    );

    let res = report.resilience.as_ref().expect("outage opens fault windows");
    assert_eq!(res.faults, 2, "both zone-a members fail");
    assert!(res.fault_ns >= 300 * MILLI, "window spans the scripted MTTR");
    assert!(
        res.fault_ns < report.makespan,
        "the fleet recovers — the window must close before the run ends"
    );
    assert!(
        res.finished_in_fault > 0,
        "the bursty workload keeps finishing work inside the window"
    );
    assert!(
        res.slo_in_fault <= res.slo_clear,
        "attainment inside the window ({}) cannot beat attainment outside it ({})",
        res.slo_in_fault,
        res.slo_clear
    );
    // Per-zone availability: zone-a ate all the downtime.
    assert_eq!(res.domains.len(), 2);
    let za = res.domains.iter().find(|d| d.zone == "zone-a").unwrap();
    let zb = res.domains.iter().find(|d| d.zone == "zone-b").unwrap();
    assert_eq!(za.instances, 2);
    assert!(za.downtime_ns >= 2 * 300 * MILLI, "{}", za.downtime_ns);
    assert!(za.availability < 1.0);
    assert_eq!(zb.downtime_ns, 0);
    assert_eq!(zb.availability, 1.0);

    // Losing two thirds of the fleet for 300 ms must show up end to end.
    assert!(
        report.ttft_ns.mean > clear.ttft_ns.mean,
        "outage TTFT {} must exceed fault-free TTFT {}",
        report.ttft_ns.mean,
        clear.ttft_ns.mean
    );
}

#[test]
fn admission_control_accounts_for_every_arrival_under_overload() {
    let mut cfg = presets::multi_tenant_bursty(
        presets::single_dense("tiny-dense", "rtx3090"),
        2,
        200.0,
    );
    cfg.workload.num_requests = 120;
    cfg.workload.lengths = llmservingsim::workload::LengthDist::short();
    cfg.cluster.admission = Some(AdmissionConfig {
        rate: 20.0,
        burst: 5.0,
        breaker_queue: 8,
        breaker_cooldown_ms: 200,
    });
    let mut sim = Simulation::new(cfg.clone()).unwrap();
    let report = sim.run();

    assert!(report.rejected > 0, "a 10x overload must trip admission");
    assert!(report.num_finished > 0, "admitted work still completes");
    let in_flight = sim.cluster_view(report.makespan).in_flight;
    assert_eq!(
        report.rejected + report.num_finished + in_flight,
        report.num_requests,
        "conservation: rejected + finished + in-flight == arrivals"
    );
    assert_eq!(
        report.to_json().get("rejected").as_i64(),
        Some(report.rejected as i64)
    );

    // Deterministic: the same overload rejects the same requests.
    let (again, _) = run_config(cfg).unwrap();
    assert_eq!(report.to_json().to_string(), again.to_json().to_string());
}
