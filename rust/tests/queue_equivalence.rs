//! ISSUE 6: the calendar event queue must be observably identical to a
//! trivially-correct sorted reference queue, and the whole-simulation
//! determinism contract — byte-identical report JSON — must hold across
//! every serving preset, the {trace, analytical} perf backends, and
//! 1-vs-8 sweep worker counts.
//!
//! The first half drives randomized op streams (same-timestamp bursts,
//! far-future controller ticks, interleaved push/pop, behind-`now`
//! schedules) through both queues and compares every observable: pop
//! stream, `now`, `len`, and `peek_time`. The second half pins the report
//! bytes the queue ultimately feeds.

use llmservingsim::config::{presets, PerfBackend, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::model::{ModelSpec, OpInvocation, OpKind};
use llmservingsim::perf::analytical::Roofline;
use llmservingsim::perf::trace::TraceDb;
use llmservingsim::perf::HardwareSpec;
use llmservingsim::sim::{Event, EventQueue, Nanos};
use llmservingsim::sweep::{run_sweep, SweepSpec};
use llmservingsim::util::prop;
use llmservingsim::util::rng::Rng;
use llmservingsim::workload::LengthDist;

// ---- part 1: calendar queue vs reference model ----------------------------

/// The obviously-correct model: a flat vector, popped by linear min-scan on
/// `(at, seq)`, with the same `now`-clamping rule as the real queue.
struct RefQueue {
    items: Vec<(Nanos, u64, Event)>,
    now: Nanos,
    seq: u64,
}

impl RefQueue {
    fn new() -> Self {
        RefQueue {
            items: vec![],
            now: 0,
            seq: 0,
        }
    }

    fn schedule_at(&mut self, at: Nanos, event: Event) {
        let at = at.max(self.now);
        self.items.push((at, self.seq, event));
        self.seq += 1;
    }

    fn schedule_in(&mut self, delay: Nanos, event: Event) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    fn pop(&mut self) -> Option<(Nanos, Event)> {
        if self.items.is_empty() {
            return None;
        }
        let mut best = 0;
        for i in 1..self.items.len() {
            let (at, seq, _) = self.items[i];
            let (b_at, b_seq, _) = self.items[best];
            if (at, seq) < (b_at, b_seq) {
                best = i;
            }
        }
        let (at, _, event) = self.items.remove(best);
        self.now = at;
        Some((at, event))
    }

    fn peek_time(&self) -> Option<Nanos> {
        self.items.iter().map(|&(at, _, _)| at).min()
    }
}

/// Every `Event` variant shows up in the streams, so payloads are compared
/// through `PartialEq` across the whole enum, not just one arm.
fn event_for(i: u64, k: u64) -> Event {
    match k {
        0 => Event::RequestArrival { request_id: i },
        1 => Event::StepComplete {
            instance: (i % 5) as usize,
        },
        2 => Event::Wake {
            instance: (i % 7) as usize,
        },
        3 => Event::KvTransferDone {
            request_id: i,
            dst_instance: (i % 3) as usize,
        },
        4 => Event::ExpertFetchDone {
            instance: (i % 4) as usize,
            layer: i % 11,
            expert: i % 13,
        },
        5 => Event::MetricsTick,
        6 => Event::ControllerTick,
        7 => Event::InstanceReady {
            instance: (i % 5) as usize,
        },
        _ => Event::InstanceFail {
            instance: (i % 5) as usize,
        },
    }
}

#[derive(Debug, Clone)]
enum Op {
    /// `schedule_in(delay)` — relative, saturating.
    In(Nanos, Event),
    /// `schedule_at(at)` — absolute, possibly behind `now` (clamped).
    At(Nanos, Event),
    Pop,
}

/// Delay mixture spanning every queue regime: zero-delay bursts,
/// sub-bucket, multi-bucket, past-the-ring-horizon (overflow heap), and
/// saturating far-future; plus absolute times that land behind `now`.
fn gen_ops(rng: &mut Rng) -> Vec<Op> {
    let len = 100 + rng.below(200) as usize;
    let mut ops = Vec::with_capacity(len);
    for i in 0..len as u64 {
        let ev = event_for(i, rng.below(9));
        ops.push(match rng.below(8) {
            0 | 1 => Op::Pop,
            2 => Op::In(0, ev),
            3 => Op::In(rng.below(1 << 12), ev),
            4 => Op::In(rng.below(1 << 24), ev),
            5 => Op::In(600_000_000 + rng.below(1 << 34), ev),
            6 => Op::At(rng.below(1 << 16), ev),
            _ => Op::In(u64::MAX / (1 + rng.below(4)), ev),
        });
    }
    ops
}

#[test]
fn calendar_queue_matches_sorted_reference_on_random_schedules() {
    prop::check("queue-equivalence", 128, gen_ops, |ops| {
        let mut q = EventQueue::new();
        let mut r = RefQueue::new();
        for (step, op) in ops.iter().enumerate() {
            match *op {
                Op::In(d, ev) => {
                    q.schedule_in(d, ev);
                    r.schedule_in(d, ev);
                }
                Op::At(at, ev) => {
                    q.schedule_at(at, ev);
                    r.schedule_at(at, ev);
                }
                Op::Pop => {
                    let got = q.pop();
                    let want = r.pop();
                    if got != want {
                        return Err(format!("step {step}: pop {got:?} != {want:?}"));
                    }
                }
            }
            if q.len() != r.items.len() {
                return Err(format!(
                    "step {step}: len {} != {}",
                    q.len(),
                    r.items.len()
                ));
            }
            if q.now() != r.now {
                return Err(format!("step {step}: now {} != {}", q.now(), r.now));
            }
            if q.peek_time() != r.peek_time() {
                return Err(format!(
                    "step {step}: peek {:?} != {:?}",
                    q.peek_time(),
                    r.peek_time()
                ));
            }
        }
        loop {
            let got = q.pop();
            let want = r.pop();
            if got != want {
                return Err(format!("drain: pop {got:?} != {want:?}"));
            }
            if got.is_none() {
                break;
            }
        }
        if !q.is_empty() {
            return Err("queue claims non-empty after full drain".into());
        }
        Ok(())
    });
}

#[test]
fn same_timestamp_bursts_pop_fifo_under_interleaved_pops() {
    let mut q = EventQueue::new();
    let mut next = 0u64;
    for i in 0..1000u64 {
        q.schedule_at(5_000_000, Event::RequestArrival { request_id: i });
        if i % 3 == 0 {
            // pop while the burst is still being scheduled: strict FIFO
            let (at, ev) = q.pop().unwrap();
            assert_eq!(at, 5_000_000);
            assert_eq!(ev, Event::RequestArrival { request_id: next });
            next += 1;
        }
    }
    while let Some((at, ev)) = q.pop() {
        assert_eq!(at, 5_000_000);
        assert_eq!(ev, Event::RequestArrival { request_id: next });
        next += 1;
    }
    assert_eq!(next, 1000, "every event popped exactly once");
}

#[test]
fn far_future_controller_ticks_survive_the_overflow_horizon() {
    const HOUR: Nanos = 3_600_000_000_000;
    let mut q = EventQueue::new();
    // Hourly ticks land far beyond the ~537 ms calendar ring.
    for k in 1..=5u64 {
        q.schedule_at(k * HOUR, Event::ControllerTick);
    }
    // Near-term chatter interleaved after them.
    for i in 0..100u64 {
        q.schedule_in(i * 1_000, Event::Wake { instance: 0 });
    }
    let mut times = vec![];
    while let Some((at, _)) = q.pop() {
        times.push(at);
    }
    assert_eq!(times.len(), 105);
    assert!(times.windows(2).all(|w| w[0] <= w[1]), "pops out of order");
    assert_eq!(*times.last().unwrap(), 5 * HOUR);
}

// ---- part 2: report byte-identity -----------------------------------------

fn small(mut cfg: SimConfig, perf: PerfBackend) -> SimConfig {
    cfg.workload.num_requests = 12;
    cfg.workload.lengths = LengthDist::short();
    cfg.perf = perf;
    cfg
}

fn report_string(cfg: SimConfig) -> String {
    let (report, _) = run_config(cfg).unwrap();
    report.to_json().to_string()
}

/// A synthetic profiled trace (every `OpKind`, 1.7x roofline) so the trace
/// backend runs hermetically: dense presets price via exact trace
/// interpolation, MoE presets via its calibrated-analytical extension.
fn synthetic_trace() -> std::path::PathBuf {
    let model = ModelSpec::tiny_dense();
    let hw = HardwareSpec::preset("rtx3090").unwrap();
    let roof = Roofline::new(hw.clone(), model.clone());
    let mut db = TraceDb::new(&hw.name, &model.name);
    for &kind in OpKind::all() {
        if kind.is_decode_grid() {
            for b in [1u64, 2, 4, 8] {
                for c in [64u64, 256, 1024] {
                    let inv = OpInvocation::decode(b, c);
                    let ns = (roof.raw_latency(inv) * 1.7 * 1e9).round() as u64;
                    db.add_batch_ctx(kind, b, c, ns.max(1));
                }
            }
        } else {
            for t in [4u64, 16, 64, 256] {
                let inv = if kind == OpKind::AttnPrefill {
                    OpInvocation::prefill(t)
                } else {
                    OpInvocation::tokens(kind, t)
                };
                let ns = (roof.raw_latency(inv) * 1.7 * 1e9).round() as u64;
                db.add_tokens(kind, t, ns.max(1));
            }
        }
    }
    let path = std::env::temp_dir().join("llmss_queue_equiv_trace.json");
    db.save(&path).unwrap();
    path
}

#[test]
fn reports_byte_identical_across_presets_and_backends() {
    let trace = synthetic_trace();
    let backends = [
        PerfBackend::Analytical,
        PerfBackend::Trace {
            path: trace.to_string_lossy().into_owned(),
        },
    ];
    for &name in presets::serving_preset_names() {
        for backend in &backends {
            let cfg = small(
                presets::by_name(name, "tiny-dense", "tiny-moe", "rtx3090").unwrap(),
                backend.clone(),
            );
            let a = report_string(cfg.clone());
            let b = report_string(cfg);
            assert_eq!(a, b, "preset '{name}' x {backend:?}: report bytes drifted");
        }
    }
    let _ = std::fs::remove_file(&trace);
}

#[test]
fn sweep_reports_byte_identical_at_1_and_8_workers() {
    let mut spec = SweepSpec {
        num_requests: 12,
        quick: true,
        seed: 0x6EED,
        ..SweepSpec::default()
    };
    spec.axes.presets = presets::serving_preset_names()
        .iter()
        .map(|s| s.to_string())
        .collect();
    let cfgs = spec.expand().unwrap();
    assert_eq!(
        cfgs.len(),
        presets::serving_preset_names().len(),
        "one grid point per serving preset"
    );

    let reference: Vec<(String, String)> = cfgs
        .iter()
        .map(|cfg| {
            let (report, _) = run_config(cfg.clone()).unwrap();
            (cfg.name.clone(), report.to_json().to_string())
        })
        .collect();
    for threads in [1, 8] {
        let swept: Vec<(String, String)> = run_sweep(&cfgs, threads)
            .unwrap()
            .points
            .into_iter()
            .map(|p| (p.name, p.report.to_json().to_string()))
            .collect();
        assert_eq!(swept, reference, "sweep diverged at {threads} workers");
    }
}
