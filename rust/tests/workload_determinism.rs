//! Property tests for the workload engine's determinism contract
//! (ISSUE 3 satellite): same seed ⇒ byte-identical request streams from
//! every traffic source, identical whether generated eagerly or pulled
//! incrementally, with monotone non-decreasing arrivals — across a fuzzed
//! space of rates, seeds, tenant mixes, and session shapes.
//!
//! Uses the in-repo property harness (`util/prop.rs`): failures report the
//! per-case seed for replay.

use llmservingsim::prop_assert;
use llmservingsim::util::prop;
use llmservingsim::util::rng::Rng;
use llmservingsim::workload::{
    to_json, LengthDist, TenantSpec, Traffic, WorkloadSpec,
};

/// A fuzzed spec: random built-in source, rate spanning 5 orders of
/// magnitude, random tenant/session shape.
fn gen_spec(rng: &mut Rng) -> WorkloadSpec {
    let names = Traffic::builtin_names();
    let name = names[rng.below(names.len() as u64) as usize];
    // rates from 0.01 to 1000 req/s (log-uniform)
    let rate = 10f64.powf(rng.range_f64(-2.0, 3.0));
    WorkloadSpec {
        num_requests: 1 + rng.below(60) as usize,
        traffic: Traffic::for_name(name, rate).unwrap(),
        lengths: LengthDist::short(),
        sessions: rng.below(8) as usize,
        shared_prefix: rng.below(48),
        tenants: TenantSpec::mix(rng.below(4) as usize),
        seed: rng.next_u64(),
    }
}

#[test]
fn same_seed_same_stream_bytes() {
    prop::check("workload-same-seed-identical", 64, gen_spec, |spec| {
        let a = spec.generate().map_err(|e| e.to_string())?;
        let b = spec.generate().map_err(|e| e.to_string())?;
        prop_assert!(a == b, "two eager generations differ for {spec:?}");
        // byte-identical through the JSON trace codec too
        prop_assert!(
            to_json(&a).to_string() == to_json(&b).to_string(),
            "trace JSON differs for {spec:?}"
        );
        Ok(())
    });
}

#[test]
fn eager_equals_incremental_pull() {
    prop::check("workload-eager-vs-pull", 64, gen_spec, |spec| {
        let eager = spec.generate().map_err(|e| e.to_string())?;
        let mut src = spec.source().map_err(|e| e.to_string())?;
        let mut pulled = Vec::new();
        while let Some(r) = src.next_request() {
            pulled.push(r);
        }
        prop_assert!(
            eager == pulled,
            "eager and incremental streams diverge for {}",
            spec.traffic.kind_name()
        );
        prop_assert!(
            src.next_request().is_none(),
            "source must stay exhausted after the stream ends"
        );
        Ok(())
    });
}

#[test]
fn streams_are_monotone_and_well_formed() {
    prop::check("workload-monotone-wellformed", 64, gen_spec, |spec| {
        let reqs = spec.generate().map_err(|e| e.to_string())?;
        prop_assert!(
            reqs.len() == spec.num_requests,
            "expected {} requests, got {}",
            spec.num_requests,
            reqs.len()
        );
        let tenant_count = spec.tenants.len().max(1) as u32;
        for w in reqs.windows(2) {
            prop_assert!(
                w[0].arrival <= w[1].arrival,
                "arrivals not monotone: {} then {}",
                w[0].arrival,
                w[1].arrival
            );
        }
        for r in &reqs {
            prop_assert!(r.prompt_tokens > 0, "empty prompt in {r:?}");
            prop_assert!(r.output_tokens > 0, "empty output in {r:?}");
            prop_assert!(
                r.shared_prefix < r.prompt_tokens,
                "shared prefix must leave at least one computed token: {r:?}"
            );
            prop_assert!(
                r.tenant < tenant_count,
                "tenant {} out of range {tenant_count} in {r:?}",
                r.tenant
            );
        }
        Ok(())
    });
}

#[test]
fn different_seeds_differ() {
    // guards the properties above against passing vacuously; 16+ requests
    // so two seeds cannot collide on every sampled length by chance
    let gen = |rng: &mut Rng| {
        let mut s = gen_spec(rng);
        s.num_requests = 16 + rng.below(40) as usize;
        s
    };
    prop::check("workload-seed-sensitivity", 32, gen, |spec| {
        // even `burst` differs across seeds via its sampled lengths
        let a = spec.generate().map_err(|e| e.to_string())?;
        let mut reseeded = spec.clone();
        reseeded.seed ^= 0x9E3779B9;
        let b = reseeded.generate().map_err(|e| e.to_string())?;
        prop_assert!(a != b, "seed change left the stream identical: {spec:?}");
        Ok(())
    });
}
