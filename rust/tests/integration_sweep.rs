//! Determinism under parallelism: the same seed + config must produce
//! byte-identical report JSON standalone, and through the sweep engine at
//! 1, 2, and 8 worker threads (ISSUE: the acceptance contract of the
//! Send-safe core).

use llmservingsim::config::{PerfBackend, SimConfig};
use llmservingsim::coordinator::run_config;
use llmservingsim::sweep::{
    run_manifest, run_sweep, summarize, sweep_json, ExperimentManifest,
    SweepSpec,
};

/// A 2 presets x 2 rates x 2 routers grid (8 points), small enough for CI.
fn grid_spec() -> SweepSpec {
    let mut spec = SweepSpec {
        num_requests: 15,
        quick: true,
        seed: 0xDE75,
        ..SweepSpec::default()
    };
    spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
    spec.axes.rates = vec![10.0, 40.0];
    spec.axes.routers = vec!["round-robin".into(), "least-outstanding".into()];
    spec
}

fn report_jsons(cfgs: &[SimConfig], threads: usize) -> Vec<(String, String)> {
    run_sweep(cfgs, threads)
        .unwrap()
        .points
        .into_iter()
        .map(|p| (p.name, p.report.to_json().to_string()))
        .collect()
}

#[test]
fn standalone_runs_are_byte_identical() {
    for cfg in grid_spec().expand().unwrap() {
        let (a, _) = run_config(cfg.clone()).unwrap();
        let (b, _) = run_config(cfg.clone()).unwrap();
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "config '{}' not reproducible standalone",
            cfg.name
        );
    }
}

#[test]
fn sweep_matches_standalone_at_1_2_and_8_threads() {
    let cfgs = grid_spec().expand().unwrap();
    assert_eq!(cfgs.len(), 8, "the CI grid is 2x2x2");

    // Standalone reference, one config at a time on the main thread.
    let reference: Vec<(String, String)> = cfgs
        .iter()
        .map(|cfg| {
            let (report, _) = run_config(cfg.clone()).unwrap();
            (cfg.name.clone(), report.to_json().to_string())
        })
        .collect();

    for threads in [1, 2, 8] {
        let swept = report_jsons(&cfgs, threads);
        assert_eq!(swept.len(), reference.len());
        for ((ref_name, ref_json), (name, json)) in reference.iter().zip(&swept) {
            assert_eq!(ref_name, name, "point order must follow expansion");
            assert_eq!(
                ref_json, json,
                "config '{name}' diverged from standalone at {threads} threads"
            );
        }
    }
}

#[test]
fn different_seeds_actually_change_reports() {
    // Guards against the determinism tests passing vacuously (e.g. the
    // seed being ignored entirely).
    let mut a = grid_spec();
    a.axes.presets.truncate(1);
    a.axes.rates.truncate(1);
    a.axes.routers.truncate(1);
    let mut b = a.clone();
    b.seed = a.seed + 1;
    let ra = report_jsons(&a.expand().unwrap(), 1);
    let rb = report_jsons(&b.expand().unwrap(), 1);
    assert_ne!(ra[0].1, rb[0].1, "seed must influence the workload");
}

#[test]
fn sweep_summary_and_json_cover_the_grid() {
    let cfgs = grid_spec().expand().unwrap();
    let outcome = run_sweep(&cfgs, 4).unwrap();
    for p in &outcome.points {
        assert_eq!(
            p.report.num_finished, 15,
            "point '{}' dropped requests",
            p.name
        );
    }
    let baseline = "S(D)|rate=10|router=round-robin";
    let summary = summarize(&outcome, Some(baseline)).unwrap();
    assert_eq!(summary.baseline, baseline);
    assert_eq!(summary.deltas.len(), cfgs.len() - 1);
    let v = sweep_json(&outcome, &summary);
    assert_eq!(v.get("points").as_arr().unwrap().len(), cfgs.len());
    assert_eq!(
        v.get("summary").get("baseline").as_str(),
        Some(baseline),
        "summary JSON must carry the baseline"
    );
}

#[test]
fn manifest_r1_reproduces_the_plain_sweep_bytes() {
    // No-regression gate for the manifest path (ISSUE 9): with R=1 the
    // aggregate's `points` and `summary` sections must be byte-identical
    // to what the pre-manifest sweep pipeline emits for the same spec.
    let mut spec = grid_spec();
    spec.baseline = Some("S(D)|rate=10|router=round-robin".into());

    let cfgs = spec.expand().unwrap();
    let outcome = run_sweep(&cfgs, 4).unwrap();
    let summary = summarize(&outcome, spec.baseline.as_deref()).unwrap();
    let plain = sweep_json(&outcome, &summary);

    let aggregate = run_manifest(&ExperimentManifest::new(spec), 4).unwrap();
    assert_eq!(
        aggregate.get("points").to_string(),
        plain.get("points").to_string(),
        "R=1 manifest points diverged from the classic sweep"
    );
    assert_eq!(
        aggregate.get("summary").to_string(),
        plain.get("summary").to_string(),
        "R=1 manifest summary diverged from the classic sweep"
    );
    assert!(
        aggregate.get("replication").is_null(),
        "R=1 aggregates must not carry a replication key"
    );
}

#[test]
fn workloads_axis_all_sources_byte_identical_at_any_worker_count() {
    // The acceptance contract of the workload engine: `--workloads all`
    // enumerates every registered traffic source, and each grid point's
    // report is byte-identical at 1 and 8 workers (and to a standalone
    // run).
    let registry = llmservingsim::policy::snapshot();
    let mut spec = SweepSpec {
        num_requests: 12,
        quick: true,
        seed: 0xB0B5,
        ..SweepSpec::default()
    };
    spec.axes = spec.axes.with_all_workloads(&registry);
    let cfgs = spec.expand().unwrap();
    assert_eq!(
        cfgs.len(),
        registry.traffic_names().len(),
        "every registered traffic source must become a grid point"
    );
    for name in ["poisson", "uniform", "burst", "mmpp", "diurnal", "sessions"] {
        assert!(
            cfgs.iter().any(|c| c.name.ends_with(&format!("wl={name}"))),
            "built-in '{name}' missing from the grid"
        );
    }

    let reference: Vec<(String, String)> = cfgs
        .iter()
        .map(|cfg| {
            let (report, _) = run_config(cfg.clone()).unwrap();
            (cfg.name.clone(), report.to_json().to_string())
        })
        .collect();
    for threads in [1, 8] {
        let swept = report_jsons(&cfgs, threads);
        assert_eq!(swept, reference, "workload sweep diverged at {threads} threads");
    }
    // every source actually finished its requests
    for (name, json) in &reference {
        let v = llmservingsim::util::json::parse(json).unwrap();
        assert_eq!(
            v.get("num_finished").as_i64(),
            Some(12),
            "point '{name}' dropped requests"
        );
    }
}

#[test]
fn eviction_and_backend_axes_expand() {
    // A second grid shape touching the other axes: prefix-cache preset x
    // eviction policy x perf backend.
    let mut spec = SweepSpec {
        num_requests: 10,
        quick: true,
        ..SweepSpec::default()
    };
    spec.axes.presets = vec!["S(D)+PC".into()];
    spec.axes.evictions = vec!["lru".into(), "lfu".into()];
    spec.axes.backends = vec![PerfBackend::Analytical, PerfBackend::CycleReplay];
    let cfgs = spec.expand().unwrap();
    assert_eq!(cfgs.len(), 4);
    let outcome = run_sweep(&cfgs, 2).unwrap();
    assert_eq!(outcome.points.len(), 4);
    for p in &outcome.points {
        assert!(p.report.num_finished > 0, "point '{}' finished nothing", p.name);
    }
}
