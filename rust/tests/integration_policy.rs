//! Policy-plugin layer integration (ISSUE 2 acceptance): registry
//! round-trip over every built-in, custom policies added in THIS single
//! file — zero edits to `config/`, `instance/`, or `memory/` internals —
//! reachable both via the registry (by name, sweepable) and via
//! `SimulationBuilder` injection, with sweep determinism preserved at 1
//! and 8 workers.


use llmservingsim::config::{presets, SimConfig};
use llmservingsim::coordinator::Simulation;
use llmservingsim::instance::SeqMap;
use llmservingsim::policy::{
    self, CacheLeaf, EvictionPolicy, SchedulePolicy,
};
use llmservingsim::router::{InstanceView, RoutePolicy};
use llmservingsim::sim::Nanos;
use llmservingsim::sweep::{run_sweep, SweepSpec};
use llmservingsim::workload::{LengthDist, Request};

// ---------------------------------------------------------------------------
// Custom policies: one file, no core edits.
// ---------------------------------------------------------------------------

/// Longest prompt first — inverse of the built-in SJF.
struct LongestFirst;

impl SchedulePolicy for LongestFirst {
    fn name(&self) -> &str {
        "longest-first"
    }
    fn order(&mut self, wait: &mut [u64], seqs: &SeqMap, _now: Nanos) {
        wait.sort_by_key(|id| {
            let s = &seqs[id];
            (std::cmp::Reverse(s.req.prompt_tokens), s.req.id)
        });
    }
}

/// Evict the smallest leaf first — inverse of the built-in `largest`.
struct SmallestFirst;

impl EvictionPolicy for SmallestFirst {
    fn name(&self) -> &str {
        "smallest-first"
    }
    fn pick(&mut self, leaves: &[CacheLeaf]) -> Option<usize> {
        leaves.iter().min_by_key(|l| (l.tokens, l.id)).map(|l| l.id)
    }
}

/// Route to the highest instance id that is a candidate.
struct HighestId;

impl RoutePolicy for HighestId {
    fn choose(&mut self, _req: &Request, candidates: &[InstanceView]) -> usize {
        candidates.iter().map(|v| v.id).max().unwrap()
    }
    fn name(&self) -> &str {
        "highest-id"
    }
}

fn register_customs() {
    policy::register_sched_policy("longest-first", || Box::new(LongestFirst));
    policy::register_evict_policy("smallest-first", || Box::new(SmallestFirst));
    policy::register_route_policy("highest-id", || Box::new(HighestId));
}

fn small(mut cfg: SimConfig, n: usize) -> SimConfig {
    cfg.workload.num_requests = n;
    cfg.workload.lengths = LengthDist::short();
    cfg
}

// ---------------------------------------------------------------------------
// Registry round-trip
// ---------------------------------------------------------------------------

#[test]
fn every_builtin_name_resolves() {
    let reg = policy::snapshot();
    for name in ["round-robin", "least-outstanding", "least-kv", "prefix-aware"] {
        assert_eq!(reg.make_route(name).unwrap().name(), name);
    }
    // the wrapper documents its fallback in the reported name
    assert_eq!(
        reg.make_route("session-affinity").unwrap().name(),
        "session-affinity(least-outstanding)"
    );
    for name in ["fcfs", "sjf", "priority"] {
        assert_eq!(reg.make_sched(name).unwrap().name(), name);
    }
    for name in ["lru", "lfu", "largest"] {
        assert_eq!(reg.make_evict(name).unwrap().name(), name);
    }
}

#[test]
fn unknown_names_error_with_candidate_list() {
    let reg = policy::snapshot();
    let e = reg.make_route("coin-flip").unwrap_err().to_string();
    assert!(e.contains("coin-flip"), "{e}");
    for candidate in ["round-robin", "least-outstanding", "prefix-aware"] {
        assert!(e.contains(candidate), "'{e}' should list '{candidate}'");
    }
    let e = reg.make_sched("lifo").unwrap_err().to_string();
    assert!(e.contains("fcfs") && e.contains("sjf") && e.contains("priority"));
    let e = reg.make_evict("fifo").unwrap_err().to_string();
    assert!(e.contains("lru") && e.contains("lfu") && e.contains("largest"));
}

// ---------------------------------------------------------------------------
// Custom policies end-to-end
// ---------------------------------------------------------------------------

#[test]
fn registered_customs_resolve_from_config_names() {
    register_customs();
    let mut cfg = small(
        presets::with_prefix_cache(
            presets::multi_dense("tiny-dense", "rtx3090"),
            llmservingsim::config::CacheScope::PerInstance,
        ),
        20,
    );
    cfg.router = "highest-id".to_string();
    for i in &mut cfg.instances {
        i.sched = "longest-first".to_string();
        i.prefix_cache.as_mut().unwrap().policy = "smallest-first".to_string();
    }
    let mut sim = Simulation::new(cfg).unwrap();
    assert_eq!(sim.router_policy_name(), "highest-id");
    assert_eq!(sim.instance(0).sched_name(), "longest-first");
    let report = sim.run();
    assert_eq!(report.num_finished, 20);
    // highest-id routes everything to the last instance
    assert!(report.utilization.get(&1).copied().unwrap_or(0.0) > 0.0);
    assert!(report.utilization.get(&0).copied().unwrap_or(0.0) == 0.0);
}

#[test]
fn builder_injection_needs_no_registration() {
    // The same custom policies, injected per-simulation: config keeps
    // built-in names, the builder overrides them.
    let cfg = small(
        presets::with_prefix_cache(
            presets::single_dense("tiny-dense", "rtx3090"),
            llmservingsim::config::CacheScope::PerInstance,
        ),
        15,
    );
    let mut sim = Simulation::builder(cfg)
        .with_route_policy(Box::new(HighestId))
        .with_sched_policy(|| Box::new(LongestFirst))
        .with_evict_policy(|| Box::new(SmallestFirst))
        .build()
        .unwrap();
    assert_eq!(sim.router_policy_name(), "highest-id");
    assert_eq!(sim.instance(0).sched_name(), "longest-first");
    let report = sim.run();
    assert_eq!(report.num_finished, 15);
}

#[test]
fn custom_and_builtin_sched_policies_differ_observably() {
    register_customs();
    let run = |sched: &str| {
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"), 30);
        // burst arrivals + tiny batch so admission order matters; constant
        // decode lengths make SJF provably optimal for mean TTFT here
        cfg.workload.traffic = llmservingsim::workload::Traffic::burst();
        cfg.workload.lengths.output_sigma = 0.0;
        for i in &mut cfg.instances {
            i.sched = sched.to_string();
            i.max_batch_seqs = 1;
        }
        let mut sim = Simulation::new(cfg).unwrap();
        sim.run()
    };
    let sjf = run("sjf");
    let ljf = run("longest-first");
    assert_eq!(sjf.num_finished, ljf.num_finished);
    assert!(
        sjf.ttft_ns.mean < ljf.ttft_ns.mean,
        "SJF must beat longest-first on mean TTFT ({} !< {})",
        sjf.ttft_ns.mean,
        ljf.ttft_ns.mean
    );
}

// ---------------------------------------------------------------------------
// Sweep integration + determinism
// ---------------------------------------------------------------------------

#[test]
fn sweep_enumerates_registered_customs_and_stays_deterministic() {
    register_customs();
    let registry = policy::snapshot();
    assert!(registry.sched_names().contains(&"longest-first".to_string()));
    assert!(registry.evict_names().contains(&"smallest-first".to_string()));

    // sched x evict grid mixing built-ins and customs on a prefix-cache
    // preset; byte-identical reports at 1 and 8 workers.
    let mut spec = SweepSpec {
        num_requests: 12,
        quick: true,
        seed: 0x5011C7,
        ..SweepSpec::default()
    };
    spec.axes.presets = vec!["S(D)+PC".into()];
    spec.axes.scheds = vec!["fcfs".into(), "longest-first".into()];
    spec.axes.evictions = vec!["lru".into(), "smallest-first".into()];
    let cfgs = spec.expand().unwrap();
    assert_eq!(cfgs.len(), 4);
    assert!(cfgs
        .iter()
        .any(|c| c.name == "S(D)+PC|sched=longest-first|evict=smallest-first"));

    let solo = run_sweep(&cfgs, 1).unwrap();
    let pool = run_sweep(&cfgs, 8).unwrap();
    assert_eq!(solo.points.len(), pool.points.len());
    for (a, b) in solo.points.iter().zip(&pool.points) {
        assert_eq!(a.name, b.name);
        assert_eq!(
            a.report.to_json().to_string(),
            b.report.to_json().to_string(),
            "point '{}' diverged across worker counts",
            a.name
        );
        assert!(a.report.num_finished > 0);
    }
}

#[test]
fn sweep_rejects_unregistered_policy_axis_values() {
    let mut spec = SweepSpec {
        num_requests: 5,
        quick: true,
        ..SweepSpec::default()
    };
    spec.axes.scheds = vec!["definitely-not-registered".into()];
    let e = spec.expand().unwrap_err().to_string();
    assert!(
        e.contains("definitely-not-registered") && e.contains("fcfs"),
        "{e}"
    );
}
