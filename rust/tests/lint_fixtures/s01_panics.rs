// Fixture: S01 — unjustified aborts in core library code. Never compiled.
pub fn pick(v: &[u32]) -> u32 {
    let first = v.first().unwrap();
    let envd: u32 = std::env::var("N").expect("N must be set").parse().unwrap();
    if *first > envd {
        panic!("out of range");
    }
    match *first {
        0 => unreachable!(),
        n => n,
    }
}
