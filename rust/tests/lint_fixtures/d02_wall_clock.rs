// Fixture: D02 — ambient clocks. Never compiled.
use std::time::{Instant, SystemTime};

pub fn stamp() -> u128 {
    let t0 = Instant::now();
    let wall = SystemTime::now();
    let _ = wall;
    t0.elapsed().as_nanos()
}
