// Fixture: well-formed inline allows (same line, and in the comment block
// directly above — including a wrapped two-line comment) suppress every
// rule. Never compiled.
use std::collections::HashMap; // simlint: allow(D01) — fixture exercising same-line suppression

pub struct Table {
    // simlint: allow(D01) — fixture exercising the comment-block-above
    // form, with the reason wrapping onto a second line
    pub by_id: HashMap<u64, u32>,
}

pub fn pick(v: &[u32]) -> u32 {
    // simlint: allow(S01) — fixture invariant: callers never pass an empty slice
    *v.first().unwrap()
}
