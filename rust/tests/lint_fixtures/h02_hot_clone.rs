// Fixture: H02 — cloning batch-state (`Request`) on the hot path. The same
// clone in a function no hot root reaches is fine. Never compiled.
pub struct Request {
    pub id: u64,
}

pub struct Simulation {
    req: Request,
}

impl Simulation {
    pub fn handle_event(&mut self) {
        let copy = self.req.clone();
        let _ = copy;
    }
}

pub fn snapshot(r: &Request) -> Request {
    r.clone()
}
