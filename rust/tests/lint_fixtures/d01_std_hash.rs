// Fixture: D01 — std HashMap/HashSet in a core module. Scanned with a
// virtual core-module path; never compiled.
use std::collections::HashMap;
use std::collections::HashSet;

pub struct Table {
    pub by_id: HashMap<u64, u32>,
    pub live: HashSet<u64>,
}
