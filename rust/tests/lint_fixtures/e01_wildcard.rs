// Fixture: E01 — wildcard arm in a match over a core enum (silent
// fall-through when a variant is added), a guarded wildcard (exempt:
// guards never satisfy exhaustiveness, so the compiler still forces
// coverage), and a non-core match (out of scope). Never compiled.
pub enum Event {
    Arrival,
    StepComplete,
    ControllerTick,
}

pub fn dispatch(e: &Event) -> u32 {
    match e {
        Event::Arrival => 1,
        _ => 0,
    }
}

pub fn guarded(e: &Event, busy: bool) -> u32 {
    match e {
        Event::Arrival => 1,
        _ if busy => 2,
        Event::StepComplete => 3,
        Event::ControllerTick => 4,
    }
}

pub fn noncore(n: u32) -> u32 {
    match n {
        0 => 0,
        _ => 1,
    }
}
