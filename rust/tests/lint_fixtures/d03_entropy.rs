// Fixture: D03 — entropy-seeded randomness. Never compiled.
use std::collections::hash_map::RandomState;

pub fn hasher() -> RandomState {
    RandomState::new()
}
