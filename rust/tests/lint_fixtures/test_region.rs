// Fixture: everything inside a `#[cfg(test)]` item is exempt from the
// rules; code after the test module is not. Never compiled.
pub fn live(x: Option<u32>) -> u32 {
    x.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn scratch() {
        let mut m: HashMap<u32, u32> = HashMap::new();
        m.insert(1, 2);
        assert_eq!(m.get(&1).copied().unwrap(), 2);
    }
}

pub fn also_live(x: Option<u32>) -> u32 {
    x.unwrap()
}
