// Fixture: H01 — allocation in a function reachable from a hot root, with
// the three escape hatches demonstrated: an inline allow, a `cold` marker,
// and plain unreachability. Never compiled.
pub struct Simulation;

impl Simulation {
    pub fn handle_event(&mut self) {
        self.dispatch_one();
        self.cold_refresh();
    }

    fn dispatch_one(&mut self) {
        let mut pending: Vec<u64> = Vec::new();
        pending.push(1);
        // simlint: allow(H01) — fixture exercising inline suppression
        let label = format!("step");
        let _ = label;
    }

    // simlint: cold — fixture: control-plane refresh, allocates by design
    fn cold_refresh(&mut self) {
        let _scratch: Vec<u64> = Vec::new();
    }
}

pub fn offline_report() -> String {
    String::from("never reachable from a hot root")
}
