// Fixture: P01 — two route registrations; the test supplies a README that
// mentions only one of them, so the other must be flagged as undocumented.
// Never compiled.
pub fn install(r: &mut Registry) {
    r.register_route("fixture-documented", || Dummy);
    r.register_route("fixture-ghost", || Dummy);
}
