// Fixture: D04 — iteration over a hash-based container in a core module,
// including a method chain split across lines (the case a line-based
// scanner provably misses). Never compiled.
use crate::util::fxhash::FxHashMap;

pub struct Metrics {
    busy: FxHashMap<usize, u64>,
}

impl Metrics {
    pub fn report(&self) -> Vec<(usize, u64)> {
        self.busy
            .iter()
            .map(|(&k, &v)| (k, v))
            .collect()
    }

    pub fn total(&self) -> u64 {
        let mut sum = 0;
        for (_, v) in &self.busy {
            sum += v;
        }
        sum
    }
}
