// Fixture: malformed or reasonless allow directives must NOT suppress.
// Never compiled.
use std::collections::HashMap; // simlint: allow(D01)

pub struct Table {
    // simlint: allow(D99) — unknown rule id
    pub by_id: HashMap<u64, u32>,
}

pub fn pick(v: &[u32]) -> u32 {
    // simlint: allow S01 — missing parentheses
    *v.first().unwrap()
}
