//! Cross-module integration tests over the public API: full simulations
//! through config -> coordinator -> instances -> memory/network/perf ->
//! metrics, checking system-level invariants.

use llmservingsim::config::{
    presets, CacheScope, GateKind, KvTransferPolicy, OffloadPolicy, PerfBackend,
    SimConfig,
};
use llmservingsim::coordinator::{run_config, Simulation};
use llmservingsim::workload::{LengthDist, Traffic, WorkloadSpec};

fn small(mut cfg: SimConfig, n: usize) -> SimConfig {
    cfg.workload.num_requests = n;
    cfg.workload.lengths = LengthDist::short();
    cfg
}

#[test]
fn token_conservation_across_all_presets() {
    // every finished request must emit exactly output_tokens tokens
    for cfg in presets::fig3_configs("tiny-dense", "tiny-moe", "rtx3090") {
        let cfg = small(cfg, 25);
        let name = cfg.name.clone();
        let expected: u64 = cfg
            .workload
            .generate()
            .unwrap()
            .iter()
            .map(|r| r.output_tokens)
            .sum();
        let (report, _) = run_config(cfg).unwrap();
        assert_eq!(report.num_finished, 25, "{name}");
        assert_eq!(report.generated_tokens, expected, "{name}");
    }
}

#[test]
fn makespan_bounded_by_arrivals_plus_service() {
    let cfg = small(presets::single_dense("tiny-dense", "rtx3090"), 50);
    let last_arrival = cfg.workload.generate().unwrap().last().unwrap().arrival;
    let (report, _) = run_config(cfg).unwrap();
    assert!(report.makespan >= last_arrival);
    // sanity ceiling: tiny model on GPU-like perf shouldn't take > 1000 s
    assert!(report.makespan < 1_000_000_000_000);
}

#[test]
fn ttft_not_before_prompt_could_finish() {
    let cfg = small(presets::single_dense("tiny-dense", "rtx3090"), 30);
    let (report, _) = run_config(cfg).unwrap();
    assert!(report.ttft_ns.min > 0.0);
    assert!(report.itl_ns.min > 0.0);
}

#[test]
fn seeds_change_results_configs_stay_deterministic() {
    let base = small(presets::multi_dense("tiny-dense", "rtx3090"), 40);
    let (a, _) = run_config(base.clone()).unwrap();
    let mut reseeded = base.clone();
    reseeded.workload.seed ^= 0xDEAD;
    let (b, _) = run_config(reseeded).unwrap();
    assert_ne!(a.makespan, b.makespan, "different workload seed must differ");
    let (c, _) = run_config(base).unwrap();
    assert_eq!(a.makespan, c.makespan, "same config must be bit-identical");
}

#[test]
fn higher_rate_does_not_reduce_throughput() {
    let mk = |rate: f64| {
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"), 60);
        cfg.workload.traffic = Traffic::poisson(rate);
        run_config(cfg).unwrap().0
    };
    let slow = mk(5.0);
    let fast = mk(50.0);
    assert!(fast.throughput_tps > slow.throughput_tps * 0.9);
}

#[test]
fn tp_instance_serves_faster_under_load() {
    let mk = |tp: usize| {
        let mut cfg = small(presets::single_dense("llama3.1-8b", "rtx3090"), 30);
        cfg.instances[0].devices = tp;
        cfg.instances[0].tp = tp;
        cfg.workload.traffic = Traffic::burst();
        run_config(cfg).unwrap().0
    };
    let tp1 = mk(1);
    let tp2 = mk(2);
    assert!(
        tp2.makespan < tp1.makespan,
        "tp2 {} !< tp1 {}",
        tp2.makespan,
        tp1.makespan
    );
}

#[test]
fn pd_vs_colocated_same_token_totals() {
    let co = small(presets::multi_dense("tiny-dense", "rtx3090"), 30);
    let pd = small(presets::pd_dense("tiny-dense", "rtx3090"), 30);
    let (a, _) = run_config(co).unwrap();
    let (b, _) = run_config(pd).unwrap();
    assert_eq!(a.generated_tokens, b.generated_tokens);
}

#[test]
fn moe_offload_policies_all_complete() {
    for policy in [
        OffloadPolicy::None,
        OffloadPolicy::OnDemand,
        OffloadPolicy::Prefetch,
        OffloadPolicy::Pim,
    ] {
        let mut cfg = small(presets::single_moe("tiny-moe", "rtx3090"), 20);
        cfg.instances[0].offload = policy;
        cfg.instances[0].gate = GateKind::Zipf { s: 1.0 };
        let (r, _) = run_config(cfg).unwrap();
        assert_eq!(r.num_finished, 20, "offload {policy:?}");
    }
}

#[test]
fn ep_degrees_complete_and_price_alltoall() {
    for ep in [1usize, 2, 4, 8] {
        let mut cfg = small(presets::single_moe("tiny-moe", "rtx3090"), 15);
        cfg.instances[0].devices = ep.max(1);
        cfg.instances[0].tp = ep.max(1);
        cfg.instances[0].ep = ep;
        let (r, _) = run_config(cfg).unwrap();
        assert_eq!(r.num_finished, 15, "ep={ep}");
    }
}

#[test]
fn all_router_policies_complete_on_mixed_fleet() {
    // enumerate the registry instead of a hard-coded list: any policy a
    // user registers is exercised the same way
    for policy in llmservingsim::policy::snapshot().route_names() {
        let mut cfg = small(presets::multi_dense("tiny-dense", "rtx3090"), 25);
        cfg.router = policy.clone();
        cfg.workload.sessions = 4;
        cfg.workload.shared_prefix = 16;
        let (r, _) = run_config(cfg).unwrap();
        assert_eq!(r.num_finished, 25, "router {policy}");
    }
}

#[test]
fn kv_transfer_policies_differ_in_bytes_not_tokens() {
    let mk = |p: KvTransferPolicy| {
        let mut cfg = small(presets::pd_dense("tiny-dense", "rtx3090"), 25);
        for i in &mut cfg.instances {
            i.kv_transfer = p;
        }
        let mut sim = Simulation::new(cfg).unwrap();
        let r = sim.run();
        (r.generated_tokens, sim.inter_instance_bytes())
    };
    let (tok_b, bytes_b) = mk(KvTransferPolicy::Blocking);
    let (tok_l, bytes_l) = mk(KvTransferPolicy::Layered);
    assert_eq!(tok_b, tok_l);
    assert!(bytes_l < bytes_b);
}

#[test]
fn memory_pressure_still_finishes_all_requests() {
    let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"), 40);
    // KV pool fits any single request but not the burst => heavy preemption
    cfg.instances[0].mem_capacity = Some(
        llmservingsim::model::ModelSpec::tiny_dense().param_bytes() + (4 << 20),
    );
    cfg.workload.traffic = Traffic::burst();
    let (r, _) = run_config(cfg).unwrap();
    assert_eq!(r.num_finished, 40);
}

#[test]
fn prefix_cache_hit_rate_increases_with_sharing() {
    let mk = |sessions: usize| {
        let mut cfg = small(
            presets::with_prefix_cache(
                presets::single_dense("tiny-dense", "rtx3090"),
                CacheScope::PerInstance,
            ),
            60,
        );
        cfg.workload = WorkloadSpec {
            num_requests: 60,
            traffic: Traffic::poisson(10.0),
            lengths: LengthDist::short(),
            sessions,
            shared_prefix: 48,
            tenants: vec![],
            seed: 7,
        };
        let (_, s) = run_config(cfg).unwrap();
        s.cache_stats[0].hit_rate()
    };
    let few_sessions = mk(2); // heavy sharing
    let many_sessions = mk(50); // light sharing
    assert!(
        few_sessions > many_sessions,
        "2 sessions {few_sessions} !> 50 sessions {many_sessions}"
    );
}

#[test]
fn analytical_vs_cycle_backends_agree_on_tokens() {
    let mut a = small(presets::single_dense("tiny-dense", "rtx3090"), 10);
    a.perf = PerfBackend::Analytical;
    let mut c = a.clone();
    c.perf = PerfBackend::Cycle;
    let (ra, _) = run_config(a).unwrap();
    let (rc, _) = run_config(c).unwrap();
    assert_eq!(ra.generated_tokens, rc.generated_tokens);
    // but timing differs (different hardware models)
    assert_ne!(ra.makespan, rc.makespan);
}

#[test]
fn af_disaggregation_changes_attention_pricing() {
    let mut plain = small(presets::single_dense("llama3.1-8b", "rtx3090"), 10);
    plain.workload.traffic = Traffic::burst();
    let mut af = plain.clone();
    af.instances[0].af_disagg = true;
    let (p, _) = run_config(plain).unwrap();
    let (a, _) = run_config(af).unwrap();
    assert_eq!(p.generated_tokens, a.generated_tokens);
    assert_ne!(p.makespan, a.makespan);
}
