//! Golden-report snapshot (ISSUE 3 satellite): the `sharegpt_100` workload
//! on the `rtx3090` preset must reproduce its checked-in report JSON
//! byte-for-byte. Any perf-model, scheduler, event-ordering, or metrics
//! change that shifts a single nanosecond fails this test loudly instead
//! of drifting silently.
//!
//! Workflow:
//! * fixture present  → assert byte equality; on mismatch, the actual
//!   report is written next to the target dir
//!   (`target/golden_report_actual.json` — CI uploads it as an artifact)
//!   and the test panics with both paths.
//! * fixture absent   → it is generated and written (self-blessing first
//!   run; commit the file). Refresh intentionally with
//!   `UPDATE_GOLDEN=1 cargo test -q --test golden_report`.

use std::path::PathBuf;

use llmservingsim::config::presets;
use llmservingsim::coordinator::run_config;

fn manifest_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

/// The pinned scenario: the paper's §III-A evaluation workload (100
/// ShareGPT-like requests, Poisson 10 req/s) on a single RTX3090 instance.
fn golden_config() -> llmservingsim::config::SimConfig {
    let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
    cfg.workload = llmservingsim::workload::WorkloadSpec::sharegpt_100(10.0);
    cfg
}

#[test]
fn sharegpt_100_rtx3090_matches_golden_report() {
    let fixture = manifest_path("tests/fixtures/golden_sharegpt100_rtx3090.json");
    let (report, _) = run_config(golden_config()).unwrap();
    let actual = report.to_json().to_string();

    // Plain compare mode (CI once the fixture is committed): a missing
    // fixture is a hard failure, never a silent self-bless.
    if std::env::var_os("GOLDEN_STRICT").is_some() && !fixture.exists() {
        panic!(
            "GOLDEN_STRICT is set but the golden fixture is not committed at \
             {} — run `cargo test -q --test golden_report` once without \
             GOLDEN_STRICT and commit the file it writes",
            fixture.display()
        );
    }

    let update = std::env::var_os("UPDATE_GOLDEN").is_some();
    if update || !fixture.exists() {
        std::fs::create_dir_all(fixture.parent().unwrap()).unwrap();
        std::fs::write(&fixture, &actual).unwrap();
        eprintln!(
            "golden fixture {} at {} — commit it so future runs pin the report",
            if update { "refreshed" } else { "blessed" },
            fixture.display()
        );
        return;
    }

    let expected = std::fs::read_to_string(&fixture).unwrap();
    if actual != expected {
        let out = manifest_path("target/golden_report_actual.json");
        std::fs::create_dir_all(out.parent().unwrap()).unwrap();
        std::fs::write(&out, &actual).unwrap();
        panic!(
            "golden report mismatch for sharegpt_100/rtx3090:\n  expected: {}\n  \
             actual written to: {}\nIf the change is intentional, refresh with \
             UPDATE_GOLDEN=1 cargo test -q --test golden_report",
            fixture.display(),
            out.display()
        );
    }
}

#[test]
fn golden_scenario_is_reproducible_in_process() {
    // the snapshot is only meaningful if the scenario is deterministic
    let (a, _) = run_config(golden_config()).unwrap();
    let (b, _) = run_config(golden_config()).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
}
