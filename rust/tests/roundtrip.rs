//! Round-trip tests for the two JSON codecs users feed external data
//! through (ISSUE 3 satellite): `workload::to_json/from_json` (request
//! traces, now carrying `tenant`/`slo` fields) and
//! `TraceDb::to_json/from_json` (profiled latency tables) — including
//! rejection of malformed input with actionable errors.

use llmservingsim::model::OpKind;
use llmservingsim::perf::trace::TraceDb;
use llmservingsim::util::json;
use llmservingsim::workload::{
    self, Request, SloClass, TenantSpec, Traffic, WorkloadSpec,
};

// ---------------------------------------------------------------------------
// workload trace codec
// ---------------------------------------------------------------------------

fn tenant_spec() -> WorkloadSpec {
    let mut spec = WorkloadSpec::sharegpt_100(20.0);
    spec.num_requests = 50;
    spec.tenants = TenantSpec::mix(3);
    spec.sessions = 4;
    spec.shared_prefix = 24;
    spec
}

#[test]
fn workload_roundtrip_preserves_tenant_and_slo() {
    let reqs = tenant_spec().generate().unwrap();
    assert!(reqs.iter().any(|r| r.tenant > 0), "mix must use >1 tenant");
    assert!(
        reqs.iter().any(|r| r.slo_class == SloClass::Batch),
        "mix must use both classes"
    );
    let parsed = workload::from_json(&workload::to_json(&reqs)).unwrap();
    assert_eq!(reqs, parsed);
    // and the serialized form is stable across serializations
    assert_eq!(
        workload::to_json(&reqs).to_string(),
        workload::to_json(&parsed).to_string()
    );
}

#[test]
fn workload_roundtrip_through_replay_traffic() {
    let dir = std::env::temp_dir().join("llmss_roundtrip_replay");
    let path = dir.join("trace.json");
    let reqs = tenant_spec().generate().unwrap();
    workload::save_trace(&path, &reqs).unwrap();

    // a replay workload streams exactly the saved trace
    let mut spec = tenant_spec();
    spec.traffic = Traffic::Replay {
        path: path.to_string_lossy().into_owned(),
    };
    let replayed = spec.generate().unwrap();
    assert_eq!(reqs, replayed);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn workload_missing_tenant_fields_default() {
    // pre-multi-tenant traces (no tenant/slo keys) still load
    let v = json::parse(
        r#"[{"id": 3, "arrival_ns": 9, "prompt_tokens": 5, "output_tokens": 2}]"#,
    )
    .unwrap();
    let reqs = workload::from_json(&v).unwrap();
    assert_eq!(reqs[0].tenant, 0);
    assert_eq!(reqs[0].slo_class, SloClass::Interactive);
    assert_eq!(reqs[0].session, 0, "session defaults to the index");
}

#[test]
fn workload_rejects_malformed() {
    // not an array
    assert!(workload::from_json(&json::parse(r#"{"id": 1}"#).unwrap()).is_err());
    // missing required numeric field
    let e = workload::from_json(
        &json::parse(r#"[{"id": 1, "arrival_ns": 5, "prompt_tokens": 4}]"#).unwrap(),
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("output_tokens"), "{e}");
    // wrong type for a required field
    assert!(workload::from_json(
        &json::parse(
            r#"[{"id": "one", "arrival_ns": 5, "prompt_tokens": 4, "output_tokens": 2}]"#
        )
        .unwrap()
    )
    .is_err());
    // malformed optional fields are errors, not silent defaults
    let e = workload::from_json(
        &json::parse(
            r#"[{"id": 1, "arrival_ns": 5, "prompt_tokens": 4, "output_tokens": 2,
                 "slo": "platinum"}]"#,
        )
        .unwrap(),
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("platinum") && e.contains("interactive"), "{e}");
    assert!(workload::from_json(
        &json::parse(
            r#"[{"id": 1, "arrival_ns": 5, "prompt_tokens": 4, "output_tokens": 2,
                 "tenant": -3}]"#
        )
        .unwrap()
    )
    .is_err());
    // out-of-u32-range tenant is rejected, not silently truncated
    assert!(workload::from_json(
        &json::parse(
            r#"[{"id": 1, "arrival_ns": 5, "prompt_tokens": 4, "output_tokens": 2,
                 "tenant": 4294967297}]"#
        )
        .unwrap()
    )
    .is_err());
}

#[test]
fn workload_from_json_sorts_by_arrival() {
    let v = json::parse(
        r#"[{"id": 0, "arrival_ns": 100, "prompt_tokens": 4, "output_tokens": 1},
            {"id": 1, "arrival_ns": 5,   "prompt_tokens": 4, "output_tokens": 1}]"#,
    )
    .unwrap();
    let reqs = workload::from_json(&v).unwrap();
    assert_eq!(reqs[0].id, 1, "trace must come back arrival-sorted");
    assert!(reqs.windows(2).all(|w| w[0].arrival <= w[1].arrival));
}

#[test]
fn request_default_is_single_tenant_interactive() {
    let r = Request::default();
    assert_eq!(r.tenant, 0);
    assert_eq!(r.slo_class, SloClass::Interactive);
}

// ---------------------------------------------------------------------------
// TraceDb codec
// ---------------------------------------------------------------------------

fn sample_db() -> TraceDb {
    let mut db = TraceDb::new("test-hw", "tiny-dense");
    db.add_tokens(OpKind::QkvProj, 64, 1_200);
    db.add_tokens(OpKind::QkvProj, 128, 2_300);
    db.add_tokens(OpKind::AttnPrefill, 64, 9_000);
    db.add_batch_ctx(OpKind::AttnDecode, 4, 256, 3_100);
    db.add_batch_ctx(OpKind::AttnDecode, 8, 512, 6_400);
    db
}

#[test]
fn trace_db_roundtrip() {
    let db = sample_db();
    let back = TraceDb::from_json(&db.to_json()).unwrap();
    assert_eq!(back.hardware, db.hardware);
    assert_eq!(back.model, db.model);
    // the parsed DB serializes to identical bytes
    assert_eq!(db.to_json().to_string(), back.to_json().to_string());
    assert_eq!(
        db.samples(OpKind::AttnDecode),
        back.samples(OpKind::AttnDecode),
        "decode grid lost in roundtrip"
    );
    assert_eq!(db.samples(OpKind::QkvProj), back.samples(OpKind::QkvProj));
    assert!(back.has(OpKind::AttnPrefill));
}

#[test]
fn trace_db_rejects_malformed() {
    // missing top-level fields
    let e = TraceDb::from_json(&json::parse(r#"{"model": "m"}"#).unwrap())
        .unwrap_err()
        .to_string();
    assert!(e.contains("hardware"), "{e}");
    assert!(TraceDb::from_json(
        &json::parse(r#"{"hardware": "h", "model": "m"}"#).unwrap()
    )
    .is_err());
    // unknown op kind
    let e = TraceDb::from_json(
        &json::parse(
            r#"{"hardware": "h", "model": "m",
                "ops": {"warp-drive": {"grid": "tokens", "points": []}}}"#,
        )
        .unwrap(),
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("warp-drive"), "{e}");
    // unknown grid kind
    assert!(TraceDb::from_json(
        &json::parse(
            r#"{"hardware": "h", "model": "m",
                "ops": {"qkv_proj": {"grid": "hypercube", "points": []}}}"#
        )
        .unwrap()
    )
    .is_err());
    // malformed point tuple
    assert!(TraceDb::from_json(
        &json::parse(
            r#"{"hardware": "h", "model": "m",
                "ops": {"qkv_proj": {"grid": "tokens", "points": [[64]]}}}"#
        )
        .unwrap()
    )
    .is_err());
}
