//! Model architecture specifications.
//!
//! [`ModelSpec`] mirrors `python/compile/model.py::ModelConfig` and adds the
//! analytical quantities the performance and memory models need: parameter
//! bytes, KV-cache bytes per token, and per-operator FLOP/byte counts.
//!
//! `tiny-*` presets are actually executed/profiled on the CPU PJRT backend;
//! the paper-scale presets (Llama3.1-8B, Phi-mini-MoE) drive the calibrated
//! analytical extension of the trace model (see `perf::trace`).

pub mod operators;

pub use operators::{OpKind, OpInvocation};

/// Bytes per element for the serving dtype (fp16/bf16 deployment style).
pub const DTYPE_BYTES: u64 = 2;

/// A transformer decoder architecture (dense or MoE).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub hidden: u64,
    pub heads: u64,
    /// KV heads (GQA); == heads for MHA.
    pub kv_heads: u64,
    /// Dense-FFN inner dimension (SwiGLU).
    pub ffn: u64,
    pub layers: u64,
    pub vocab: u64,
    /// Number of experts; 0 for dense models.
    pub experts: u64,
    /// Experts activated per token.
    pub top_k: u64,
    /// Per-expert FFN inner dimension.
    pub expert_ffn: u64,
    /// MoE layer stride: every `moe_every`-th layer is MoE (1 = all layers).
    pub moe_every: u64,
}

impl ModelSpec {
    pub fn head_dim(&self) -> u64 {
        self.hidden / self.heads
    }

    pub fn is_moe(&self) -> bool {
        self.experts > 0
    }

    /// Number of MoE layers (0 for dense).
    pub fn moe_layers(&self) -> u64 {
        if self.is_moe() {
            self.layers / self.moe_every
        } else {
            0
        }
    }

    /// Number of layers with a dense FFN.
    pub fn dense_ffn_layers(&self) -> u64 {
        self.layers - self.moe_layers()
    }

    /// KV-cache bytes per token across all layers (K + V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.layers * self.kv_heads * self.head_dim() * DTYPE_BYTES
    }

    /// Total parameter bytes (weights only).
    pub fn param_bytes(&self) -> u64 {
        let h = self.hidden;
        let kvh_dim = self.kv_heads * self.head_dim();
        let attn = h * h + 2 * h * kvh_dim + h * h; // wq, wk, wv, wo
        let dense_ffn = 3 * h * self.ffn;
        let moe_ffn = self.experts * 3 * h * self.expert_ffn + h * self.experts;
        let per_dense_layer = attn + dense_ffn + 2 * h;
        let per_moe_layer = attn + moe_ffn + 2 * h;
        let emb = 2 * self.vocab * h; // tied embeddings counted twice (in+out)
        let body = self.dense_ffn_layers() * per_dense_layer
            + self.moe_layers() * per_moe_layer;
        (body + emb) * DTYPE_BYTES
    }

    /// Bytes of expert weights for ONE expert of ONE layer.
    pub fn expert_bytes(&self) -> u64 {
        3 * self.hidden * self.expert_ffn * DTYPE_BYTES
    }

    /// FLOPs for one forward pass over `tokens` tokens of ONE layer,
    /// attending to `ctx` total context tokens (weights-only GEMM count;
    /// used by the roofline model).
    pub fn layer_flops(&self, tokens: u64, ctx: u64, moe_layer: bool) -> u64 {
        let h = self.hidden;
        let d = self.head_dim();
        let kvh_dim = self.kv_heads * d;
        let qkv = 2 * tokens * h * (h + 2 * kvh_dim);
        let attn = 2 * tokens * ctx * self.heads * d * 2; // QK^T + PV
        let proj = 2 * tokens * h * h;
        let ffn = if moe_layer {
            2 * tokens * h * self.experts // gate
                + self.top_k * 2 * tokens * h * self.expert_ffn * 3
        } else {
            2 * tokens * h * self.ffn * 3
        };
        qkv + attn + proj + ffn
    }

    /// Total forward FLOPs over all layers + LM head.
    pub fn forward_flops(&self, tokens: u64, ctx: u64) -> u64 {
        let moe = self.moe_layers() * self.layer_flops(tokens, ctx, true);
        let dense = self.dense_ffn_layers() * self.layer_flops(tokens, ctx, false);
        moe + dense + 2 * tokens * self.hidden * self.vocab
    }

    // ---- presets ---------------------------------------------------------

    /// The tiny dense model that the AOT grid actually lowers/profiles.
    pub fn tiny_dense() -> ModelSpec {
        ModelSpec {
            name: "tiny-dense".into(),
            hidden: 256,
            heads: 8,
            kv_heads: 8,
            ffn: 1024,
            layers: 4,
            vocab: 2048,
            experts: 0,
            top_k: 0,
            expert_ffn: 0,
            moe_every: 1,
        }
    }

    /// The tiny MoE model that the AOT grid actually lowers/profiles.
    pub fn tiny_moe() -> ModelSpec {
        ModelSpec {
            name: "tiny-moe".into(),
            hidden: 256,
            heads: 8,
            kv_heads: 8,
            ffn: 1024,
            layers: 4,
            vocab: 2048,
            experts: 8,
            top_k: 2,
            expert_ffn: 512,
            moe_every: 1,
        }
    }

    /// Llama 3.1 8B (paper's dense model; analytical-extension target).
    pub fn llama31_8b() -> ModelSpec {
        ModelSpec {
            name: "llama3.1-8b".into(),
            hidden: 4096,
            heads: 32,
            kv_heads: 8,
            ffn: 14336,
            layers: 32,
            vocab: 128256,
            experts: 0,
            top_k: 0,
            expert_ffn: 0,
            moe_every: 1,
        }
    }

    /// Phi-mini-MoE (paper's MoE model; analytical-extension target).
    pub fn phi_mini_moe() -> ModelSpec {
        ModelSpec {
            name: "phi-mini-moe".into(),
            hidden: 4096,
            heads: 32,
            kv_heads: 8,
            ffn: 0, // all layers MoE
            layers: 32,
            vocab: 32064,
            experts: 16,
            top_k: 2,
            expert_ffn: 6400,
            moe_every: 1,
        }
    }

    /// Look up a preset by name.
    pub fn preset(name: &str) -> Option<ModelSpec> {
        match name {
            "tiny-dense" => Some(Self::tiny_dense()),
            "tiny-moe" => Some(Self::tiny_moe()),
            "llama3.1-8b" => Some(Self::llama31_8b()),
            "phi-mini-moe" => Some(Self::phi_mini_moe()),
            _ => None,
        }
    }

    /// All preset names (for CLI help / config validation messages).
    pub fn preset_names() -> &'static [&'static str] {
        &["tiny-dense", "tiny-moe", "llama3.1-8b", "phi-mini-moe"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ModelSpec::preset_names() {
            let m = ModelSpec::preset(name).unwrap();
            assert_eq!(&m.name, name);
            assert_eq!(m.hidden % m.heads, 0);
        }
        assert!(ModelSpec::preset("nope").is_none());
    }

    #[test]
    fn tiny_matches_python_manifest_dims() {
        let m = ModelSpec::tiny_dense();
        assert_eq!((m.hidden, m.heads, m.ffn, m.vocab), (256, 8, 1024, 2048));
        let m = ModelSpec::tiny_moe();
        assert_eq!((m.experts, m.top_k, m.expert_ffn), (8, 2, 512));
    }

    #[test]
    fn kv_bytes_scale_with_layers() {
        let m = ModelSpec::tiny_dense();
        // 2 (K+V) * 4 layers * 8 heads * 32 dim * 2 bytes
        assert_eq!(m.kv_bytes_per_token(), 2 * 4 * 8 * 32 * 2);
    }

    #[test]
    fn llama8b_param_count_plausible() {
        let m = ModelSpec::llama31_8b();
        let params = m.param_bytes() / DTYPE_BYTES;
        // ~8.0B (7.5–8.5 allowing for tied-embedding accounting)
        assert!(
            (7_000_000_000..9_000_000_000).contains(&params),
            "params={params}"
        );
    }

    #[test]
    fn moe_layer_flops_use_topk_not_all_experts() {
        let m = ModelSpec::tiny_moe();
        let moe = m.layer_flops(16, 16, true);
        let dense = m.layer_flops(16, 16, false);
        // top_k * expert_ffn = 2*512 = 1024 == dense ffn → near-equal FLOPs
        let ratio = moe as f64 / dense as f64;
        assert!((0.9..1.2).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn forward_flops_monotone() {
        let m = ModelSpec::tiny_dense();
        assert!(m.forward_flops(2, 2) < m.forward_flops(4, 4));
        assert!(m.forward_flops(4, 64) < m.forward_flops(4, 128));
    }

    #[test]
    fn expert_bytes() {
        let m = ModelSpec::tiny_moe();
        assert_eq!(m.expert_bytes(), 3 * 256 * 512 * 2);
    }
}
