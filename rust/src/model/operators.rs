//! Operator vocabulary shared by the AOT manifest, the profiler, and the
//! trace-driven performance model.
//!
//! An [`OpInvocation`] is the unit the simulator prices: "run operator X
//! with this many tokens / this batch / this context". The trace DB is keyed
//! on `(OpKind, grid point)`; `perf::trace` interpolates between profiled
//! grid points.

use std::fmt;

/// The operator kinds emitted by `python/compile/aot.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    QkvProj,
    AttnPrefill,
    AttnDecode,
    OutProj,
    Ffn,
    MoeGate,
    ExpertFfn,
    LmHead,
    RmsNorm,
}

impl OpKind {
    /// Manifest string name (matches `aot.py` `op` field).
    pub fn as_str(self) -> &'static str {
        match self {
            OpKind::QkvProj => "qkv_proj",
            OpKind::AttnPrefill => "attn_prefill",
            OpKind::AttnDecode => "attn_decode",
            OpKind::OutProj => "out_proj",
            OpKind::Ffn => "ffn",
            OpKind::MoeGate => "moe_gate",
            OpKind::ExpertFfn => "expert_ffn",
            OpKind::LmHead => "lm_head",
            OpKind::RmsNorm => "rmsnorm",
        }
    }

    pub fn from_str(s: &str) -> Option<OpKind> {
        Some(match s {
            "qkv_proj" => OpKind::QkvProj,
            "attn_prefill" => OpKind::AttnPrefill,
            "attn_decode" => OpKind::AttnDecode,
            "out_proj" => OpKind::OutProj,
            "ffn" => OpKind::Ffn,
            "moe_gate" => OpKind::MoeGate,
            "expert_ffn" => OpKind::ExpertFfn,
            "lm_head" => OpKind::LmHead,
            "rmsnorm" => OpKind::RmsNorm,
            _ => return None,
        })
    }

    /// All kinds, in manifest order.
    pub fn all() -> &'static [OpKind] {
        &[
            OpKind::QkvProj,
            OpKind::AttnPrefill,
            OpKind::AttnDecode,
            OpKind::OutProj,
            OpKind::Ffn,
            OpKind::MoeGate,
            OpKind::ExpertFfn,
            OpKind::LmHead,
            OpKind::RmsNorm,
        ]
    }

    /// True for operators whose grid is 2-D `(batch, ctx)` rather than 1-D
    /// `(tokens)`.
    pub fn is_decode_grid(self) -> bool {
        matches!(self, OpKind::AttnDecode)
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A priced operator invocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpInvocation {
    pub kind: OpKind,
    /// Token count for 1-D-grid ops; batch size for `AttnDecode`.
    pub tokens: u64,
    /// Context length; only meaningful for `AttnDecode` (and informative for
    /// `AttnPrefill`, where `tokens` is the sequence length).
    pub ctx: u64,
}

impl OpInvocation {
    pub fn tokens(kind: OpKind, tokens: u64) -> Self {
        OpInvocation { kind, tokens, ctx: 0 }
    }

    pub fn decode(batch: u64, ctx: u64) -> Self {
        OpInvocation {
            kind: OpKind::AttnDecode,
            tokens: batch,
            ctx,
        }
    }

    pub fn prefill(seq: u64) -> Self {
        OpInvocation {
            kind: OpKind::AttnPrefill,
            tokens: seq,
            ctx: seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_names() {
        for &k in OpKind::all() {
            assert_eq!(OpKind::from_str(k.as_str()), Some(k));
        }
        assert_eq!(OpKind::from_str("bogus"), None);
    }

    #[test]
    fn decode_grid_flag() {
        assert!(OpKind::AttnDecode.is_decode_grid());
        assert!(!OpKind::Ffn.is_decode_grid());
    }

    #[test]
    fn invocation_constructors() {
        let inv = OpInvocation::decode(8, 256);
        assert_eq!(inv.kind, OpKind::AttnDecode);
        assert_eq!((inv.tokens, inv.ctx), (8, 256));
        let inv = OpInvocation::prefill(128);
        assert_eq!((inv.tokens, inv.ctx), (128, 128));
    }
}
