//! Flow-aware analysis: item-level parse, call graph, and the H/E/P rules.
//!
//! The per-line rules in [`super::rules`] see single findings; this module
//! sees *structure*. It builds, from the same token stream the scanner
//! already produces:
//!
//! * an **item-level parse** — `impl`/`trait` blocks (with their self-type)
//!   and `fn` definitions with body token ranges;
//! * a **cross-file symbol table** — every function keyed by bare name and
//!   by `Type::name`;
//! * a **call graph** — call sites extracted from each body (`foo(…)`,
//!   `.foo(…)`, `Type::foo(…)`), resolved conservatively by name: a call
//!   may reach *every* same-named function in the scanned set, so
//!   reachability over-approximates (flags more, never less);
//! * a **hot set** — functions reachable from the hot roots in
//!   [`HOT_ROOTS`] (`Simulation::handle_event`, `SimDriver::step`,
//!   `ServingInstance::begin_step`, the `EventQueue` push/pop surface).
//!
//! On top of that sit three rule families:
//!
//! * **H01** — allocation constructors (`Vec::new`, `vec!`, `to_vec`,
//!   `collect`, `format!`, `String::from`, `Box::new`) in any function
//!   reachable from a hot root. PR 6 made the event core allocation-free;
//!   H01 statically keeps it that way. Known-amortized scratch-buffer
//!   sites carry `// simlint: allow(H01) — <reason>`; whole cold-by-design
//!   functions (diagnostics, teardown) can opt out of the hot set with
//!   `// simlint: cold — <reason>` directly above the `fn`.
//! * **H02** — `.clone()` on `Request`/batch-state values ([`H02_TYPES`])
//!   in a hot function. The serving loop moves requests; clones are the
//!   bug class PR 6 eliminated.
//! * **E01** — a wildcard `_ =>` arm in a `match` whose patterns mention a
//!   core enum ([`CORE_ENUMS`]), inside a core module. Adding an `Event`
//!   or `ClusterAction` variant must fail the lint, not fall through
//!   silently. (A match consisting *only* of `_ =>` carries no enum path
//!   in its patterns and is invisible to this rule — acceptable, since
//!   such a match cannot silently lose a new variant it never named.)
//! * **P01** — registry/doc consistency: every built-in name in a
//!   [`FAMILIES`] definition site (a `register_*("name", …)` call or the
//!   family's canonical `*_names()` literal list) must appear in that
//!   family's companion functions (the match arms behind `from_str`,
//!   `for_name`, `preset`, `profile`, `by_name`, …) and in README.md /
//!   DESIGN.md. The candidate-list errors and the `presets` listing
//!   enumerate the live registry at runtime, so they cannot drift — the
//!   statically checkable surfaces are exactly the companion-function
//!   arms and the docs.

use super::rules::typed_symbols;
use super::scanner::{ScanResult, Token, TokenKind};
use super::{Finding, RuleId};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Reachability roots: the event-core entry points. `(impl type, fn name)`.
pub const HOT_ROOTS: &[(&str, &str)] = &[
    ("Simulation", "handle_event"),
    ("SimDriver", "step"),
    ("ServingInstance", "begin_step"),
    ("EventQueue", "schedule_at"),
    ("EventQueue", "schedule_in"),
    ("EventQueue", "pop"),
];

/// Enums whose matches must stay wildcard-free in core modules (E01):
/// the event vocabulary, controller actions, the operator vocabulary,
/// and the terminal request/instance lifecycle states.
pub const CORE_ENUMS: &[&str] = &[
    "Event",
    "ClusterAction",
    "OpKind",
    "Phase",
    "Lifecycle",
];

/// Request/batch-state types whose `.clone()` is banned on hot paths (H02).
pub const H02_TYPES: &[&str] = &["Request", "SeqState", "StepOutcome", "KvHandoff"];

// ---------------------------------------------------------------------------
// Item-level parse
// ---------------------------------------------------------------------------

/// One parsed function definition.
#[derive(Debug, Clone)]
pub struct FnDef {
    /// Index into the scanned file list.
    pub file: usize,
    /// Enclosing `impl`/`trait` self-type, if any.
    pub qual: Option<String>,
    pub name: String,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// Token indices of the body braces `[open, close]`, if the fn has one.
    pub body: Option<(usize, usize)>,
    /// Marked `// simlint: cold — <reason>`: excluded from the hot set and
    /// from propagation through it.
    pub is_cold: bool,
    pub in_test: bool,
}

impl FnDef {
    /// `Type::name` or bare `name`, for messages.
    pub fn display(&self) -> String {
        match &self.qual {
            Some(q) => format!("{q}::{}", self.name),
            None => self.name.clone(),
        }
    }
}

/// Index of the punct closing the bracket opened at `open`.
fn matching_close(toks: &[Token], open: usize, open_c: char, close_c: char) -> Option<usize> {
    let mut depth = 0usize;
    let mut j = open;
    while j < toks.len() {
        if toks[j].is_punct(open_c) {
            depth += 1;
        } else if toks[j].is_punct(close_c) {
            depth = depth.checked_sub(1)?;
            if depth == 0 {
                return Some(j);
            }
        }
        j += 1;
    }
    None
}

/// Skip a balanced `<…>` generics group starting at `*i` (if present).
/// A `>` directly preceded by `-` is an arrow, not a closer.
fn skip_generics(toks: &[Token], i: &mut usize) {
    if !toks.get(*i).is_some_and(|t| t.is_punct('<')) {
        return;
    }
    let mut depth = 0i32;
    while *i < toks.len() {
        let t = &toks[*i];
        if t.is_punct('<') {
            depth += 1;
        } else if t.is_punct('>') && !(*i > 0 && toks[*i - 1].is_punct('-')) {
            depth -= 1;
            if depth == 0 {
                *i += 1;
                return;
            }
        }
        *i += 1;
    }
}

/// Read a type path at `*i` (skipping leading `&`/`mut`/`dyn`), returning
/// the final path segment; trailing generic args are skipped.
fn read_path_last(toks: &[Token], i: &mut usize) -> Option<String> {
    while toks
        .get(*i)
        .is_some_and(|t| t.is_punct('&') || t.is_punct('(') || t.is_ident("mut") || t.is_ident("dyn"))
    {
        *i += 1;
    }
    let mut last: Option<String> = None;
    loop {
        let t = toks.get(*i)?;
        if t.kind != TokenKind::Ident {
            break;
        }
        last = Some(t.text.clone());
        *i += 1;
        if toks.get(*i).is_some_and(|t| t.is_punct(':'))
            && toks.get(*i + 1).is_some_and(|t| t.is_punct(':'))
        {
            *i += 2;
            continue;
        }
        break;
    }
    skip_generics(toks, i);
    last
}

/// Parse an `impl`/`trait` item header starting at the keyword token.
/// Returns `(self type, index of body open brace)`.
fn parse_item_header(toks: &[Token], start: usize) -> Option<(String, usize)> {
    let mut i = start + 1;
    skip_generics(toks, &mut i);
    let mut qual = read_path_last(toks, &mut i)?;
    loop {
        let t = toks.get(i)?;
        if t.is_ident("for") {
            i += 1;
            qual = read_path_last(toks, &mut i)?;
            continue;
        }
        if t.is_punct('{') {
            return Some((qual, i));
        }
        if t.is_punct(';') {
            // `impl Foo;` is not Rust, but a trait alias/odd input ends here.
            return None;
        }
        i += 1; // where clauses, `+ Send` bounds, parens in Fn bounds
    }
}

/// Is the token at `i` an *item-position* `impl`/`trait` keyword (as
/// opposed to `-> impl Trait` / `(x: impl Trait)` type positions)?
fn item_position(toks: &[Token], i: usize) -> bool {
    if i == 0 {
        return true;
    }
    let p = &toks[i - 1];
    p.is_punct('{')
        || p.is_punct('}')
        || p.is_punct(';')
        || p.is_punct(']')
        || p.is_ident("pub")
        || p.is_ident("unsafe")
}

/// Parse every function definition in one scanned file.
pub fn parse_fns(file: usize, scan: &ScanResult) -> Vec<FnDef> {
    let toks = &scan.tokens;
    let mut out = Vec::new();
    // Stack of (body close index, self type) for impl/trait blocks.
    let mut ctx: Vec<(usize, String)> = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        while ctx.last().is_some_and(|(close, _)| i > *close) {
            ctx.pop();
        }
        let t = &toks[i];
        if (t.is_ident("impl") || t.is_ident("trait")) && item_position(toks, i) {
            if let Some((qual, open)) = parse_item_header(toks, i) {
                if let Some(close) = matching_close(toks, open, '{', '}') {
                    ctx.push((close, qual));
                    i = open + 1;
                    continue;
                }
            }
        }
        if t.is_ident("fn") && toks.get(i + 1).is_some_and(|n| n.kind == TokenKind::Ident) {
            let name = toks[i + 1].text.clone();
            let mut j = i + 2;
            let mut body = None;
            while j < toks.len() {
                if toks[j].is_punct('{') {
                    body = matching_close(toks, j, '{', '}').map(|c| (j, c));
                    break;
                }
                if toks[j].is_punct(';') {
                    break;
                }
                j += 1;
            }
            let qual = ctx.last().map(|(_, q)| q.clone());
            out.push(FnDef {
                file,
                qual,
                name,
                line: t.line,
                body,
                is_cold: super::cold_marked(scan, t.line),
                in_test: t.in_test,
            });
            i = match body {
                Some((open, _)) => open + 1, // visit nested items too
                None => j,
            };
            continue;
        }
        i += 1;
    }
    out
}

// ---------------------------------------------------------------------------
// Call graph + reachability
// ---------------------------------------------------------------------------

/// One extracted call site: `name(…)`, `.name(…)`, or `Qual::name(…)`.
struct CallSite {
    qual: Option<String>,
    /// Receiver is literally `self` (`self.name(…)`) — resolved against
    /// the caller's own impl only.
    self_recv: bool,
    /// A `.name(…)` method call (any receiver).
    is_method: bool,
    name: String,
}

/// Keywords that look like `ident (` but are not calls.
const NON_CALL_KEYWORDS: &[&str] = &[
    "if", "while", "for", "in", "match", "return", "loop", "move", "else",
    "let", "as", "mut", "ref", "box", "await", "yield", "fn",
];

/// Std-container/iterator/option method names. A `recv.m()` call with one
/// of these names is overwhelmingly a call into std; resolving it by bare
/// name to a same-named domain method would wire unrelated impls into the
/// hot set (measured on this tree: `.insert(` alone linked the event core
/// to the radix tree, and `.parse(`/`.load(` to the whole config layer).
/// Domain dispatch names (`op_latency`, `on_tick`, `order`, `pick`, …)
/// stay resolvable. Sorted; kept deliberately std-shaped — never add a
/// domain method name here, mark the callee `simlint: cold` instead.
const STD_METHODS: &[&str] = &[
    "abs", "all", "and_then", "any", "as_bytes", "as_mut", "as_ref",
    "as_slice", "as_str", "binary_search", "binary_search_by", "ceil",
    "chain", "checked_add", "checked_sub", "chunks", "clear", "clone",
    "cloned", "collect", "contains", "contains_key", "copied", "count",
    "default", "drain", "entry", "enumerate", "exp", "expect", "extend",
    "filter", "filter_map", "find", "find_map", "first", "flat_map",
    "floor", "fold", "get", "get_mut", "get_or_insert_with", "insert",
    "into_iter", "is_empty", "is_err", "is_none", "is_ok", "is_some",
    "iter", "iter_mut", "join", "keys", "last", "len", "ln", "log2", "map",
    "map_err", "max", "max_by", "max_by_key", "min", "min_by",
    "min_by_key", "new", "next", "ok_or", "ok_or_else", "parse",
    "position", "powf", "powi", "push", "push_back", "push_front",
    "remove", "replace", "reserve", "resize", "retain", "rev", "round",
    "rsplitn", "saturating_add", "saturating_sub", "skip", "skip_while",
    "sort", "sort_by", "sort_by_key", "sort_unstable", "sort_unstable_by",
    "split", "split_whitespace", "splitn", "sqrt", "starts_with",
    "strip_prefix", "strip_suffix", "sum", "swap", "take", "take_while",
    "to_string", "to_vec", "trim", "truncate", "unwrap", "unwrap_or",
    "unwrap_or_default", "unwrap_or_else", "values", "values_mut",
    "windows", "wrapping_mul", "write_str", "zip",
];

fn call_sites(toks: &[Token], open: usize, close: usize) -> Vec<CallSite> {
    let mut out = Vec::new();
    for j in open + 1..close {
        let t = &toks[j];
        if t.kind != TokenKind::Ident || !toks.get(j + 1).is_some_and(|n| n.is_punct('(')) {
            continue;
        }
        if NON_CALL_KEYWORDS.contains(&t.text.as_str()) {
            continue;
        }
        if j >= 1 && toks[j - 1].is_ident("fn") {
            continue; // a nested definition, not a call
        }
        let mut qual = None;
        let mut self_recv = false;
        let mut is_method = false;
        if j >= 2 && toks[j - 1].is_punct(':') && toks[j - 2].is_punct(':') {
            if j >= 3 && toks[j - 3].kind == TokenKind::Ident {
                qual = Some(toks[j - 3].text.clone());
            }
        } else if j >= 1 && toks[j - 1].is_punct('.') {
            is_method = true;
            self_recv = j >= 2 && toks[j - 2].is_ident("self");
        }
        out.push(CallSite {
            qual,
            self_recv,
            is_method,
            name: t.text.clone(),
        });
    }
    out
}

/// The cross-file model: every parsed function plus its hot-set marking.
pub struct FlowModel {
    pub fns: Vec<FnDef>,
    /// `hot[i]` — `fns[i]` is reachable from a hot root.
    pub hot: Vec<bool>,
}

impl FlowModel {
    /// Parse every file, build the call graph, and mark the hot set.
    pub fn build(files: &[(String, ScanResult)]) -> FlowModel {
        let mut fns: Vec<FnDef> = Vec::new();
        for (idx, (_, scan)) in files.iter().enumerate() {
            fns.extend(parse_fns(idx, scan));
        }

        let mut by_qual: BTreeMap<(&str, &str), Vec<usize>> = BTreeMap::new();
        let mut free_by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        let mut method_by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (i, f) in fns.iter().enumerate() {
            if f.in_test {
                continue;
            }
            match &f.qual {
                Some(q) => {
                    by_qual.entry((q, &f.name)).or_default().push(i);
                    method_by_name.entry(&f.name).or_default().push(i);
                }
                None => free_by_name.entry(&f.name).or_default().push(i),
            }
        }

        // Resolution is deliberately asymmetric to stay useful:
        // * `Type::m()` / `Self::m()` — exact `(type, name)` match only; a
        //   miss means a std/external type and resolves to nothing.
        // * `self.m()` — the caller's own impl only.
        // * `recv.m()` — every impl'd method named `m` (this is what makes
        //   trait dispatch like `perf.op_latency(…)` reach all impls),
        //   EXCEPT std-shaped names (see [`STD_METHODS`]).
        // * bare `m()` — free functions only (Rust requires a path for
        //   associated fns, so a bare call can't be a method).
        let mut edges: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); fns.len()];
        for (i, f) in fns.iter().enumerate() {
            if f.in_test {
                continue;
            }
            let Some((open, close)) = f.body else { continue };
            let toks = &files[f.file].1.tokens;
            for call in call_sites(toks, open, close) {
                // `Self::helper()` means the caller's own impl type.
                let qual = match call.qual.as_deref() {
                    Some("Self") => f.qual.clone(),
                    other => other.map(str::to_string),
                };
                let name = call.name.as_str();
                let targets: Option<&Vec<usize>> = match &qual {
                    Some(q) => by_qual.get(&(q.as_str(), name)),
                    None if call.self_recv => f
                        .qual
                        .as_deref()
                        .and_then(|q| by_qual.get(&(q, name))),
                    None if call.is_method => {
                        if STD_METHODS.contains(&name) {
                            None
                        } else {
                            method_by_name.get(name)
                        }
                    }
                    None => free_by_name.get(name),
                };
                if let Some(ts) = targets {
                    edges[i].extend(ts.iter().copied());
                }
            }
        }

        let mut hot = vec![false; fns.len()];
        let mut queue: VecDeque<usize> = VecDeque::new();
        for (q, n) in HOT_ROOTS {
            if let Some(roots) = by_qual.get(&(*q, *n)) {
                queue.extend(roots.iter().copied());
            }
        }
        while let Some(i) = queue.pop_front() {
            if hot[i] || fns[i].is_cold || fns[i].in_test {
                continue;
            }
            hot[i] = true;
            for &j in &edges[i] {
                if !hot[j] {
                    queue.push_back(j);
                }
            }
        }

        FlowModel { fns, hot }
    }
}

// ---------------------------------------------------------------------------
// H-rules: hot-path allocation and clone guards
// ---------------------------------------------------------------------------

fn push_finding(
    findings: &mut Vec<Finding>,
    rule: RuleId,
    path: &str,
    scan: &ScanResult,
    tok: &Token,
    message: String,
) {
    findings.push(Finding {
        rule,
        path: path.to_string(),
        line: tok.line,
        col: tok.col,
        message,
        line_text: scan.line_text(tok.line).to_string(),
    });
}

/// `A :: B` starting at `j` (four-token window `A : : B`).
fn path2(toks: &[Token], j: usize, a: &str, b: &str) -> bool {
    toks[j].is_ident(a)
        && toks.get(j + 1).is_some_and(|t| t.is_punct(':'))
        && toks.get(j + 2).is_some_and(|t| t.is_punct(':'))
        && toks.get(j + 3).is_some_and(|t| t.is_ident(b))
}

/// Run H01/H02 over every hot function. Findings are raw — the caller
/// applies inline allows and the baseline.
pub fn check_hot(files: &[(String, ScanResult)], model: &FlowModel) -> Vec<Finding> {
    let mut findings = Vec::new();
    // Per-file H02 symbol tables, built lazily (most files have no hot fn).
    let mut h02_syms: BTreeMap<usize, BTreeSet<String>> = BTreeMap::new();

    for (i, f) in model.fns.iter().enumerate() {
        if !model.hot[i] {
            continue;
        }
        let Some((open, close)) = f.body else { continue };
        let (path, scan) = &files[f.file];
        let toks = &scan.tokens;
        let who = f.display();

        let syms = h02_syms.entry(f.file).or_insert_with(|| {
            let refs: Vec<&Token> = scan.tokens.iter().filter(|t| !t.in_test).collect();
            typed_symbols(&refs, H02_TYPES)
        });

        for j in open + 1..close {
            let t = &toks[j];
            // H01: allocation constructors.
            if path2(toks, j, "Vec", "new")
                || path2(toks, j, "String", "from")
                || path2(toks, j, "Box", "new")
            {
                push_finding(
                    &mut findings,
                    RuleId::H01,
                    path,
                    scan,
                    t,
                    format!(
                        "`{}::{}` allocates inside `{who}`, which is reachable from a hot root",
                        t.text,
                        toks[j + 3].text
                    ),
                );
                continue;
            }
            if (t.is_ident("vec") || t.is_ident("format"))
                && toks.get(j + 1).is_some_and(|n| n.is_punct('!'))
            {
                push_finding(
                    &mut findings,
                    RuleId::H01,
                    path,
                    scan,
                    t,
                    format!(
                        "`{}!` allocates inside `{who}`, which is reachable from a hot root",
                        t.text
                    ),
                );
                continue;
            }
            if t.is_punct('.')
                && toks
                    .get(j + 1)
                    .is_some_and(|n| n.is_ident("to_vec") || n.is_ident("collect"))
            {
                let m = &toks[j + 1];
                let called = toks.get(j + 2).is_some_and(|n| {
                    n.is_punct('(')
                        || (n.is_punct(':') && toks.get(j + 3).is_some_and(|c| c.is_punct(':')))
                });
                if called {
                    push_finding(
                        &mut findings,
                        RuleId::H01,
                        path,
                        scan,
                        m,
                        format!(
                            "`.{}()` allocates inside `{who}`, which is reachable from a hot root",
                            m.text
                        ),
                    );
                }
                continue;
            }
            // H02: clones of Request/batch-state values.
            if t.is_punct('.')
                && toks.get(j + 1).is_some_and(|n| n.is_ident("clone"))
                && toks.get(j + 2).is_some_and(|n| n.is_punct('('))
                && j >= 1
                && toks[j - 1].kind == TokenKind::Ident
                && syms.contains(&toks[j - 1].text)
            {
                push_finding(
                    &mut findings,
                    RuleId::H02,
                    path,
                    scan,
                    &toks[j + 1],
                    format!(
                        "`{}.clone()` copies request/batch state inside `{who}`, \
                         which is reachable from a hot root",
                        toks[j - 1].text
                    ),
                );
            }
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// E01: exhaustive dispatch over core enums
// ---------------------------------------------------------------------------

fn matching_close_ref(toks: &[&Token], open: usize, open_c: char, close_c: char) -> Option<usize> {
    let mut depth = 0usize;
    let mut j = open;
    while j < toks.len() {
        if toks[j].is_punct(open_c) {
            depth += 1;
        } else if toks[j].is_punct(close_c) {
            depth = depth.checked_sub(1)?;
            if depth == 0 {
                return Some(j);
            }
        }
        j += 1;
    }
    None
}

/// Scan one file (non-test tokens) for wildcard arms in matches over core
/// enums. Called from `rules::check` for core-module files.
pub(crate) fn check_e01(
    path: &str,
    scan: &ScanResult,
    toks: &[&Token],
    findings: &mut Vec<Finding>,
) {
    let mut i = 0usize;
    while i < toks.len() {
        if !toks[i].is_ident("match") {
            i += 1;
            continue;
        }
        // Scrutinee: everything to the first `{` at paren/bracket depth 0.
        let mut j = i + 1;
        let mut pd = 0i32;
        let mut open = None;
        while j < toks.len() {
            let t = toks[j];
            if t.is_punct('(') || t.is_punct('[') {
                pd += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                pd -= 1;
            } else if pd == 0 && t.is_punct('{') {
                open = Some(j);
                break;
            } else if pd == 0 && t.is_punct(';') {
                break;
            }
            j += 1;
        }
        let Some(open) = open else {
            i += 1;
            continue;
        };
        if let Some(close) = matching_close_ref(toks, open, '{', '}') {
            check_match_arms(path, scan, toks, open, close, findings);
        }
        // Nested matches are reached by the outer loop continuing inside.
        i += 1;
    }
}

/// Parse the arms of one match body; flag wildcard arms when any arm
/// pattern names a core enum.
fn check_match_arms(
    path: &str,
    scan: &ScanResult,
    toks: &[&Token],
    open: usize,
    close: usize,
    findings: &mut Vec<Finding>,
) {
    let mut enum_name: Option<&str> = None;
    let mut wildcards: Vec<usize> = Vec::new();

    let mut i = open + 1;
    while i < close {
        // Pattern: tokens to the `=>` at depth 0.
        let pat_start = i;
        let mut depth = 0i32;
        let mut arrow = None;
        let mut j = i;
        while j < close {
            let t = toks[j];
            if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
                depth += 1;
            } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
                depth -= 1;
            } else if depth == 0
                && t.is_punct('=')
                && toks.get(j + 1).is_some_and(|n| n.is_punct('>'))
            {
                arrow = Some(j);
                break;
            }
            j += 1;
        }
        let Some(arrow) = arrow else { break };

        // Guard split: the pattern ends at a depth-0 `if`.
        let mut pat_end = arrow;
        {
            let mut d = 0i32;
            for k in pat_start..arrow {
                let t = toks[k];
                if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
                    d += 1;
                } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
                    d -= 1;
                } else if d == 0 && t.is_ident("if") {
                    pat_end = k;
                    break;
                }
            }
        }

        // Core-enum reference in the pattern (`Event ::`, …)?
        for k in pat_start..pat_end {
            if toks[k].kind == TokenKind::Ident
                && toks.get(k + 1).is_some_and(|t| t.is_punct(':'))
                && toks.get(k + 2).is_some_and(|t| t.is_punct(':'))
            {
                if let Some(e) = CORE_ENUMS.iter().find(|e| **e == toks[k].text) {
                    enum_name = Some(*e);
                }
            }
        }

        // Wildcard: a depth-0 alternation branch that is exactly `_`, in an
        // arm with NO guard. A guarded `_ if cond =>` arm is exempt: guards
        // don't count toward exhaustiveness, so the compiler still forces
        // the remaining arms to cover every variant — a new variant cannot
        // fall through silently there.
        if pat_end == arrow {
            let mut d = 0i32;
            let mut branch: Vec<usize> = Vec::new();
            let mut flush = |branch: &mut Vec<usize>, wildcards: &mut Vec<usize>| {
                if branch.len() == 1 && toks[branch[0]].is_ident("_") {
                    wildcards.push(branch[0]);
                }
                branch.clear();
            };
            for k in pat_start..pat_end {
                let t = toks[k];
                if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
                    if d == 0 {
                        branch.push(k);
                    }
                    d += 1;
                    continue;
                }
                if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
                    d -= 1;
                    if d == 0 {
                        branch.push(k);
                    }
                    continue;
                }
                if d == 0 {
                    if t.is_punct('|') {
                        flush(&mut branch, &mut wildcards);
                    } else {
                        branch.push(k);
                    }
                }
            }
            flush(&mut branch, &mut wildcards);
        }

        // Skip the arm expression: a `{…}` block, or scan to a depth-0 `,`.
        i = arrow + 2;
        if i < close && toks[i].is_punct('{') {
            match matching_close_ref(toks, i, '{', '}') {
                Some(c) => {
                    i = c + 1;
                    if i < close && toks[i].is_punct(',') {
                        i += 1;
                    }
                }
                None => break,
            }
        } else {
            let mut d = 0i32;
            while i < close {
                let t = toks[i];
                if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
                    d += 1;
                } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
                    d -= 1;
                } else if d == 0 && t.is_punct(',') {
                    i += 1;
                    break;
                }
                i += 1;
            }
        }
    }

    if let Some(e) = enum_name {
        for w in wildcards {
            push_finding(
                findings,
                RuleId::E01,
                path,
                scan,
                toks[w],
                format!(
                    "wildcard `_ =>` arm in a match over core enum `{e}` — \
                     a new variant would fall through silently"
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// P01: registry/doc consistency
// ---------------------------------------------------------------------------

/// Where a family's built-in names are defined or must re-appear.
pub enum SourceSpec {
    /// First string-literal argument of every `<method>("name", …)` call.
    Register(&'static str),
    /// All string literals inside `fn <name>` (optionally `Type::<name>`).
    FnLiterals(Option<&'static str>, &'static str),
}

impl SourceSpec {
    fn describe(&self) -> String {
        match self {
            SourceSpec::Register(m) => format!("`{m}(…)` calls"),
            SourceSpec::FnLiterals(Some(q), n) => format!("`{q}::{n}`"),
            SourceSpec::FnLiterals(None, n) => format!("`{n}`"),
        }
    }
}

/// One plugin-name family: definition site + the companion surfaces every
/// name must appear in. Docs (README.md / DESIGN.md) are an implicit
/// surface for every family.
pub struct FamilySpec {
    pub family: &'static str,
    pub def: SourceSpec,
    pub surfaces: &'static [SourceSpec],
}

/// The registry families P01 keeps consistent.
pub const FAMILIES: &[FamilySpec] = &[
    FamilySpec {
        family: "route policy",
        def: SourceSpec::Register("register_route"),
        surfaces: &[],
    },
    FamilySpec {
        family: "schedule policy",
        def: SourceSpec::FnLiterals(Some("SchedPolicy"), "as_str"),
        surfaces: &[SourceSpec::FnLiterals(Some("SchedPolicy"), "from_str")],
    },
    FamilySpec {
        family: "eviction policy",
        def: SourceSpec::FnLiterals(Some("EvictPolicy"), "as_str"),
        surfaces: &[SourceSpec::FnLiterals(Some("EvictPolicy"), "from_str")],
    },
    FamilySpec {
        family: "traffic source",
        def: SourceSpec::FnLiterals(Some("Traffic"), "builtin_names"),
        surfaces: &[SourceSpec::FnLiterals(Some("Traffic"), "for_name")],
    },
    FamilySpec {
        family: "cluster controller",
        def: SourceSpec::Register("register_controller"),
        surfaces: &[],
    },
    FamilySpec {
        family: "hardware preset",
        def: SourceSpec::FnLiterals(Some("HardwareSpec"), "preset_names"),
        surfaces: &[SourceSpec::FnLiterals(Some("HardwareSpec"), "preset")],
    },
    FamilySpec {
        family: "chaos profile",
        def: SourceSpec::FnLiterals(Some("ChaosConfig"), "profile_names"),
        surfaces: &[SourceSpec::FnLiterals(Some("ChaosConfig"), "profile")],
    },
    FamilySpec {
        family: "serving preset",
        def: SourceSpec::FnLiterals(None, "serving_preset_names"),
        surfaces: &[SourceSpec::FnLiterals(None, "by_name")],
    },
];

/// A name extracted from a definition site, with its anchor for findings.
struct NameOrigin {
    name: String,
    file: usize,
    line: u32,
    col: u32,
}

fn fn_matches(f: &FnDef, qual: Option<&str>, name: &str) -> bool {
    f.name == name && f.qual.as_deref() == qual
}

/// Collect the string literals a [`SourceSpec`] denotes, with positions.
fn collect_names(
    files: &[(String, ScanResult)],
    model: &FlowModel,
    spec: &SourceSpec,
) -> Vec<NameOrigin> {
    let mut out = Vec::new();
    match spec {
        SourceSpec::Register(method) => {
            for (fi, (_, scan)) in files.iter().enumerate() {
                let toks = &scan.tokens;
                for j in 0..toks.len() {
                    if toks[j].in_test {
                        continue;
                    }
                    if toks[j].is_ident(method)
                        && toks.get(j + 1).is_some_and(|t| t.is_punct('('))
                        && toks.get(j + 2).is_some_and(|t| t.kind == TokenKind::Str)
                    {
                        let s = &toks[j + 2];
                        out.push(NameOrigin {
                            name: s.text.clone(),
                            file: fi,
                            line: s.line,
                            col: s.col,
                        });
                    }
                }
            }
        }
        SourceSpec::FnLiterals(qual, name) => {
            for f in &model.fns {
                if f.in_test || !fn_matches(f, *qual, name) {
                    continue;
                }
                let Some((open, close)) = f.body else { continue };
                let toks = &files[f.file].1.tokens;
                for t in &toks[open + 1..close] {
                    if t.kind == TokenKind::Str && !t.in_test {
                        out.push(NameOrigin {
                            name: t.text.clone(),
                            file: f.file,
                            line: t.line,
                            col: t.col,
                        });
                    }
                }
            }
        }
    }
    out
}

/// Run the P01 consistency check. `docs` are `(display name, content)`
/// pairs (README.md / DESIGN.md); when empty, the doc surface is skipped
/// (single-file scans, fixture trees).
pub fn check_p01(
    files: &[(String, ScanResult)],
    model: &FlowModel,
    docs: &[(String, String)],
) -> Vec<Finding> {
    let mut findings = Vec::new();
    for fam in FAMILIES {
        let defs = collect_names(files, model, &fam.def);
        if defs.is_empty() {
            continue; // family not present in this scanned set
        }
        // Surface literal sets (exact match: a real arm, not a mention).
        let surface_sets: Vec<(String, BTreeSet<String>)> = fam
            .surfaces
            .iter()
            .map(|s| {
                let names: BTreeSet<String> = collect_names(files, model, s)
                    .into_iter()
                    .map(|n| n.name)
                    .collect();
                (s.describe(), names)
            })
            .collect();
        for def in &defs {
            let (path, scan) = &files[def.file];
            let mut missing: Vec<String> = Vec::new();
            for (desc, names) in &surface_sets {
                // A surface that is entirely absent from the scanned set
                // (partial scan) cannot be checked honestly — skip it.
                if !names.is_empty() && !names.contains(&def.name) {
                    missing.push(desc.clone());
                }
            }
            for (doc_name, content) in docs {
                if !content.contains(&def.name) {
                    missing.push(doc_name.clone());
                }
            }
            if missing.is_empty() {
                continue;
            }
            let tok = Token {
                kind: TokenKind::Str,
                text: def.name.clone(),
                line: def.line,
                col: def.col,
                in_test: false,
            };
            push_finding(
                &mut findings,
                RuleId::P01,
                path,
                scan,
                &tok,
                format!(
                    "built-in {} name '{}' is missing from: {}",
                    fam.family,
                    def.name,
                    missing.join(", ")
                ),
            );
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scanner::scan;

    fn model_of(files: &[(String, ScanResult)]) -> FlowModel {
        FlowModel::build(files)
    }

    #[test]
    fn parses_impl_qualified_fns() {
        let src = "impl<'a> SimDriver<'a> {\n    pub fn step(&mut self) -> Option<u64> { self.tick() }\n    fn tick(&mut self) -> Option<u64> { None }\n}\nfn free() {}\n";
        let s = scan(src);
        let fns = parse_fns(0, &s);
        let names: Vec<String> = fns.iter().map(|f| f.display()).collect();
        assert_eq!(names, vec!["SimDriver::step", "SimDriver::tick", "free"]);
    }

    #[test]
    fn trait_impl_qualifies_by_self_type() {
        let src = "impl std::str::FromStr for SchedPolicy {\n    type Err = ();\n    fn from_str(s: &str) -> Result<Self, ()> { Err(()) }\n}\n";
        let s = scan(src);
        let fns = parse_fns(0, &s);
        assert_eq!(fns[0].display(), "SchedPolicy::from_str");
    }

    #[test]
    fn return_position_impl_is_not_an_item() {
        let src = "fn f() -> impl Iterator<Item = u32> { (0..3).into_iter() }\nfn g() {}\n";
        let s = scan(src);
        let fns = parse_fns(0, &s);
        let names: Vec<&str> = fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["f", "g"]);
        assert!(fns.iter().all(|f| f.qual.is_none()));
    }

    #[test]
    fn reachability_crosses_files_and_respects_cold() {
        let a = "impl Simulation {\n    fn handle_event(&mut self) { helper(); }\n}\n";
        let b = "pub fn helper() { deep(); }\npub fn deep() {}\n// simlint: cold — diagnostics only\npub fn frosty() { deep(); }\n";
        let files = vec![
            ("coordinator/mod.rs".to_string(), scan(a)),
            ("util/h.rs".to_string(), scan(b)),
        ];
        let m = model_of(&files);
        let hot: BTreeSet<String> = m
            .fns
            .iter()
            .zip(&m.hot)
            .filter(|(_, h)| **h)
            .map(|(f, _)| f.name.clone())
            .collect();
        assert!(hot.contains("handle_event"), "{hot:?}");
        assert!(hot.contains("helper"), "{hot:?}");
        assert!(hot.contains("deep"), "{hot:?}");
        assert!(!hot.contains("frosty"), "cold fn must stay out: {hot:?}");
    }

    #[test]
    fn h01_fires_only_in_hot_fns() {
        let src = "impl Simulation {\n    fn handle_event(&mut self) { let v: Vec<u32> = Vec::new(); }\n}\nfn unreached() { let v: Vec<u32> = Vec::new(); }\n";
        let files = vec![("coordinator/mod.rs".to_string(), scan(src))];
        let m = model_of(&files);
        let fs = check_hot(&files, &m);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, RuleId::H01);
        assert!(fs[0].message.contains("handle_event"));
    }

    #[test]
    fn h02_fires_on_request_clone_in_hot_fn() {
        let src = "impl Simulation {\n    fn handle_event(&mut self, req: Request) { let r2 = req.clone(); }\n}\n";
        let files = vec![("coordinator/mod.rs".to_string(), scan(src))];
        let m = model_of(&files);
        let fs = check_hot(&files, &m);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, RuleId::H02);
    }

    #[test]
    fn e01_flags_wildcard_over_core_enum_only() {
        let src = "fn f(e: Event) -> u32 {\n    match e {\n        Event::MetricsTick => 1,\n        _ => 0,\n    }\n}\nfn g(s: &str) -> u32 {\n    match s {\n        \"x\" => 1,\n        _ => 0,\n    }\n}\n";
        let s = scan(src);
        let refs: Vec<&Token> = s.tokens.iter().filter(|t| !t.in_test).collect();
        let mut fs = Vec::new();
        check_e01("sim/mod.rs", &s, &refs, &mut fs);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, RuleId::E01);
        assert!(fs[0].message.contains("Event"));
    }

    #[test]
    fn e01_ignores_wildcards_in_nested_noncore_match() {
        let src = "fn f(e: Event, s: &str) -> u32 {\n    match e {\n        Event::MetricsTick => match s { \"x\" => 1, _ => 0 },\n        Event::ControllerTick => 2,\n    }\n}\n";
        let s = scan(src);
        let refs: Vec<&Token> = s.tokens.iter().filter(|t| !t.in_test).collect();
        let mut fs = Vec::new();
        check_e01("sim/mod.rs", &s, &refs, &mut fs);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn e01_exempts_guarded_wildcard_flags_bare_one() {
        // `_ if n > 0` doesn't count toward exhaustiveness (the compiler
        // still forces the rest to cover every variant), so only the bare
        // `_ =>` arm fires.
        let src = "fn f(e: Event, n: u32) -> u32 {\n    match e {\n        Event::MetricsTick => 1,\n        _ if n > 0 => 2,\n        _ => 0,\n    }\n}\n";
        let s = scan(src);
        let refs: Vec<&Token> = s.tokens.iter().filter(|t| !t.in_test).collect();
        let mut fs = Vec::new();
        check_e01("sim/mod.rs", &s, &refs, &mut fs);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].line, 5);
    }

    #[test]
    fn p01_flags_names_missing_from_surface_and_docs() {
        let src = "impl ChaosConfig {\n    pub fn profile_names() -> &'static [&'static str] {\n        &[\"none\", \"light\", \"storm\"]\n    }\n    pub fn profile(name: &str) -> u32 {\n        match name { \"none\" => 0, \"light\" => 1, _ => 2 }\n    }\n}\n";
        let files = vec![("config/mod.rs".to_string(), scan(src))];
        let m = model_of(&files);
        let docs = vec![(
            "README.md".to_string(),
            "profiles: none, light, storm".to_string(),
        )];
        let fs = check_p01(&files, &m, &docs);
        // "storm" is defined but absent from ChaosConfig::profile.
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].message.contains("storm"), "{fs:?}");
        assert!(fs[0].message.contains("ChaosConfig::profile"), "{fs:?}");

        // And a doc gap is its own finding.
        let docs2 = vec![("README.md".to_string(), "profiles: none, storm".to_string())];
        let fs2 = check_p01(&files, &m, &docs2);
        assert!(
            fs2.iter().any(|f| f.message.contains("'light'")
                && f.message.contains("README.md")),
            "{fs2:?}"
        );
    }
}
