//! simlint — the in-repo determinism & invariant static-analysis pass.
//!
//! The simulator's headline guarantee is byte-identical output for a given
//! seed at any worker count. That contract is easy to break silently: one
//! `HashMap` iteration feeding a report, one `Instant::now` leaking into
//! simulated time, and outputs differ across runs while every test still
//! passes. simlint polices those hazards *statically*, in CI, with zero
//! external dependencies — the scanner and rules live in this crate (no
//! `syn`, no registry crates) so the lint gates the tree even in offline
//! environments.
//!
//! Rule set (see [`rules`] for the rationale of each):
//!
//! | ID  | Scope        | Hazard                                           |
//! |-----|--------------|--------------------------------------------------|
//! | D01 | core modules | std `HashMap`/`HashSet` (SipHash, random key)    |
//! | D02 | everywhere¹  | `Instant::now` / `SystemTime` ambient clocks     |
//! | D03 | everywhere²  | entropy-seeded randomness                        |
//! | D04 | core modules | iteration over hash-based containers             |
//! | S01 | core modules | `unwrap`/`expect`/`panic!` without justification |
//!
//! ¹ except `util/bench.rs`, `util/logging.rs`, `benches/`.
//! ² except `util/rng.rs`, the sanctioned seeded-RNG home.
//!
//! Suppression is two-tier:
//!
//! * **Inline**: `// simlint: allow(S01) — <reason>` on the offending line
//!   or in the comment block directly above it. The reason is mandatory —
//!   a directive without one does not suppress. This is the preferred tier:
//!   the justification lives next to the code it justifies.
//! * **Baseline**: `rust/simlint.allow` grandfathers pre-existing findings
//!   (see [`baseline`]). Regenerated with `simlint --update-baseline`. The
//!   tree currently carries an **empty** baseline: every core-module
//!   finding has been fixed or inline-justified.

pub mod baseline;
pub mod rules;
pub mod scanner;

use std::path::Path;

/// Machine-readable rule identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RuleId {
    D01,
    D02,
    D03,
    D04,
    S01,
}

impl RuleId {
    pub const ALL: [RuleId; 5] = [
        RuleId::D01,
        RuleId::D02,
        RuleId::D03,
        RuleId::D04,
        RuleId::S01,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            RuleId::D01 => "D01",
            RuleId::D02 => "D02",
            RuleId::D03 => "D03",
            RuleId::D04 => "D04",
            RuleId::S01 => "S01",
        }
    }

    pub fn parse(s: &str) -> Option<RuleId> {
        match s.trim() {
            "D01" => Some(RuleId::D01),
            "D02" => Some(RuleId::D02),
            "D03" => Some(RuleId::D03),
            "D04" => Some(RuleId::D04),
            "S01" => Some(RuleId::S01),
            _ => None,
        }
    }

    /// One-line fix hint, shown with every finding.
    pub fn fix_hint(self) -> &'static str {
        match self {
            RuleId::D01 => {
                "use util::fxhash::FxHashMap/FxHashSet, or BTreeMap/BTreeSet for ordered data"
            }
            RuleId::D02 => {
                "take time from the event queue; wall-clock only in util/bench.rs, util/logging.rs, benches/"
            }
            RuleId::D03 => "use util::rng::Rng::new(seed) — every random stream is seeded",
            RuleId::D04 => {
                "collect keys and sort before enumerating, or collect into a BTreeMap"
            }
            RuleId::S01 => {
                "handle the error, or add `// simlint: allow(S01) — <invariant>` stating why it cannot fire"
            }
        }
    }
}

/// One lint finding, with everything needed to render, baseline, or gate.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: RuleId,
    /// Path as scanned (root prefix included), `/`-separated.
    pub path: String,
    /// 1-based source line.
    pub line: u32,
    /// 1-based character column.
    pub col: u32,
    pub message: String,
    /// Trimmed content of the offending line — the baseline key.
    pub line_text: String,
}

impl Finding {
    /// Render as `RULE path:line:col message` plus a fix-hint line.
    pub fn render(&self) -> String {
        format!(
            "{} {}:{}:{} {}\n    = {}\n    help: {}",
            self.rule.as_str(),
            self.path,
            self.line,
            self.col,
            self.message,
            self.line_text,
            self.rule.fix_hint()
        )
    }
}

/// A parsed `simlint: allow(…)` directive from one comment line.
#[derive(Debug)]
struct AllowDirective {
    rules: Vec<RuleId>,
    /// A directive must carry a justification to suppress anything.
    has_reason: bool,
}

/// Parse a line-comment text (the part after `//`) as an allow directive.
/// Returns `None` for comments that are not directives *and* for malformed
/// directives (unknown rule id, missing parentheses) — malformed directives
/// must not suppress.
fn parse_allow(comment: &str) -> Option<AllowDirective> {
    let t = comment.trim_start();
    let rest = t.strip_prefix("simlint:")?.trim_start();
    let rest = rest.strip_prefix("allow")?.trim_start();
    let rest = rest.strip_prefix('(')?;
    let close = rest.find(')')?;
    let mut rules = Vec::new();
    for part in rest[..close].split(',') {
        rules.push(RuleId::parse(part)?);
    }
    if rules.is_empty() {
        return None;
    }
    // Reason: whatever follows the `)`, minus connective punctuation
    // (em/en dashes, hyphens, colons). Require a little substance.
    let after: String = rest[close + 1..]
        .chars()
        .filter(|c| !matches!(c, '—' | '–' | '-' | ':' | ' ' | '\t'))
        .collect();
    Some(AllowDirective {
        rules,
        has_reason: after.chars().count() >= 3,
    })
}

/// Is the finding at `line` covered by an inline allow directive — on the
/// line itself, or in the contiguous pure-comment block directly above it?
fn allowed(scan: &scanner::ScanResult, rule: RuleId, line: u32) -> bool {
    let covers = |l: u32| {
        scan.line_comments
            .iter()
            .filter(|(cl, _)| *cl == l)
            .filter_map(|(_, text)| parse_allow(text))
            .any(|d| d.has_reason && d.rules.contains(&rule))
    };
    if covers(line) {
        return true;
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 && scan.pure_comment_lines.contains(&l) {
        if covers(l) {
            return true;
        }
        l -= 1;
    }
    false
}

/// Scan one file's source, returning findings **after** inline-allow
/// filtering (the baseline is applied by the caller, typically the CLI).
/// `path` is used both for rule scoping (core module? exempt file?) and as
/// the `Finding::path`; tests pass virtual paths like `coordinator/mod.rs`.
pub fn scan_source(path: &str, source: &str) -> Vec<Finding> {
    let scan = scanner::scan(source);
    rules::check(path, &scan)
        .into_iter()
        .filter(|f| !allowed(&scan, f.rule, f.line))
        .collect()
}

/// Recursively scan every `.rs` file under `root`. Files are visited in
/// sorted path order so output (and baselines) are deterministic. Paths in
/// findings are `root`-prefixed and `/`-separated.
pub fn scan_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in files {
        let source = std::fs::read_to_string(&path)?;
        let rel = path.to_string_lossy().replace('\\', "/");
        findings.extend(scan_source(&rel, &source));
    }
    Ok(findings)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_allow_accepts_well_formed() {
        let d = parse_allow(" simlint: allow(S01) — registry lock poisoned").unwrap();
        assert_eq!(d.rules, vec![RuleId::S01]);
        assert!(d.has_reason);
        let d = parse_allow(" simlint: allow(D01, D04) — pre-sorted before use").unwrap();
        assert_eq!(d.rules, vec![RuleId::D01, RuleId::D04]);
    }

    #[test]
    fn parse_allow_rejects_malformed() {
        assert!(parse_allow(" simlint: allow(S99) — bogus rule").is_none());
        assert!(parse_allow(" simlint: allow S01 — no parens").is_none());
        assert!(parse_allow(" just a comment mentioning simlint").is_none());
        // Well-formed but reasonless: parses, but must not suppress.
        let d = parse_allow(" simlint: allow(S01)").unwrap();
        assert!(!d.has_reason);
        let d = parse_allow(" simlint: allow(S01) — ").unwrap();
        assert!(!d.has_reason);
    }

    #[test]
    fn allow_on_same_line_suppresses() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap() // simlint: allow(S01) — caller checked is_some\n}\n";
        assert!(scan_source("sim/mod.rs", src).is_empty());
    }

    #[test]
    fn allow_in_comment_block_above_suppresses() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    // simlint: allow(S01) — caller checked is_some, and the check is\n    // load-bearing for admission control\n    x.unwrap()\n}\n";
        assert!(scan_source("sim/mod.rs", src).is_empty());
    }

    #[test]
    fn reasonless_allow_does_not_suppress() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    // simlint: allow(S01)\n    x.unwrap()\n}\n";
        let fs = scan_source("sim/mod.rs", src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, RuleId::S01);
    }

    #[test]
    fn wrong_rule_allow_does_not_suppress() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    // simlint: allow(D01) — wrong rule entirely\n    x.unwrap()\n}\n";
        assert_eq!(scan_source("sim/mod.rs", src).len(), 1);
    }

    #[test]
    fn findings_carry_span_and_hint() {
        let src = "use std::collections::HashMap;\n";
        let fs = scan_source("router/mod.rs", src);
        assert_eq!(fs.len(), 1);
        let f = &fs[0];
        assert_eq!(f.rule, RuleId::D01);
        assert_eq!(f.line, 1);
        assert_eq!(f.col, 23);
        assert_eq!(f.line_text, "use std::collections::HashMap;");
        assert!(f.render().contains("help: "));
    }
}
