//! simlint — the in-repo determinism & invariant static-analysis pass.
//!
//! The simulator's headline guarantee is byte-identical output for a given
//! seed at any worker count. That contract is easy to break silently: one
//! `HashMap` iteration feeding a report, one `Instant::now` leaking into
//! simulated time, and outputs differ across runs while every test still
//! passes. simlint polices those hazards *statically*, in CI, with zero
//! external dependencies — the scanner and rules live in this crate (no
//! `syn`, no registry crates) so the lint gates the tree even in offline
//! environments.
//!
//! Rule set (see [`rules`] for the rationale of each):
//!
//! | ID  | Scope        | Hazard                                           |
//! |-----|--------------|--------------------------------------------------|
//! | D01 | core modules | std `HashMap`/`HashSet` (SipHash, random key)    |
//! | D02 | everywhere¹  | `Instant::now` / `SystemTime` ambient clocks     |
//! | D03 | everywhere²  | entropy-seeded randomness                        |
//! | D04 | core modules | iteration over hash-based containers             |
//! | S01 | core modules | `unwrap`/`expect`/`panic!` without justification |
//! | H01 | hot set³     | allocation constructors on the event hot path    |
//! | H02 | hot set³     | `.clone()` of `Request`/batch-state values       |
//! | E01 | core modules | wildcard `_ =>` arm in a match over a core enum  |
//! | P01 | cross-file   | registered name missing from surfaces/docs       |
//!
//! ¹ except `util/bench.rs`, `util/logging.rs`, `benches/`.
//! ² except `util/rng.rs`, the sanctioned seeded-RNG home.
//! ³ functions reachable from [`flow::HOT_ROOTS`] in the call graph; see
//!   [`flow`] for construction and the `// simlint: cold — <reason>`
//!   opt-out for cold-by-design functions.
//!
//! Suppression is two-tier:
//!
//! * **Inline**: `// simlint: allow(S01) — <reason>` on the offending line
//!   or in the comment block directly above it. The reason is mandatory —
//!   a directive without one does not suppress. This is the preferred tier:
//!   the justification lives next to the code it justifies.
//! * **Baseline**: `rust/simlint.allow` grandfathers pre-existing findings
//!   (see [`baseline`]). Regenerated with `simlint --update-baseline`. The
//!   tree currently carries an **empty** baseline: every core-module
//!   finding has been fixed or inline-justified.

pub mod baseline;
pub mod flow;
pub mod rules;
pub mod scanner;

use std::path::Path;

/// Machine-readable rule identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RuleId {
    D01,
    D02,
    D03,
    D04,
    S01,
    H01,
    H02,
    E01,
    P01,
}

impl RuleId {
    pub const ALL: [RuleId; 9] = [
        RuleId::D01,
        RuleId::D02,
        RuleId::D03,
        RuleId::D04,
        RuleId::S01,
        RuleId::H01,
        RuleId::H02,
        RuleId::E01,
        RuleId::P01,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            RuleId::D01 => "D01",
            RuleId::D02 => "D02",
            RuleId::D03 => "D03",
            RuleId::D04 => "D04",
            RuleId::S01 => "S01",
            RuleId::H01 => "H01",
            RuleId::H02 => "H02",
            RuleId::E01 => "E01",
            RuleId::P01 => "P01",
        }
    }

    pub fn parse(s: &str) -> Option<RuleId> {
        match s.trim() {
            "D01" => Some(RuleId::D01),
            "D02" => Some(RuleId::D02),
            "D03" => Some(RuleId::D03),
            "D04" => Some(RuleId::D04),
            "S01" => Some(RuleId::S01),
            "H01" => Some(RuleId::H01),
            "H02" => Some(RuleId::H02),
            "E01" => Some(RuleId::E01),
            "P01" => Some(RuleId::P01),
            _ => None,
        }
    }

    /// One-line fix hint, shown with every finding.
    pub fn fix_hint(self) -> &'static str {
        match self {
            RuleId::D01 => {
                "use util::fxhash::FxHashMap/FxHashSet, or BTreeMap/BTreeSet for ordered data"
            }
            RuleId::D02 => {
                "take time from the event queue; wall-clock only in util/bench.rs, util/logging.rs, benches/"
            }
            RuleId::D03 => "use util::rng::Rng::new(seed) — every random stream is seeded",
            RuleId::D04 => {
                "collect keys and sort before enumerating, or collect into a BTreeMap"
            }
            RuleId::S01 => {
                "handle the error, or add `// simlint: allow(S01) — <invariant>` stating why it cannot fire"
            }
            RuleId::H01 => {
                "hoist the allocation out of the hot path (reuse a scratch buffer); `// simlint: allow(H01) — <reason>` for amortized sites, `// simlint: cold — <reason>` above cold-by-design fns"
            }
            RuleId::H02 => {
                "move or borrow the request/batch state instead of cloning it on the hot path"
            }
            RuleId::E01 => {
                "name every variant explicitly so adding one fails this match instead of falling through"
            }
            RuleId::P01 => {
                "add the registered name to the listed companion functions and to README.md/DESIGN.md"
            }
        }
    }
}

/// One lint finding, with everything needed to render, baseline, or gate.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: RuleId,
    /// Path as scanned (root prefix included), `/`-separated.
    pub path: String,
    /// 1-based source line.
    pub line: u32,
    /// 1-based character column.
    pub col: u32,
    pub message: String,
    /// Trimmed content of the offending line — the baseline key.
    pub line_text: String,
}

impl Finding {
    /// Stable finding ID: FNV-1a 64 over `(rule, path, line_text)`,
    /// rendered as 16 hex digits. Deliberately *excludes* line/col so the
    /// ID survives unrelated edits above the finding; two identical
    /// offending lines in one file share an ID (they are the same defect).
    pub fn id(&self) -> String {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        for part in [self.rule.as_str(), "\u{1f}", &self.path, "\u{1f}", &self.line_text] {
            for b in part.bytes() {
                h = (h ^ u64::from(b)).wrapping_mul(PRIME);
            }
        }
        format!("{h:016x}")
    }

    /// Render as `RULE path:line:col message` plus a fix-hint line.
    pub fn render(&self) -> String {
        format!(
            "{} {}:{}:{} {}\n    = {}\n    help: {}",
            self.rule.as_str(),
            self.path,
            self.line,
            self.col,
            self.message,
            self.line_text,
            self.rule.fix_hint()
        )
    }
}

/// A parsed `simlint: allow(…)` directive from one comment line.
#[derive(Debug)]
struct AllowDirective {
    rules: Vec<RuleId>,
    /// A directive must carry a justification to suppress anything.
    has_reason: bool,
}

/// Parse a line-comment text (the part after `//`) as an allow directive.
/// Returns `None` for comments that are not directives *and* for malformed
/// directives (unknown rule id, missing parentheses) — malformed directives
/// must not suppress.
fn parse_allow(comment: &str) -> Option<AllowDirective> {
    let t = comment.trim_start();
    let rest = t.strip_prefix("simlint:")?.trim_start();
    let rest = rest.strip_prefix("allow")?.trim_start();
    let rest = rest.strip_prefix('(')?;
    let close = rest.find(')')?;
    let mut rules = Vec::new();
    for part in rest[..close].split(',') {
        rules.push(RuleId::parse(part)?);
    }
    if rules.is_empty() {
        return None;
    }
    // Reason: whatever follows the `)`, minus connective punctuation
    // (em/en dashes, hyphens, colons). Require a little substance.
    let after: String = rest[close + 1..]
        .chars()
        .filter(|c| !matches!(c, '—' | '–' | '-' | ':' | ' ' | '\t'))
        .collect();
    Some(AllowDirective {
        rules,
        has_reason: after.chars().count() >= 3,
    })
}

/// Is the finding at `line` covered by an inline allow directive — on the
/// line itself, or in the contiguous pure-comment block directly above it?
fn allowed(scan: &scanner::ScanResult, rule: RuleId, line: u32) -> bool {
    let covers = |l: u32| {
        scan.line_comments
            .iter()
            .filter(|(cl, _)| *cl == l)
            .filter_map(|(_, text)| parse_allow(text))
            .any(|d| d.has_reason && d.rules.contains(&rule))
    };
    if covers(line) {
        return true;
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 && scan.pure_comment_lines.contains(&l) {
        if covers(l) {
            return true;
        }
        l -= 1;
    }
    false
}

/// Parse a line-comment text as a `simlint: cold — <reason>` directive.
/// Like `allow`, the reason is mandatory: a bare `simlint: cold` marks
/// nothing. The directive must be exactly `cold` followed by a separator
/// (so an identifier like `coldstart` in prose never counts).
fn parse_cold(comment: &str) -> bool {
    let t = comment.trim_start();
    let Some(rest) = t.strip_prefix("simlint:") else {
        return false;
    };
    let Some(rest) = rest.trim_start().strip_prefix("cold") else {
        return false;
    };
    if !rest
        .chars()
        .next()
        .is_some_and(|c| matches!(c, ' ' | '\t' | '—' | '–' | '-' | ':'))
    {
        return false;
    }
    let reason: String = rest
        .chars()
        .filter(|c| !matches!(c, '—' | '–' | '-' | ':' | ' ' | '\t'))
        .collect();
    reason.chars().count() >= 3
}

/// Is the `fn` at `line` marked cold — a `simlint: cold — <reason>`
/// directive in the contiguous comment block directly above it? Attribute
/// lines (`#[inline]`, `#[must_use]`, …) between the block and the `fn`
/// are skipped, so the marker can sit above the attributes.
pub(crate) fn cold_marked(scan: &scanner::ScanResult, line: u32) -> bool {
    let covers = |l: u32| {
        scan.line_comments
            .iter()
            .filter(|(cl, _)| *cl == l)
            .any(|(_, text)| parse_cold(text))
    };
    if covers(line) {
        return true;
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 {
        if scan.pure_comment_lines.contains(&l) {
            if covers(l) {
                return true;
            }
        } else if !scan.line_text(l).starts_with("#[") {
            return false;
        }
        l -= 1;
    }
    false
}

/// Scan one file's source with the per-file rules (D/S/E families),
/// returning findings **after** inline-allow filtering (the baseline is
/// applied by the caller, typically the CLI). The cross-file families
/// (H01/H02/P01) need the whole scanned set — see [`analyze_sources`].
/// `path` is used both for rule scoping (core module? exempt file?) and as
/// the `Finding::path`; tests pass virtual paths like `coordinator/mod.rs`.
pub fn scan_source(path: &str, source: &str) -> Vec<Finding> {
    let scan = scanner::scan(source);
    rules::check(path, &scan)
        .into_iter()
        .filter(|f| !allowed(&scan, f.rule, f.line))
        .collect()
}

/// Full analysis over a set of in-memory sources: the per-file rules plus
/// the flow-aware families (H01/H02 over the call-graph hot set, P01
/// registry/doc consistency). `docs` are `(name, content)` pairs for
/// README.md / DESIGN.md; pass `&[]` to skip the doc surface. Findings are
/// inline-allow filtered and sorted by `(path, line, col, rule)`.
pub fn analyze_sources(files: &[(String, String)], docs: &[(String, String)]) -> Vec<Finding> {
    let scanned: Vec<(String, scanner::ScanResult)> = files
        .iter()
        .map(|(p, src)| (p.clone(), scanner::scan(src)))
        .collect();

    let mut findings = Vec::new();
    for (path, scan) in &scanned {
        findings.extend(
            rules::check(path, scan)
                .into_iter()
                .filter(|f| !allowed(scan, f.rule, f.line)),
        );
    }

    let model = flow::FlowModel::build(&scanned);
    let mut cross = flow::check_hot(&scanned, &model);
    cross.extend(flow::check_p01(&scanned, &model, docs));
    for f in cross {
        let covered = scanned
            .iter()
            .find(|(p, _)| *p == f.path)
            .is_some_and(|(_, scan)| allowed(scan, f.rule, f.line));
        if !covered {
            findings.push(f);
        }
    }

    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.col, a.rule).cmp(&(b.path.as_str(), b.line, b.col, b.rule))
    });
    findings
}

/// Full analysis over paths (directories are walked for `.rs` files).
/// README.md/DESIGN.md are discovered by walking up from the first root to
/// the nearest directory containing **both** — the repo root — so the P01
/// doc surface is active for tree scans and absent for loose-file scans
/// outside a checkout.
pub fn analyze_paths(roots: &[std::path::PathBuf]) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for root in roots {
        if root.is_dir() {
            collect_rs_files(root, &mut files)?;
        } else {
            files.push(root.clone());
        }
    }
    files.sort();
    files.dedup();
    let sources: Vec<(String, String)> = files
        .iter()
        .map(|p| {
            let src = std::fs::read_to_string(p)?;
            Ok((p.to_string_lossy().replace('\\', "/"), src))
        })
        .collect::<std::io::Result<_>>()?;
    Ok(analyze_sources(&sources, &discover_docs(roots)))
}

fn discover_docs(roots: &[std::path::PathBuf]) -> Vec<(String, String)> {
    let Some(first) = roots.first() else {
        return Vec::new();
    };
    let start = first.canonicalize().unwrap_or_else(|_| first.clone());
    let mut cur = if start.is_dir() {
        Some(start.as_path())
    } else {
        start.parent()
    };
    while let Some(d) = cur {
        let readme = d.join("README.md");
        let design = d.join("DESIGN.md");
        if readme.is_file() && design.is_file() {
            let mut out = Vec::new();
            for p in [readme, design] {
                if let (Some(name), Ok(content)) = (p.file_name(), std::fs::read_to_string(&p)) {
                    out.push((name.to_string_lossy().into_owned(), content));
                }
            }
            return out;
        }
        cur = d.parent();
    }
    Vec::new()
}

/// Recursively scan every `.rs` file under `root` with the **full**
/// analysis (per-file + flow-aware rules, docs discovered upward). Files
/// are visited in sorted path order so output (and baselines) are
/// deterministic. Paths in findings are `root`-prefixed, `/`-separated.
pub fn scan_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    analyze_paths(&[root.to_path_buf()])
}

/// Render findings as the `--format json` report: a stable, sorted-key
/// document built on [`crate::util::json`], so `parse → to_string`
/// round-trips byte-identically.
pub fn report_json(findings: &[Finding]) -> String {
    use crate::util::json::{Number, Value};
    use std::collections::BTreeMap;
    let arr = findings
        .iter()
        .map(|f| {
            let mut o = BTreeMap::new();
            o.insert("id".to_string(), Value::Str(f.id()));
            o.insert("rule".to_string(), Value::Str(f.rule.as_str().to_string()));
            o.insert("path".to_string(), Value::Str(f.path.clone()));
            o.insert("line".to_string(), Value::Num(Number::Int(i64::from(f.line))));
            o.insert("col".to_string(), Value::Num(Number::Int(i64::from(f.col))));
            o.insert("message".to_string(), Value::Str(f.message.clone()));
            o.insert("line_text".to_string(), Value::Str(f.line_text.clone()));
            o.insert("help".to_string(), Value::Str(f.rule.fix_hint().to_string()));
            Value::Obj(o)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("schema".to_string(), Value::Str("simlint/v2".to_string()));
    root.insert(
        "finding_count".to_string(),
        Value::Num(Number::Int(findings.len() as i64)),
    );
    root.insert("findings".to_string(), Value::Arr(arr));
    Value::Obj(root).to_string()
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_allow_accepts_well_formed() {
        let d = parse_allow(" simlint: allow(S01) — registry lock poisoned").unwrap();
        assert_eq!(d.rules, vec![RuleId::S01]);
        assert!(d.has_reason);
        let d = parse_allow(" simlint: allow(D01, D04) — pre-sorted before use").unwrap();
        assert_eq!(d.rules, vec![RuleId::D01, RuleId::D04]);
    }

    #[test]
    fn parse_allow_rejects_malformed() {
        assert!(parse_allow(" simlint: allow(S99) — bogus rule").is_none());
        assert!(parse_allow(" simlint: allow S01 — no parens").is_none());
        assert!(parse_allow(" just a comment mentioning simlint").is_none());
        // Well-formed but reasonless: parses, but must not suppress.
        let d = parse_allow(" simlint: allow(S01)").unwrap();
        assert!(!d.has_reason);
        let d = parse_allow(" simlint: allow(S01) — ").unwrap();
        assert!(!d.has_reason);
    }

    #[test]
    fn allow_on_same_line_suppresses() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap() // simlint: allow(S01) — caller checked is_some\n}\n";
        assert!(scan_source("sim/mod.rs", src).is_empty());
    }

    #[test]
    fn allow_in_comment_block_above_suppresses() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    // simlint: allow(S01) — caller checked is_some, and the check is\n    // load-bearing for admission control\n    x.unwrap()\n}\n";
        assert!(scan_source("sim/mod.rs", src).is_empty());
    }

    #[test]
    fn reasonless_allow_does_not_suppress() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    // simlint: allow(S01)\n    x.unwrap()\n}\n";
        let fs = scan_source("sim/mod.rs", src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, RuleId::S01);
    }

    #[test]
    fn wrong_rule_allow_does_not_suppress() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    // simlint: allow(D01) — wrong rule entirely\n    x.unwrap()\n}\n";
        assert_eq!(scan_source("sim/mod.rs", src).len(), 1);
    }

    #[test]
    fn parse_cold_requires_reason_and_separator() {
        assert!(parse_cold(" simlint: cold — debug dump, never on the event path"));
        assert!(parse_cold("simlint: cold: teardown"));
        assert!(!parse_cold(" simlint: cold"));
        assert!(!parse_cold(" simlint: cold — "));
        assert!(!parse_cold(" simlint: coldstart path"));
        assert!(!parse_cold(" just mentions cold"));
    }

    #[test]
    fn cold_marker_skips_attribute_lines() {
        let src = "// simlint: cold — diagnostics only\n#[inline(never)]\nfn dump() {}\nfn live() {}\n";
        let scan = scanner::scan(src);
        assert!(cold_marked(&scan, 3));
        assert!(!cold_marked(&scan, 4));
    }

    #[test]
    fn finding_id_is_stable_and_position_independent() {
        let mk = |line| Finding {
            rule: RuleId::H01,
            path: "sim/mod.rs".to_string(),
            line,
            col: 9,
            message: "msg".to_string(),
            line_text: "let v = Vec::new();".to_string(),
        };
        let a = mk(10);
        let b = mk(99);
        assert_eq!(a.id(), b.id(), "id must survive line drift");
        assert_eq!(a.id().len(), 16);
        assert!(a.id().chars().all(|c| c.is_ascii_hexdigit()));
        let mut c = mk(10);
        c.rule = RuleId::H02;
        assert_ne!(a.id(), c.id());
    }

    #[test]
    fn json_report_round_trips() {
        let src = "use std::collections::HashMap;\n";
        let fs = scan_source("router/mod.rs", src);
        let rendered = report_json(&fs);
        let parsed = crate::util::json::parse(&rendered).expect("report parses");
        assert_eq!(parsed.to_string(), rendered, "sorted-key doc round-trips");
        assert!(rendered.contains("\"schema\": \"simlint/v2\""));
        assert!(rendered.contains("\"rule\": \"D01\""));
    }

    #[test]
    fn analyze_sources_runs_flow_rules_and_respects_allows() {
        let hot = "impl Simulation {\n    fn handle_event(&mut self) {\n        let a: Vec<u32> = Vec::new();\n        let b: Vec<u32> = Vec::new(); // simlint: allow(H01) — amortized scratch, cleared not dropped\n    }\n}\n";
        let fs = analyze_sources(
            &[("coordinator/mod.rs".to_string(), hot.to_string())],
            &[],
        );
        let h01: Vec<&Finding> = fs.iter().filter(|f| f.rule == RuleId::H01).collect();
        assert_eq!(h01.len(), 1, "{fs:?}");
        assert_eq!(h01[0].line, 3);
    }

    #[test]
    fn findings_carry_span_and_hint() {
        let src = "use std::collections::HashMap;\n";
        let fs = scan_source("router/mod.rs", src);
        assert_eq!(fs.len(), 1);
        let f = &fs[0];
        assert_eq!(f.rule, RuleId::D01);
        assert_eq!(f.line, 1);
        assert_eq!(f.col, 23);
        assert_eq!(f.line_text, "use std::collections::HashMap;");
        assert!(f.render().contains("help: "));
    }
}
