//! Baseline (grandfather) file support.
//!
//! A baseline entry suppresses one finding without touching the source. The
//! format is deliberately line-diff-friendly and content-addressed:
//!
//! ```text
//! RULE<TAB>path<TAB>trimmed source line
//! ```
//!
//! Keying on the *trimmed line content* rather than the line number means
//! unrelated edits above a grandfathered finding do not invalidate the
//! baseline, while any edit to the offending line itself (including fixing
//! it) does — stale entries are then just dead lines that the next
//! `--update-baseline` drops.
//!
//! `#`-prefixed lines and blank lines are comments. Entries are kept
//! sorted, and [`format_baseline`] is the single serializer, so
//! `--update-baseline` round-trips byte-identically.

use super::Finding;
use std::collections::BTreeSet;

/// One suppression key: `(rule, path, trimmed line)`.
type Entry = (String, String, String);

/// Parsed baseline: a set of suppression keys.
#[derive(Debug, Default, Clone)]
pub struct Baseline {
    entries: BTreeSet<Entry>,
}

impl Baseline {
    /// Parse baseline text. Malformed lines (fewer than three tab-separated
    /// fields) are ignored rather than fatal: a corrupt entry merely fails
    /// to suppress, which is the safe direction.
    pub fn parse(text: &str) -> Baseline {
        let mut entries = BTreeSet::new();
        for raw in text.lines() {
            let line = raw.trim_end();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.splitn(3, '\t');
            if let (Some(rule), Some(path), Some(text)) =
                (parts.next(), parts.next(), parts.next())
            {
                entries.insert((rule.to_string(), path.to_string(), text.to_string()));
            }
        }
        Baseline { entries }
    }

    pub fn contains(&self, f: &Finding) -> bool {
        // Allocation-free probe would need Borrow on the tuple; a lint pass
        // over a few hundred files does not care.
        self.entries.contains(&(
            f.rule.as_str().to_string(),
            f.path.clone(),
            f.line_text.clone(),
        ))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize this baseline back to text — byte-identical with the
    /// output of [`format_baseline`] for the same entry set.
    pub fn render(&self) -> String {
        render_entries(self.entries.iter())
    }
}

const HEADER: &str = "\
# simlint baseline — grandfathered findings, one per line:
#   RULE<TAB>path<TAB>trimmed source line
# Entries suppress exactly one finding each; fixing the offending line
# orphans its entry. Regenerate with:
#   cargo run --manifest-path rust/Cargo.toml --bin simlint -- --check rust/src --update-baseline
";

fn render_entries<'a, I: Iterator<Item = &'a Entry>>(entries: I) -> String {
    let mut out = String::from(HEADER);
    for (rule, path, text) in entries {
        out.push_str(rule);
        out.push('\t');
        out.push_str(path);
        out.push('\t');
        out.push_str(text);
        out.push('\n');
    }
    out
}

/// Serialize a finding list as a fresh baseline (sorted, deduplicated).
pub fn format_baseline(findings: &[Finding]) -> String {
    let entries: BTreeSet<Entry> = findings
        .iter()
        .map(|f| {
            (
                f.rule.as_str().to_string(),
                f.path.clone(),
                f.line_text.clone(),
            )
        })
        .collect();
    render_entries(entries.iter())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::RuleId;

    fn finding(rule: RuleId, path: &str, text: &str) -> Finding {
        Finding {
            rule,
            path: path.to_string(),
            line: 1,
            col: 1,
            message: String::new(),
            line_text: text.to_string(),
        }
    }

    #[test]
    fn round_trip_is_byte_identical() {
        let fs = vec![
            finding(RuleId::S01, "rust/src/sim/mod.rs", "x.unwrap();"),
            finding(RuleId::D01, "rust/src/router/mod.rs", "use std::collections::HashMap;"),
        ];
        let once = format_baseline(&fs);
        let twice = Baseline::parse(&once).render();
        assert_eq!(once, twice);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let b = Baseline::parse("# header\n\nD01\tp.rs\tuse foo;\n");
        assert_eq!(b.len(), 1);
        assert!(!b.is_empty());
    }

    #[test]
    fn contains_matches_on_content_not_line_number() {
        let b = Baseline::parse("S01\ta/b.rs\tx.unwrap();\n");
        let mut f = finding(RuleId::S01, "a/b.rs", "x.unwrap();");
        f.line = 999;
        assert!(b.contains(&f));
        let g = finding(RuleId::S01, "a/b.rs", "y.unwrap();");
        assert!(!b.contains(&g));
    }

    #[test]
    fn malformed_entries_do_not_suppress() {
        let b = Baseline::parse("S01 a/b.rs x.unwrap();\n");
        assert!(b.is_empty());
    }

    #[test]
    fn empty_baseline_renders_header_only() {
        let b = Baseline::default();
        assert_eq!(b.render(), super::HEADER);
    }
}
