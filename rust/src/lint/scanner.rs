//! Character-level scanner for `.rs` source.
//!
//! simlint deliberately does not depend on a real Rust parser (`syn` would
//! be a registry dependency; rustc internals are unstable). Instead this
//! module runs a small character state machine that understands just enough
//! lexical structure to be trustworthy:
//!
//! * strings (plain, raw `r#"…"#`, byte, byte-raw), char literals vs
//!   lifetimes, nested block comments — all stripped, so `"HashMap"` in a
//!   string or comment never fires a rule;
//! * a **whole-file token stream** with line/column positions — rules match
//!   token patterns (e.g. `.` `unwrap` `(`), so method chains split across
//!   lines are matched exactly like single-line calls;
//! * line comments captured per line, so `// simlint: allow(…)` directives
//!   can be resolved against findings;
//! * `#[cfg(test)]` items marked so rules skip test-only code (the brace
//!   depth of the item body is tracked on the token stream).

use std::collections::BTreeSet;

/// Token classes the rules care about. Anything that is not an identifier,
/// a number, or a string literal comes through as a single-character punct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    Ident,
    Num,
    /// String literal; `text` is the *inner* content (quotes stripped,
    /// escapes kept verbatim). Emitted so cross-file consistency rules
    /// (P01) can read registered names — identifier/punct adjacency
    /// patterns are unaffected because a string can never sit inside one.
    Str,
    Punct,
}

/// One lexical token with its 1-based source position.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
    /// Inside a `#[cfg(test)]` item — rules skip these.
    pub in_test: bool,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokenKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokenKind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }
}

/// Everything rules and the allow-directive resolver need about one file.
#[derive(Debug, Default)]
pub struct ScanResult {
    pub tokens: Vec<Token>,
    /// `(line, text-after-`//`)` for every line comment in the file.
    pub line_comments: Vec<(u32, String)>,
    /// Lines whose only non-whitespace content is a comment.
    pub pure_comment_lines: BTreeSet<u32>,
    /// Raw source split into lines (for baseline keys and rendering).
    pub source_lines: Vec<String>,
}

impl ScanResult {
    /// Trimmed text of a 1-based source line (empty if out of range).
    pub fn line_text(&self, line: u32) -> &str {
        self.source_lines
            .get(line as usize - 1)
            .map(|s| s.trim())
            .unwrap_or("")
    }
}

/// Scan one file into tokens + comment metadata.
pub fn scan(source: &str) -> ScanResult {
    let chars: Vec<char> = source.chars().collect();
    let mut out = ScanResult {
        source_lines: source.lines().map(str::to_string).collect(),
        ..ScanResult::default()
    };

    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut col: u32 = 1;
    // Whether the current line has any non-comment, non-whitespace content
    // so far / any comment content — used for pure-comment-line detection.
    let mut line_has_code = false;
    let mut line_has_comment = false;

    macro_rules! bump {
        () => {{
            if chars[i] == '\n' {
                if line_has_comment && !line_has_code {
                    out.pure_comment_lines.insert(line);
                }
                line += 1;
                col = 1;
                line_has_code = false;
                line_has_comment = false;
            } else {
                col += 1;
            }
            i += 1;
        }};
    }

    while i < chars.len() {
        let c = chars[i];

        // Line comment: capture the text for directive parsing.
        if c == '/' && i + 1 < chars.len() && chars[i + 1] == '/' {
            line_has_comment = true;
            let start = i + 2;
            let mut end = start;
            while end < chars.len() && chars[end] != '\n' {
                end += 1;
            }
            let text: String = chars[start..end].iter().collect();
            out.line_comments.push((line, text));
            while i < end {
                bump!();
            }
            continue;
        }

        // Block comment, possibly nested.
        if c == '/' && i + 1 < chars.len() && chars[i + 1] == '*' {
            line_has_comment = true;
            let mut depth = 1usize;
            bump!();
            bump!();
            while i < chars.len() && depth > 0 {
                if chars[i] == '/' && i + 1 < chars.len() && chars[i + 1] == '*' {
                    depth += 1;
                    bump!();
                    bump!();
                } else if chars[i] == '*' && i + 1 < chars.len() && chars[i + 1] == '/' {
                    depth -= 1;
                    bump!();
                    bump!();
                } else {
                    if chars[i] != '\n' && !chars[i].is_whitespace() {
                        line_has_comment = true;
                    }
                    bump!();
                }
            }
            continue;
        }

        if c == '\n' || c.is_whitespace() {
            bump!();
            continue;
        }

        // String literal — emitted as a `Str` token carrying the inner text.
        if c == '"' {
            line_has_code = true;
            let (tline, tcol) = (line, col);
            let mut text = String::new();
            bump!(); // opening quote
            while i < chars.len() {
                if chars[i] == '\\' && i + 1 < chars.len() {
                    text.push(chars[i]);
                    text.push(chars[i + 1]);
                    bump!();
                    bump!();
                } else if chars[i] == '"' {
                    bump!();
                    break;
                } else {
                    text.push(chars[i]);
                    bump!();
                }
            }
            out.tokens.push(Token {
                kind: TokenKind::Str,
                text,
                line: tline,
                col: tcol,
                in_test: false,
            });
            continue;
        }

        // Char literal vs lifetime.
        if c == '\'' {
            line_has_code = true;
            let next = chars.get(i + 1).copied();
            let after = chars.get(i + 2).copied();
            let is_lifetime = matches!(next, Some(n) if n.is_alphabetic() || n == '_')
                && after != Some('\'');
            if is_lifetime {
                bump!(); // '
                while i < chars.len()
                    && (chars[i].is_alphanumeric() || chars[i] == '_')
                {
                    bump!();
                }
            } else {
                bump!(); // opening '
                if i < chars.len() && chars[i] == '\\' {
                    bump!(); // backslash
                    if i < chars.len() {
                        let esc = chars[i];
                        bump!(); // escape head
                        if esc == 'x' {
                            for _ in 0..2 {
                                if i < chars.len() && chars[i] != '\'' {
                                    bump!();
                                }
                            }
                        } else if esc == 'u' {
                            while i < chars.len() && chars[i] != '\'' {
                                bump!();
                            }
                        }
                    }
                } else if i < chars.len() {
                    bump!(); // the char itself
                }
                if i < chars.len() && chars[i] == '\'' {
                    bump!(); // closing '
                }
            }
            continue;
        }

        // Number (decimal, hex, float tail). Emitted so `.0` tuple access
        // can never be mistaken for a method call.
        if c.is_ascii_digit() {
            line_has_code = true;
            let (tline, tcol) = (line, col);
            let mut text = String::new();
            while i < chars.len()
                && (chars[i].is_ascii_alphanumeric() || chars[i] == '_')
            {
                text.push(chars[i]);
                bump!();
            }
            // Float fraction: digit '.' digit — but not `0.iter()`-style
            // method calls (identifiers after the dot).
            if i + 1 < chars.len()
                && chars[i] == '.'
                && chars[i + 1].is_ascii_digit()
            {
                text.push('.');
                bump!();
                while i < chars.len()
                    && (chars[i].is_ascii_alphanumeric() || chars[i] == '_')
                {
                    text.push(chars[i]);
                    bump!();
                }
            }
            out.tokens.push(Token {
                kind: TokenKind::Num,
                text,
                line: tline,
                col: tcol,
                in_test: false,
            });
            continue;
        }

        // Identifier / keyword — also the entry point for raw strings
        // (`r"…"`, `r#"…"#`, `br"…"`) and byte strings (`b"…"`, `b'…'`).
        if c.is_alphabetic() || c == '_' {
            line_has_code = true;
            let (tline, tcol) = (line, col);
            let mut text = String::new();
            while i < chars.len()
                && (chars[i].is_alphanumeric() || chars[i] == '_')
            {
                text.push(chars[i]);
                bump!();
            }
            let next = chars.get(i).copied();
            let raw_prefix = matches!(text.as_str(), "r" | "br")
                && matches!(next, Some('"') | Some('#'));
            let byte_prefix = text == "b" && matches!(next, Some('"') | Some('\''));
            if raw_prefix {
                // Raw string: count hashes, then scan to `"` + same hashes.
                // Emitted as a `Str` token like plain strings.
                let mut hashes = 0usize;
                while i < chars.len() && chars[i] == '#' {
                    hashes += 1;
                    bump!();
                }
                if i < chars.len() && chars[i] == '"' {
                    let (sline, scol) = (line, col);
                    let mut stext = String::new();
                    bump!(); // opening quote
                    'raw: while i < chars.len() {
                        if chars[i] == '"' {
                            let mut seen = 0usize;
                            let mut j = i + 1;
                            while seen < hashes && j < chars.len() && chars[j] == '#' {
                                seen += 1;
                                j += 1;
                            }
                            if seen == hashes {
                                while i < j {
                                    bump!();
                                }
                                break 'raw;
                            }
                        }
                        stext.push(chars[i]);
                        bump!();
                    }
                    out.tokens.push(Token {
                        kind: TokenKind::Str,
                        text: stext,
                        line: sline,
                        col: scol,
                        in_test: false,
                    });
                }
                continue;
            }
            if byte_prefix {
                // Re-dispatch: leave the quote for the string/char arms.
                continue;
            }
            out.tokens.push(Token {
                kind: TokenKind::Ident,
                text,
                line: tline,
                col: tcol,
                in_test: false,
            });
            continue;
        }

        // Single-character punct.
        line_has_code = true;
        out.tokens.push(Token {
            kind: TokenKind::Punct,
            text: c.to_string(),
            line,
            col,
            in_test: false,
        });
        bump!();
    }
    // Final line (no trailing newline).
    if line_has_comment && !line_has_code {
        out.pure_comment_lines.insert(line);
    }

    mark_test_regions(&mut out.tokens);
    out
}

/// Mark every token belonging to a `#[cfg(test)]` item (attribute through
/// the matching closing brace of the item body) as `in_test`.
fn mark_test_regions(tokens: &mut [Token]) {
    let mut i = 0usize;
    while i < tokens.len() {
        if let Some(attr_end) = cfg_test_attr_end(tokens, i) {
            // Skip any further attributes, then find the item body.
            let mut j = attr_end + 1;
            while j + 1 < tokens.len() && tokens[j].is_punct('#') && tokens[j + 1].is_punct('[') {
                j = match matching_close(tokens, j + 1, '[', ']') {
                    Some(close) => close + 1,
                    None => tokens.len(),
                };
            }
            // Scan to the first `{` (item body) or `;` (no body).
            let mut body = None;
            while j < tokens.len() {
                if tokens[j].is_punct('{') {
                    body = Some(j);
                    break;
                }
                if tokens[j].is_punct(';') {
                    break;
                }
                j += 1;
            }
            if let Some(open) = body {
                let close = matching_close(tokens, open, '{', '}').unwrap_or(tokens.len() - 1);
                for t in &mut tokens[i..=close] {
                    t.in_test = true;
                }
                i = close + 1;
                continue;
            }
            // `#[cfg(test)] mod x;` — mark just the header.
            for t in &mut tokens[i..j.min(tokens.len())] {
                t.in_test = true;
            }
            i = j;
            continue;
        }
        i += 1;
    }
}

/// If tokens at `i` start a `#[cfg(… test …)]` attribute (and not a
/// `not(test)` one), return the index of its closing `]`.
fn cfg_test_attr_end(tokens: &[Token], i: usize) -> Option<usize> {
    if !(tokens[i].is_punct('#')
        && tokens.get(i + 1).is_some_and(|t| t.is_punct('['))
        && tokens.get(i + 2).is_some_and(|t| t.is_ident("cfg")))
    {
        return None;
    }
    let close = matching_close(tokens, i + 1, '[', ']')?;
    let body = &tokens[i + 2..close];
    let has_test = body.iter().any(|t| t.is_ident("test"));
    let has_not = body.iter().any(|t| t.is_ident("not"));
    if has_test && !has_not {
        Some(close)
    } else {
        None
    }
}

/// Index of the punct closing the bracket opened at `open` (which must hold
/// `open_c`), or `None` if unbalanced.
fn matching_close(tokens: &[Token], open: usize, open_c: char, close_c: char) -> Option<usize> {
    let mut depth = 0usize;
    let mut j = open;
    while j < tokens.len() {
        if tokens[j].is_punct(open_c) {
            depth += 1;
        } else if tokens[j].is_punct(close_c) {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
        j += 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        scan(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokenKind::Ident && !t.in_test)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_are_stripped() {
        let src = r##"
            // HashMap in a comment
            /* HashMap /* nested */ still comment */
            let s = "HashMap<String, u32>";
            let r = r#"HashMap"#;
            let c = 'H';
            let lt: &'static str = "x";
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"HashMap".to_string()), "{ids:?}");
        // `'static` is consumed as a lifetime, `str` survives as an ident.
        assert!(ids.contains(&"str".to_string()), "{ids:?}");
    }

    #[test]
    fn method_chain_across_lines_is_one_stream() {
        let src = "let x = map\n    .iter()\n    .count();";
        let toks = scan(src);
        let pat: Vec<&str> = toks.tokens.iter().map(|t| t.text.as_str()).collect();
        let pos = pat.iter().position(|t| *t == "iter").unwrap();
        assert!(toks.tokens[pos - 1].is_punct('.'));
        assert_eq!(toks.tokens[pos].line, 2);
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn live2() {}";
        let toks = scan(src);
        let unwrap_tok = toks.tokens.iter().find(|t| t.is_ident("unwrap")).unwrap();
        assert!(unwrap_tok.in_test);
        let live2 = toks.tokens.iter().find(|t| t.is_ident("live2")).unwrap();
        assert!(!live2.in_test);
    }

    #[test]
    fn not_test_cfg_is_not_marked() {
        let src = "#[cfg(not(test))]\nfn prod() { x.unwrap(); }";
        let toks = scan(src);
        let unwrap_tok = toks.tokens.iter().find(|t| t.is_ident("unwrap")).unwrap();
        assert!(!unwrap_tok.in_test);
    }

    #[test]
    fn pure_comment_lines_are_detected() {
        let src = "let a = 1; // trailing\n// pure\nlet b = 2;";
        let toks = scan(src);
        assert!(!toks.pure_comment_lines.contains(&1));
        assert!(toks.pure_comment_lines.contains(&2));
        assert_eq!(toks.line_comments.len(), 2);
    }

    #[test]
    fn char_escapes_do_not_derail() {
        let src = r"let q = '\''; let u = '\u{1F600}'; let t = map.iter();";
        let toks = scan(src);
        assert!(toks.tokens.iter().any(|t| t.is_ident("iter")));
    }
}
