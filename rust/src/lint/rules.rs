//! The simlint rule set.
//!
//! Every rule polices one way entropy or an unjustified abort can leak into
//! the simulator's byte-determinism contract:
//!
//! * **D01** — `std::collections::HashMap`/`HashSet` in a core simulation
//!   module. SipHash draws a per-process random key, so map behaviour (bucket
//!   order, resize timing) differs run to run. Core code must use
//!   `util::fxhash::{FxHashMap, FxHashSet}` or a `BTreeMap`/`BTreeSet`.
//! * **D02** — ambient clocks (`Instant::now`, `SystemTime`) outside the
//!   sanctioned wall-clock sites (`util/bench.rs`, `util/logging.rs`,
//!   `benches/`). Simulated time comes from the event queue, never the host.
//! * **D03** — entropy-seeded randomness anywhere outside `util/rng.rs`
//!   (`thread_rng`, `OsRng`, `from_entropy`, `RandomState`, …). The
//!   sanctioned path is `util::rng::Rng::new(seed)` with an explicit seed.
//! * **D04** — iteration over a hash-based map/set in a core module. Even
//!   with a fixed hasher, iteration order is an implementation detail, not a
//!   contract; enumeration that can reach a report or JSON must be sorted
//!   (or carry an `allow` explaining why order cannot escape).
//! * **S01** — `unwrap()`/`expect()`/`panic!`-family in core library code
//!   without an inline justification naming the invariant that makes the
//!   abort unreachable (or correct).
//!
//! Rules match the token stream from [`super::scanner`], so multi-line
//! method chains and string/comment contents are handled exactly.

use super::scanner::{ScanResult, Token, TokenKind};
use super::{Finding, RuleId};
use std::collections::BTreeSet;

/// Module prefixes (under `src/`) that form the deterministic simulation
/// core. D01/D04/S01/E01 apply only here; D02/D03 apply everywhere.
/// `config` and `model` are included because preset resolution and the
/// operator vocabulary feed the determinism contract (a hash-ordered
/// enumeration or unjustified abort there reaches reports just the same).
pub const CORE_MODULES: &[&str] = &[
    "cluster",
    "config",
    "coordinator",
    "instance",
    "memory",
    "metrics",
    "model",
    "network",
    "perf",
    "policy",
    "router",
    "sim",
    "sweep",
    "workload",
];

/// Files allowed to touch the host wall clock.
const D02_EXEMPT: &[&str] = &["util/bench.rs", "util/logging.rs"];

/// Identifiers whose mere appearance means entropy-seeded randomness.
const D03_IDENTS: &[&str] = &[
    "thread_rng",
    "ThreadRng",
    "OsRng",
    "StdRng",
    "SmallRng",
    "from_entropy",
    "RandomState",
    "DefaultHasher",
    "getrandom",
];

/// Hash-backed container type names for the D04 symbol table. `SeqMap` is a
/// crate-level alias for `FxHashMap<u64, SeqState>`; a single-file scanner
/// cannot resolve cross-file aliases, so it is listed explicitly.
const HASH_TYPES: &[&str] = &["FxHashMap", "FxHashSet", "HashMap", "HashSet", "SeqMap"];

/// Methods that enumerate a map in hash order.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "values",
    "values_mut",
    "drain",
];

/// Path of the file relative to the crate's `src/` directory: everything
/// after the last `src` component, or the path unchanged when there is none
/// (fixtures pass virtual paths like `coordinator/mod.rs` directly).
/// Separators are normalized to `/` first, so scoping and the `D02_EXEMPT`
/// comparisons behave identically on Windows checkouts that hand simlint
/// `\`-separated paths.
pub fn module_rel(path: &str) -> String {
    let norm = path.replace('\\', "/");
    let mut rel_start = 0usize;
    let mut rest = 0usize;
    while let Some(pos) = norm[rest..].find("src/") {
        let abs = rest + pos;
        let at_boundary = abs == 0 || norm.as_bytes()[abs - 1] == b'/';
        if at_boundary {
            rel_start = abs + 4;
        }
        rest = abs + 4;
    }
    norm[rel_start..].to_string()
}

fn first_segment(rel: &str) -> &str {
    rel.split('/').next().unwrap_or(rel)
}

/// Is this file part of the deterministic simulation core?
pub fn is_core(path: &str) -> bool {
    CORE_MODULES.contains(&first_segment(&module_rel(path)))
}

fn d02_exempt(path: &str) -> bool {
    let rel = module_rel(path);
    D02_EXEMPT.contains(&rel.as_str())
        || path
            .replace('\\', "/")
            .split('/')
            .any(|seg| seg == "benches")
}

fn d03_exempt(path: &str) -> bool {
    module_rel(path) == "util/rng.rs"
}

/// Run every rule over one scanned file. Returned findings are raw — the
/// caller applies `// simlint: allow(…)` directives and the baseline.
pub fn check(path: &str, scan: &ScanResult) -> Vec<Finding> {
    let toks: Vec<&Token> = scan.tokens.iter().filter(|t| !t.in_test).collect();
    let mut findings = Vec::new();

    let core = is_core(path);
    if core {
        check_d01(path, scan, &toks, &mut findings);
        check_d04(path, scan, &toks, &mut findings);
        check_s01(path, scan, &toks, &mut findings);
        super::flow::check_e01(path, scan, &toks, &mut findings);
    }
    if !d02_exempt(path) {
        check_d02(path, scan, &toks, &mut findings);
    }
    if !d03_exempt(path) {
        check_d03(path, scan, &toks, &mut findings);
    }

    findings.sort_by(|a, b| (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule)));
    findings
}

fn push(
    findings: &mut Vec<Finding>,
    rule: RuleId,
    path: &str,
    scan: &ScanResult,
    tok: &Token,
    message: String,
) {
    findings.push(Finding {
        rule,
        path: path.to_string(),
        line: tok.line,
        col: tok.col,
        message,
        line_text: scan.line_text(tok.line).to_string(),
    });
}

fn check_d01(path: &str, scan: &ScanResult, toks: &[&Token], findings: &mut Vec<Finding>) {
    for t in toks {
        if t.kind == TokenKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
            push(
                findings,
                RuleId::D01,
                path,
                scan,
                t,
                format!(
                    "std {} uses SipHash with a per-process random key",
                    t.text
                ),
            );
        }
    }
}

fn check_d02(path: &str, scan: &ScanResult, toks: &[&Token], findings: &mut Vec<Finding>) {
    let mut i = 0usize;
    while i < toks.len() {
        let t = toks[i];
        if t.is_ident("SystemTime") {
            push(
                findings,
                RuleId::D02,
                path,
                scan,
                t,
                "SystemTime reads the host wall clock".to_string(),
            );
        } else if t.is_ident("Instant")
            && i + 2 < toks.len()
            && toks[i + 1].is_punct(':')
            && toks[i + 2].is_punct(':')
            && toks.get(i + 3).is_some_and(|n| n.is_ident("now"))
        {
            push(
                findings,
                RuleId::D02,
                path,
                scan,
                t,
                "Instant::now reads the host monotonic clock".to_string(),
            );
            i += 4;
            continue;
        }
        i += 1;
    }
}

fn check_d03(path: &str, scan: &ScanResult, toks: &[&Token], findings: &mut Vec<Finding>) {
    for t in toks {
        if t.kind == TokenKind::Ident && D03_IDENTS.contains(&t.text.as_str()) {
            push(
                findings,
                RuleId::D03,
                path,
                scan,
                t,
                format!("`{}` draws entropy outside util::rng", t.text),
            );
        }
    }
}

/// Build the set of identifiers in this file declared with one of `types`:
/// `name: [&][Mutex<]Type<…>` declarations (struct fields, fn params, typed
/// lets) and `let name = Type::default()`-style constructor bindings. Shared
/// by D04 (hash-backed containers) and H02 (Request/batch-state clones).
pub(crate) fn typed_symbols(toks: &[&Token], types: &[&str]) -> BTreeSet<String> {
    let mut syms = BTreeSet::new();
    let mut i = 0usize;
    while i < toks.len() {
        // Pattern: Ident ':' <short type chain containing a listed type>.
        if toks[i].kind == TokenKind::Ident
            && toks.get(i + 1).is_some_and(|t| t.is_punct(':'))
            && !toks.get(i + 2).is_some_and(|t| t.is_punct(':'))
        {
            let name = &toks[i].text;
            let mut j = i + 2;
            let limit = (i + 14).min(toks.len());
            while j < limit {
                let t = toks[j];
                let delim = t.is_punct(',')
                    || t.is_punct(';')
                    || t.is_punct(')')
                    || t.is_punct('{')
                    || t.is_punct('=');
                if delim {
                    break;
                }
                if t.kind == TokenKind::Ident && types.contains(&t.text.as_str()) {
                    syms.insert(name.clone());
                    break;
                }
                j += 1;
            }
        }
        // Pattern: `let [mut] name = <listed type>::default()` (and similar
        // short constructor chains).
        if toks[i].is_ident("let") {
            let mut j = i + 1;
            if toks.get(j).is_some_and(|t| t.is_ident("mut")) {
                j += 1;
            }
            if toks.get(j).is_some_and(|t| t.kind == TokenKind::Ident)
                && toks.get(j + 1).is_some_and(|t| t.is_punct('='))
            {
                let name = &toks[j].text;
                let limit = (j + 8).min(toks.len());
                let mut k = j + 2;
                while k < limit {
                    let t = toks[k];
                    if t.is_punct('(') || t.is_punct(';') {
                        break;
                    }
                    if t.kind == TokenKind::Ident && types.contains(&t.text.as_str()) {
                        syms.insert(name.clone());
                        break;
                    }
                    k += 1;
                }
            }
        }
        i += 1;
    }
    syms
}

fn hash_symbols(toks: &[&Token]) -> BTreeSet<String> {
    typed_symbols(toks, HASH_TYPES)
}

fn check_d04(path: &str, scan: &ScanResult, toks: &[&Token], findings: &mut Vec<Finding>) {
    let syms = hash_symbols(toks);
    if syms.is_empty() {
        return;
    }
    let mut i = 0usize;
    while i < toks.len() {
        // `name.iter()` / `self.name.iter()` — flag at the method token.
        if toks[i].is_punct('.')
            && toks
                .get(i + 1)
                .is_some_and(|t| t.kind == TokenKind::Ident && ITER_METHODS.contains(&t.text.as_str()))
            && toks.get(i + 2).is_some_and(|t| t.is_punct('('))
            && i >= 1
            && toks[i - 1].kind == TokenKind::Ident
            && syms.contains(&toks[i - 1].text)
        {
            let method = toks[i + 1];
            push(
                findings,
                RuleId::D04,
                path,
                scan,
                method,
                format!(
                    "`{}.{}()` enumerates a hash-based container in hash order",
                    toks[i - 1].text, method.text
                ),
            );
            i += 3;
            continue;
        }
        // `for x in &name {` / `for x in &mut self.name {`.
        if toks[i].is_ident("in") {
            let mut j = i + 1;
            if toks.get(j).is_some_and(|t| t.is_punct('&')) {
                j += 1;
            }
            if toks.get(j).is_some_and(|t| t.is_ident("mut")) {
                j += 1;
            }
            if toks.get(j).is_some_and(|t| t.is_ident("self"))
                && toks.get(j + 1).is_some_and(|t| t.is_punct('.'))
            {
                j += 2;
            }
            if toks.get(j).is_some_and(|t| t.kind == TokenKind::Ident)
                && syms.contains(&toks[j].text)
                && toks.get(j + 1).is_some_and(|t| t.is_punct('{'))
            {
                let name = toks[j];
                push(
                    findings,
                    RuleId::D04,
                    path,
                    scan,
                    name,
                    format!(
                        "`for … in &{}` enumerates a hash-based container in hash order",
                        name.text
                    ),
                );
            }
        }
        i += 1;
    }
}

fn check_s01(path: &str, scan: &ScanResult, toks: &[&Token], findings: &mut Vec<Finding>) {
    const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
    let mut i = 0usize;
    while i < toks.len() {
        let t = toks[i];
        // `.unwrap(` / `.expect(`
        if t.is_punct('.')
            && toks
                .get(i + 1)
                .is_some_and(|n| n.is_ident("unwrap") || n.is_ident("expect"))
            && toks.get(i + 2).is_some_and(|n| n.is_punct('('))
        {
            let m = toks[i + 1];
            push(
                findings,
                RuleId::S01,
                path,
                scan,
                m,
                format!("`.{}()` aborts without a stated invariant", m.text),
            );
            i += 3;
            continue;
        }
        // `panic!(` family
        if t.kind == TokenKind::Ident
            && PANIC_MACROS.contains(&t.text.as_str())
            && toks.get(i + 1).is_some_and(|n| n.is_punct('!'))
        {
            push(
                findings,
                RuleId::S01,
                path,
                scan,
                t,
                format!("`{}!` aborts without a stated invariant", t.text),
            );
            i += 2;
            continue;
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_rel_strips_through_src() {
        assert_eq!(module_rel("rust/src/metrics/mod.rs"), "metrics/mod.rs");
        assert_eq!(module_rel("src/util/bench.rs"), "util/bench.rs");
        assert_eq!(module_rel("coordinator/mod.rs"), "coordinator/mod.rs");
        assert_eq!(module_rel("a/srcx/b.rs"), "a/srcx/b.rs");
    }

    #[test]
    fn core_classification() {
        assert!(is_core("rust/src/coordinator/mod.rs"));
        assert!(is_core("metrics/mod.rs"));
        assert!(is_core("rust/src/network/topology.rs"));
        assert!(!is_core("rust/src/util/fxhash.rs"));
        assert!(!is_core("rust/src/lint/rules.rs"));
        assert!(!is_core("rust/src/bin/simlint.rs"));
    }

    #[test]
    fn d02_exemptions() {
        assert!(d02_exempt("rust/src/util/bench.rs"));
        assert!(d02_exempt("rust/benches/perf_trajectory.rs"));
        assert!(!d02_exempt("rust/src/sweep/mod.rs"));
    }
}
