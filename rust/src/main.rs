//! LLMServingSim2.0 CLI: the Layer-3 leader entrypoint.
//!
//! Commands:
//!   profile   — run the operator-level profiler on the PJRT backend and
//!               write a latency-trace DB (the paper's "single command"
//!               hardware integration, §II-A). `--emit-bundle` additionally
//!               packages spec + trace + calibration into a hardware
//!               bundle usable by name everywhere a preset is.
//!   import-hardware — validate + register a hardware bundle; optionally
//!               install it into a bundle directory.
//!   simulate  — run a serving simulation from a preset or config file.
//!   validate  — Fig. 2 style: run the ground-truth execution engine and
//!               the trace-driven simulator on the same config; print the
//!               error table.
//!   sweep     — expand a configuration grid (presets x rates x policies x
//!               perf backends x hardware) and run it on a worker pool;
//!               emit per-config reports and a comparative summary.
//!   presets   — list built-in models, hardware, and serving configs.
//!   gen-trace — emit a synthetic ShareGPT-like request trace as JSON.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use llmservingsim::cli::Args;
use llmservingsim::config::{presets, ChaosConfig, PerfBackend, SimConfig};
use llmservingsim::coordinator::{run_config, Simulation};
use llmservingsim::groundtruth::ExecPerfModel;
use llmservingsim::model::ModelSpec;
use llmservingsim::perf::hardware;
use llmservingsim::perf::HardwareSpec;
use llmservingsim::policy;
use llmservingsim::runtime::profiler::{
    emit_bundle, profile_to_file, ProfileOptions,
};
use llmservingsim::sweep::{
    find_shard_files, merge_files, render_aggregate_table, render_table,
    run_all_shards, run_manifest, run_shard_to_file, run_sweep, summarize,
    sweep_json, ExperimentManifest, ShardOutcome, SweepSpec,
};
use llmservingsim::util::bench::Table;
use llmservingsim::util::{json, logging};
use llmservingsim::workload;

const HELP: &str = "\
LLMServingSim2.0 — unified simulator for heterogeneous LLM serving

USAGE: llmservingsim <command> [flags]

COMMANDS:
  profile    --model <preset> [--artifacts DIR] [--out FILE]
             [--hardware-tag TAG] [--reps N] [--warmup N]
             [--emit-bundle FILE] [--peak-tflops X] [--mem-gbps X]
             [--mem-gb X] [--host-gbps X] [--kernel-overhead-ns N]
             (--emit-bundle packages hardware spec + trace + calibration
              into one file; the spec flags override the roofline-fallback
              numbers recorded for TAG)
  import-hardware --bundle FILE [--dir DIR]
             (validate + register a profiled hardware bundle; --dir
              installs it so --hardware-dir runs pick it up)
  simulate   (--preset NAME | --config FILE) [--model M] [--moe-model M]
             [--hardware H] [--hardware-dir DIR]
             [--perf analytical|cycle|cycle-replay|trace:PATH]
             [--requests N] [--rate R] [--workload W] [--tenants N]
             [--controller C] [--chaos PROFILE] [--tick-ms N] [--seed S]
             [--out FILE]
             (--workload takes a registered traffic source: poisson,
              uniform, burst, mmpp, diurnal, sessions, or a custom name;
              --tenants N splits traffic over N weighted tenants with
              alternating interactive/batch SLO classes; --hardware-dir
              loads every bundle in DIR so profiled devices resolve by
              name in --hardware and config files; --controller runs a
              registered cluster controller — static, queue-threshold,
              failure-replay, chaos — on a --tick-ms cadence; --chaos
              runs the seeded fault injector with a named profile —
              none, light, heavy, partition)
  sweep      [--presets A,B,..] [--hardware H1,H2,..|all]
             [--hardware-dir DIR] [--rates R1,R2,..]
             [--workloads W1,W2,..|all] [--routers P1,P2,..|all]
             [--scheds S1,S2,..|all] [--evict E1,E2,..|all]
             [--controllers C1,C2,..|all] [--chaos P1,P2,..|all]
             [--perf B1,B2,..] [--model M] [--moe-model M] [--requests N]
             [--seed S] [--threads T] [--baseline NAME] [--out FILE]
             [--quick]
             (policy/workload/hardware/controller axes take registry
              names; `all` sweeps every registered entry, including
              imported bundles; --chaos sweeps named fault-injection
              profiles under the chaos controller — byte-identical at
              any --threads value)
             Distributed/replicated sweeps (DESIGN.md §13):
             [--replicates R] [--emit-manifest FILE]
             [--manifest FILE] [--shard I/N] [--shards N]
             [--out-dir DIR] [--force]
             (--emit-manifest captures the axis flags + --replicates as
              an experiment-manifest-v1 file; --manifest replaces the
              axis flags with that file; --shard I/N runs one 1-based
              shard of an N-way partition into --out-dir; --out-dir
              without --shard runs/resumes every shard there and merges
              — completed shard files are skipped unless --force;
              --replicates R runs each grid point R times with derived
              seeds and reports mean/std/95% CI per metric)
  sweep-merge --manifest FILE (--dir DIR | --inputs A,B,..) [--out FILE]
             [--hardware-dir DIR]
             (fold shard result files into the aggregate report — byte-
              identical to the single-process run of the same manifest;
              shards from a different manifest or partition, and corrupt
              or tampered files, are rejected by content hash)
  validate   --model <preset> [--artifacts DIR] [--trace FILE]
             [--requests N] [--rate R]
  gen-trace  [--requests N] [--rate R] [--workload W] [--tenants N]
             [--seed S] --out FILE
  presets    (lists models, hardware, serving configs)
  help
";

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match Args::parse(args, &["quick", "force"]) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}\n{HELP}");
            std::process::exit(2);
        }
    };
    let code = match run(&parsed) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.command.as_str() {
        "profile" => cmd_profile(args),
        "import-hardware" => cmd_import_hardware(args),
        "simulate" => cmd_simulate(args),
        "sweep" => cmd_sweep(args),
        "sweep-merge" => cmd_sweep_merge(args),
        "validate" => cmd_validate(args),
        "gen-trace" => cmd_gen_trace(args),
        "presets" => cmd_presets(),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let model = args.str_or("model", "tiny-dense").to_string();
    let tag = args.str_or("hardware-tag", "cpu-pjrt").to_string();
    let default_out = format!("artifacts/traces/{tag}-{model}.json");
    let out = PathBuf::from(args.str_or("out", &default_out));
    let opts = ProfileOptions {
        warmup: args.u64_or("warmup", 2)? as usize,
        reps: args.u64_or("reps", 7)? as usize,
        hardware_tag: tag.clone(),
    };
    println!("profiling {model} on the PJRT backend ...");
    let outcome = profile_to_file(&artifacts_dir(args), &model, &out, &opts)?;
    println!(
        "profiled {} ops in {:.2} s -> {}",
        outcome.ops_profiled,
        outcome.wall_ns as f64 / 1e9,
        out.display()
    );
    let mut t = Table::new(&["op kind", "leave-one-out err %"]);
    for (k, e) in &outcome.loo_error_pct {
        t.row(&[k.to_string(), format!("{e:.2}")]);
    }
    t.print();
    // The one-command onboarding pipeline (DESIGN.md §8): package the
    // profiled trace + spec + derived calibration into a hardware bundle
    // that simulate/sweep load by name.
    if let Some(bundle_out) = args.str_flag("emit-bundle") {
        let spec = hardware_spec_for_tag(args, &tag)?;
        let bundle = emit_bundle(&outcome.db, spec, Path::new(bundle_out))?;
        let ops = bundle
            .trace
            .as_ref()
            .map(|db| db.kinds().count())
            .unwrap_or(0);
        println!(
            "hardware bundle '{}' ({} profiled op kinds, {} calibration \
             factors) -> {bundle_out}",
            bundle.spec.name,
            ops,
            bundle.calibration.len()
        );
        println!(
            "next: `import-hardware --bundle {bundle_out} --dir artifacts/hardware` \
             then `simulate --hardware {} --hardware-dir artifacts/hardware`",
            bundle.spec.name
        );
    }
    Ok(())
}

/// The roofline-fallback spec recorded in an emitted bundle: the built-in
/// preset of the same name when one exists, otherwise CPU-PJRT-class
/// defaults renamed to `tag` (the profiled trace is the authoritative
/// pricing source; the spec seeds the roofline fallback and the memory
/// model). The `--peak-tflops`/`--mem-gbps`/`--mem-gb`/`--host-gbps`/
/// `--kernel-overhead-ns` flags override individual numbers.
fn hardware_spec_for_tag(args: &Args, tag: &str) -> anyhow::Result<HardwareSpec> {
    let mut spec = HardwareSpec::preset(tag).unwrap_or_else(|| HardwareSpec {
        name: tag.to_string(),
        ..HardwareSpec::cpu_pjrt()
    });
    const GB: f64 = (1u64 << 30) as f64;
    spec.peak_flops = args.f64_or("peak-tflops", spec.peak_flops / 1e12)? * 1e12;
    spec.mem_bw = args.f64_or("mem-gbps", spec.mem_bw / 1e9)? * 1e9;
    spec.mem_capacity =
        (args.f64_or("mem-gb", spec.mem_capacity as f64 / GB)? * GB) as u64;
    spec.host_bw = args.f64_or("host-gbps", spec.host_bw / 1e9)? * 1e9;
    spec.kernel_overhead = args.u64_or("kernel-overhead-ns", spec.kernel_overhead)?;
    Ok(spec)
}

fn cmd_import_hardware(args: &Args) -> anyhow::Result<()> {
    let path = args
        .str_flag("bundle")
        .ok_or_else(|| anyhow::anyhow!("import-hardware needs --bundle FILE"))?;
    let bundle = hardware::import_bundle_file(Path::new(path))?;
    println!("imported hardware '{}':", bundle.spec.name);
    let mut t = Table::new(&["field", "value"]);
    t.row(&[
        "peak TFLOP/s".into(),
        format!("{:.1}", bundle.spec.peak_flops / 1e12),
    ]);
    t.row(&[
        "mem bandwidth GB/s".into(),
        format!("{:.0}", bundle.spec.mem_bw / 1e9),
    ]);
    t.row(&[
        "mem capacity GB".into(),
        (bundle.spec.mem_capacity >> 30).to_string(),
    ]);
    t.row(&[
        "host bandwidth GB/s".into(),
        format!("{:.0}", bundle.spec.host_bw / 1e9),
    ]);
    t.row(&[
        "profiled op kinds".into(),
        bundle
            .trace
            .as_ref()
            .map(|db| db.kinds().count())
            .unwrap_or(0)
            .to_string(),
    ]);
    t.row(&[
        "calibration factors".into(),
        bundle.calibration.len().to_string(),
    ]);
    t.print();
    if let Some(dir) = args.str_flag("dir") {
        let dest = Path::new(dir).join(format!("{}.json", bundle.spec.name));
        bundle.save(&dest)?;
        println!(
            "installed to {} — load it in any run with --hardware-dir {dir}",
            dest.display()
        );
    }
    println!(
        "'{}' now resolves by name in simulate/sweep/configs for this process",
        bundle.spec.name
    );
    Ok(())
}

/// Apply `--hardware-dir DIR`: load every bundle in DIR into the global
/// hardware registry so the rest of the command sees profiled devices by
/// name. Shared by simulate and sweep.
fn load_hardware_flags(args: &Args) -> anyhow::Result<()> {
    if let Some(dir) = args.str_flag("hardware-dir") {
        let names = hardware::load_bundle_dir(Path::new(dir))?;
        if names.is_empty() {
            println!("no hardware bundles found in {dir}");
        } else {
            println!("loaded hardware bundles: {}", names.join(", "));
        }
    }
    Ok(())
}

/// Resolve a simulation config from --preset/--config plus overrides.
fn resolve_config(args: &Args) -> anyhow::Result<SimConfig> {
    let dense = args.str_or("model", "tiny-dense").to_string();
    let moe = args.str_or("moe-model", "tiny-moe").to_string();
    let hw = args.str_or("hardware", "rtx3090").to_string();
    let mut cfg = if let Some(path) = args.str_flag("config") {
        SimConfig::load(Path::new(path))?
    } else {
        let preset = args.str_or("preset", "S(D)");
        presets::by_name(preset, &dense, &moe, &hw)
            .ok_or_else(|| anyhow::anyhow!("unknown preset '{preset}'"))?
    };
    if let Some(p) = args.str_flag("perf") {
        cfg.perf = p.parse()?;
    }
    if let Some(n) = args.str_flag("requests") {
        cfg.workload.num_requests = n.parse()?;
    }
    if let Some(r) = args.str_flag("rate") {
        cfg.workload.traffic = workload::Traffic::poisson(r.parse()?);
    }
    apply_workload_flags(args, &mut cfg.workload)?;
    if let Some(c) = args.str_flag("controller") {
        // fail here with the candidate list, not mid-build
        policy::snapshot().check_controller(c)?;
        cfg.cluster.controller = c.to_string();
    }
    if let Some(p) = args.str_flag("chaos") {
        if let Some(c) = args.str_flag("controller") {
            if c != "chaos" {
                anyhow::bail!(
                    "--chaos runs the 'chaos' controller; it cannot be \
                     combined with --controller {c}"
                );
            }
        }
        cfg.cluster.chaos = ChaosConfig::profile(p)?;
        cfg.cluster.controller = "chaos".to_string();
    }
    cfg.cluster.tick_ms = args.u64_or("tick-ms", cfg.cluster.tick_ms)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Apply `--workload NAME` / `--tenants N` to a workload spec. The name
/// resolves like a sweep axis value: built-in default parameters at the
/// spec's `--rate`, otherwise a registered custom source.
fn apply_workload_flags(
    args: &Args,
    spec: &mut workload::WorkloadSpec,
) -> anyhow::Result<()> {
    if let Some(w) = args.str_flag("workload") {
        policy::snapshot().check_traffic(w)?;
        let rate = args.f64_or("rate", 10.0)?;
        spec.traffic = workload::Traffic::for_name(w, rate)
            .unwrap_or_else(|| workload::Traffic::Custom { name: w.to_string() });
    }
    let tenants = args.u64_or("tenants", 0)? as usize;
    if tenants > 0 {
        spec.tenants = workload::TenantSpec::mix(tenants);
    }
    Ok(())
}

/// Split a comma-separated flag value, dropping empty segments.
fn csv(s: &str) -> Vec<&str> {
    s.split(',').map(str::trim).filter(|t| !t.is_empty()).collect()
}

/// Parse every element of a comma-separated flag through `FromStr`.
fn csv_parse<T>(args: &Args, flag: &str) -> anyhow::Result<Vec<T>>
where
    T: std::str::FromStr,
    anyhow::Error: From<T::Err>,
{
    match args.str_flag(flag) {
        None => Ok(vec![]),
        Some(s) => csv(s)
            .into_iter()
            .map(|t| T::from_str(t).map_err(anyhow::Error::from))
            .collect(),
    }
}

/// Resolve a policy-axis flag: comma-separated registry names, or the
/// literal `all` to sweep every name registered for that decision point.
fn policy_axis(args: &Args, flag: &str, all_names: Vec<String>) -> Vec<String> {
    match args.str_flag(flag) {
        None => vec![],
        Some("all") => all_names,
        Some(s) => csv(s).into_iter().map(str::to_string).collect(),
    }
}

/// Build a [`SweepSpec`] from the classic axis flags (shared by the
/// in-process sweep, `--emit-manifest`, and ad-hoc `--replicates` runs).
fn sweep_spec_from_flags(args: &Args) -> anyhow::Result<SweepSpec> {
    let mut spec = SweepSpec {
        dense_model: args.str_or("model", "tiny-dense").to_string(),
        moe_model: args.str_or("moe-model", "tiny-moe").to_string(),
        num_requests: args.u64_or("requests", 40)? as usize,
        quick: args.switch("quick"),
        baseline: args.str_flag("baseline").map(str::to_string),
        ..SweepSpec::default()
    };
    spec.seed = args.u64_or("seed", spec.seed)?;
    if let Some(p) = args.str_flag("presets") {
        spec.axes.presets = csv(p).into_iter().map(str::to_string).collect();
    }
    // The hardware axis resolves like a policy axis: registry names, with
    // `all` expanding to every registered device (built-ins + bundles
    // loaded via --hardware-dir / import-hardware).
    spec.axes.hardware = policy_axis(args, "hardware", hardware::registered_names());
    spec.axes.rates = csv_parse::<f64>(args, "rates")?;
    // Policy axes take registry names; unknown names are rejected by
    // `expand()` with the registered candidates. `all` sweeps everything
    // currently registered (built-ins + user registrations).
    let registry = policy::snapshot();
    spec.axes.workloads = policy_axis(args, "workloads", registry.traffic_names());
    spec.axes.routers = policy_axis(args, "routers", registry.route_names());
    spec.axes.scheds = policy_axis(args, "scheds", registry.sched_names());
    spec.axes.evictions = policy_axis(args, "evict", registry.evict_names());
    spec.axes.controllers =
        policy_axis(args, "controllers", registry.controller_names());
    spec.axes.chaos = policy_axis(
        args,
        "chaos",
        ChaosConfig::profile_names()
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    spec.axes.backends = csv_parse::<PerfBackend>(args, "perf")?;
    Ok(spec)
}

/// Flags that a manifest already fixes; combining them with `--manifest`
/// would silently lose to the file, so it is an explicit error instead.
const MANIFEST_CONFLICT_FLAGS: &[&str] = &[
    "presets",
    "hardware",
    "rates",
    "workloads",
    "routers",
    "scheds",
    "evict",
    "controllers",
    "chaos",
    "perf",
    "model",
    "moe-model",
    "requests",
    "seed",
    "baseline",
    "replicates",
];

fn ensure_no_axis_flags(args: &Args) -> anyhow::Result<()> {
    for f in MANIFEST_CONFLICT_FLAGS {
        if args.str_flag(f).is_some() {
            anyhow::bail!(
                "--manifest fully specifies the sweep; drop --{f} and edit \
                 the manifest file instead"
            );
        }
    }
    if args.switch("quick") {
        anyhow::bail!(
            "--manifest fully specifies the sweep; drop --quick and set \
             \"quick\": true in the manifest instead"
        );
    }
    Ok(())
}

/// Parse `--shard I/N` (1-based index) into 0-based `(shard, shards)`.
fn parse_shard_spec(s: &str) -> anyhow::Result<(usize, usize)> {
    let bad = || {
        anyhow::anyhow!(
            "--shard expects I/N with 1 <= I <= N (e.g. --shard 2/7), got '{s}'"
        )
    };
    let (i, n) = s.split_once('/').ok_or_else(bad)?;
    let i: usize = i.trim().parse().map_err(|_| bad())?;
    let n: usize = n.trim().parse().map_err(|_| bad())?;
    if n < 1 || i < 1 || i > n {
        return Err(bad());
    }
    Ok((i - 1, n))
}

/// Print a merged aggregate: per-point table plus the extremes summary.
fn print_aggregate(aggregate: &json::Value) {
    render_aggregate_table(aggregate).print();
    let summary = aggregate.get("summary");
    println!(
        "baseline: {}",
        summary.get("baseline").as_str().unwrap_or("?")
    );
    let mut t = Table::new(&["metric", "best (config)", "worst (config)"]);
    for e in summary.get("extremes").as_arr().unwrap_or(&[]) {
        t.row(&[
            e.get("metric").as_str().unwrap_or("?").to_string(),
            format!(
                "{:.3} ({})",
                e.get("best").as_f64().unwrap_or(0.0),
                e.get("best_config").as_str().unwrap_or("?")
            ),
            format!(
                "{:.3} ({})",
                e.get("worst").as_f64().unwrap_or(0.0),
                e.get("worst_config").as_str().unwrap_or("?")
            ),
        ]);
    }
    t.print();
}

fn default_threads(args: &Args) -> anyhow::Result<usize> {
    let available = std::thread::available_parallelism()
        .map(|n| n.get() as u64)
        .unwrap_or(1);
    Ok(args.u64_or("threads", available)?.max(1) as usize)
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    load_hardware_flags(args)?;
    let from_manifest = args.str_flag("manifest");
    let manifest = if let Some(path) = from_manifest {
        ensure_no_axis_flags(args)?;
        ExperimentManifest::load(Path::new(path))?
    } else {
        let mut m = ExperimentManifest::new(sweep_spec_from_flags(args)?);
        m.replication = args.u64_or("replicates", 1)?.max(1) as usize;
        m.shards = args.u64_or("shards", 1)?.max(1) as usize;
        m
    };

    if let Some(out) = args.str_flag("emit-manifest") {
        manifest.spec.expand()?; // reject invalid axes before writing
        manifest.save(Path::new(out))?;
        println!(
            "manifest ({} grid points x {} replicate(s), {} shard(s), \
             hash {}) written to {out}",
            manifest.spec.grid_size(),
            manifest.replication,
            manifest.shards,
            manifest.hash()
        );
        return Ok(());
    }

    let threads = default_threads(args)?;
    let force = args.switch("force");

    // One shard of an N-way partition: the distributed worker's entry
    // point. Emits (or reuses) the shard result file and stops.
    if let Some(spec_str) = args.str_flag("shard") {
        let (shard, shards) = parse_shard_spec(spec_str)?;
        let dir = PathBuf::from(args.str_or("out-dir", "sweep-shards"));
        let outcome =
            run_shard_to_file(&manifest, shard, shards, threads, &dir, force)?;
        match &outcome {
            ShardOutcome::Completed(p) => println!(
                "shard {}/{shards} completed -> {}",
                shard + 1,
                p.display()
            ),
            ShardOutcome::Skipped(p) => println!(
                "shard {}/{shards} already complete, skipped ({})",
                shard + 1,
                p.display()
            ),
        }
        println!(
            "merge when all shards are done: sweep-merge --manifest <M> \
             --dir {}",
            dir.display()
        );
        return Ok(());
    }

    // Resumable local driver: run (or skip) every shard into --out-dir,
    // then merge the result files into the aggregate.
    if let Some(dir) = args.str_flag("out-dir") {
        let shards = match args.str_flag("shards") {
            Some(_) => args.u64_or("shards", 1)?.max(1) as usize,
            None => manifest.shards,
        };
        let dir = PathBuf::from(dir);
        println!(
            "running {} shard(s) of {} grid points x {} replicate(s) on \
             {} threads ...",
            shards,
            manifest.spec.grid_size(),
            manifest.replication,
            threads
        );
        let outcomes = run_all_shards(&manifest, shards, threads, &dir, force)?;
        let skipped = outcomes
            .iter()
            .filter(|o| matches!(o, ShardOutcome::Skipped(_)))
            .count();
        println!(
            "shards: {} run, {} skipped (already complete)",
            outcomes.len() - skipped,
            skipped
        );
        let files: Vec<PathBuf> =
            outcomes.iter().map(|o| o.path().to_path_buf()).collect();
        let aggregate = merge_files(&manifest, &files)?;
        print_aggregate(&aggregate);
        if let Some(out) = args.str_flag("out") {
            json::save_file(Path::new(out), &aggregate)?;
            println!("merged aggregate written to {out}");
        }
        return Ok(());
    }

    // Manifest or replicated runs go through the single-process manifest
    // path so their output is the same aggregate format shards merge to.
    if from_manifest.is_some() || manifest.replication > 1 {
        println!(
            "running manifest: {} grid points x {} replicate(s) on {} \
             threads ...",
            manifest.spec.grid_size(),
            manifest.replication,
            threads
        );
        let aggregate = run_manifest(&manifest, threads)?;
        print_aggregate(&aggregate);
        if let Some(out) = args.str_flag("out") {
            json::save_file(Path::new(out), &aggregate)?;
            println!("sweep aggregate written to {out}");
        }
        return Ok(());
    }

    // Classic in-memory sweep: byte-stable legacy path.
    let spec = manifest.spec;
    let cfgs = spec.expand()?;
    // Catch a bad baseline before the (potentially long) sweep runs, not
    // after all the work has been done.
    if let Some(b) = &spec.baseline {
        if !cfgs.iter().any(|c| &c.name == b) {
            anyhow::bail!(
                "baseline '{b}' is not a grid point; points are:\n  {}",
                cfgs.iter()
                    .map(|c| c.name.as_str())
                    .collect::<Vec<_>>()
                    .join("\n  ")
            );
        }
    }
    println!(
        "sweeping {} configs on {} worker threads ...",
        cfgs.len(),
        threads.min(cfgs.len())
    );

    let outcome = run_sweep(&cfgs, threads)?;
    let summary = summarize(&outcome, spec.baseline.as_deref())?;

    render_table(&outcome, &summary).print();
    println!("baseline: {}", summary.baseline);
    let mut t = Table::new(&["metric", "best (config)", "worst (config)"]);
    for e in &summary.extremes {
        t.row(&[
            e.metric.to_string(),
            format!("{:.3} ({})", e.best, e.best_config),
            format!("{:.3} ({})", e.worst, e.worst_config),
        ]);
    }
    t.print();
    println!(
        "sweep wall-clock: {:.3} s on {} threads",
        outcome.wall_ns as f64 / 1e9,
        outcome.threads
    );

    if let Some(out) = args.str_flag("out") {
        json::save_file(Path::new(out), &sweep_json(&outcome, &summary))?;
        println!("sweep report written to {out}");
    }
    Ok(())
}

fn cmd_sweep_merge(args: &Args) -> anyhow::Result<()> {
    load_hardware_flags(args)?;
    let manifest_path = args.str_flag("manifest").ok_or_else(|| {
        anyhow::anyhow!(
            "sweep-merge requires --manifest FILE (the manifest the shards \
             were produced from)"
        )
    })?;
    let manifest = ExperimentManifest::load(Path::new(manifest_path))?;

    let files: Vec<PathBuf> = if let Some(dir) = args.str_flag("dir") {
        let dir = PathBuf::from(dir);
        let found = find_shard_files(&dir)?;
        if found.is_empty() {
            anyhow::bail!(
                "no shard result files (shard-*.json) found in {}",
                dir.display()
            );
        }
        found
    } else if let Some(list) = args.str_flag("inputs") {
        csv(list).into_iter().map(PathBuf::from).collect()
    } else {
        anyhow::bail!(
            "sweep-merge needs shard result files: pass --dir DIR or \
             --inputs a.json,b.json,..."
        );
    };

    println!(
        "merging {} shard result file(s) against manifest hash {} ...",
        files.len(),
        manifest.hash()
    );
    let aggregate = merge_files(&manifest, &files)?;
    print_aggregate(&aggregate);
    if let Some(out) = args.str_flag("out") {
        json::save_file(Path::new(out), &aggregate)?;
        println!("merged aggregate written to {out}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    load_hardware_flags(args)?;
    let cfg = resolve_config(args)?;
    let name = cfg.name.clone();
    // simlint: allow(D02) — CLI UX: prints how long the simulation took on the
    // host; never feeds simulated time
    let t0 = std::time::Instant::now();
    let (report, summary) = run_config(cfg)?;
    let wall = t0.elapsed();

    println!("config {name}: {} requests", report.num_requests);
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["finished".into(), report.num_finished.to_string()]);
    t.row(&[
        "makespan".into(),
        format!("{:.3} s", report.makespan as f64 / 1e9),
    ]);
    t.row(&[
        "TTFT mean".into(),
        format!("{:.3} ms", report.ttft_ns.mean / 1e6),
    ]);
    t.row(&[
        "TPOT mean".into(),
        format!("{:.3} ms", report.tpot_ns.mean / 1e6),
    ]);
    t.row(&[
        "ITL mean".into(),
        format!("{:.3} ms", report.itl_ns.mean / 1e6),
    ]);
    t.row(&[
        "throughput".into(),
        format!("{:.1} tok/s", report.throughput_tps),
    ]);
    t.row(&[
        "goodput".into(),
        format!("{:.1} tok/s", report.goodput_tps),
    ]);
    t.row(&["engine steps".into(), summary.steps.to_string()]);
    t.row(&["sim events".into(), summary.events.to_string()]);
    if summary.controller != "static" {
        t.row(&["controller".into(), summary.controller.clone()]);
        t.row(&[
            "peak instances".into(),
            summary.peak_instances.to_string(),
        ]);
    }
    t.row(&[
        "sim wall-clock".into(),
        format!("{:.3} s", wall.as_secs_f64()),
    ]);
    for (i, cs) in summary.cache_stats.iter().enumerate() {
        t.row(&[
            format!("cache {i} hit rate"),
            format!("{:.1} %", cs.hit_rate() * 100.0),
        ]);
    }
    t.print();

    if report.per_class.len() > 1 || !report.per_tenant.is_empty() {
        let mut t = Table::new(&["SLO class", "finished", "attainment %", "goodput tok/s"]);
        for c in &report.per_class {
            t.row(&[
                c.class.as_str().to_string(),
                c.num_finished.to_string(),
                format!("{:.1}", c.slo_attainment * 100.0),
                format!("{:.1}", c.goodput_tps),
            ]);
        }
        t.print();
    }
    if report.per_tenant.len() > 1 {
        let mut t = Table::new(&["tenant", "finished", "tok/s", "SLO %", "TTFT ms"]);
        for tr in &report.per_tenant {
            t.row(&[
                tr.name.clone(),
                tr.num_finished.to_string(),
                format!("{:.1}", tr.throughput_tps),
                format!("{:.1}", tr.slo_attainment * 100.0),
                format!("{:.3}", tr.ttft_ns_mean / 1e6),
            ]);
        }
        t.print();
    }

    // Controller timeline: every action and lifecycle transition (samples
    // stay in the JSON report, where plotting tools want them).
    let actions: Vec<_> = report
        .timeline
        .iter()
        .filter(|e| e.kind != "sample")
        .collect();
    if !actions.is_empty() {
        let mut t = Table::new(&["t (ms)", "action", "instance", "active", "detail"]);
        for e in &actions {
            t.row(&[
                format!("{:.1}", e.at as f64 / 1e6),
                e.kind.clone(),
                e.instance.map(|i| i.to_string()).unwrap_or_default(),
                e.active.to_string(),
                e.detail.clone(),
            ]);
        }
        t.print();
    }

    if let Some(out) = args.str_flag("out") {
        json::save_file(Path::new(out), &report.to_json())?;
        println!("report written to {out}");
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> anyhow::Result<()> {
    let model = args.str_or("model", "tiny-dense").to_string();
    let root = artifacts_dir(args);
    let requests = args.u64_or("requests", 20)? as usize;
    let rate = args.f64_or("rate", 10.0)?;

    // Ground truth: real execution on CPU-PJRT.
    let mut cfg = presets::single_dense(&model, "cpu-pjrt");
    cfg.workload.num_requests = requests;
    cfg.workload.traffic = workload::Traffic::poisson(rate);
    cfg.workload.lengths = workload::LengthDist::short();

    println!("running ground-truth execution engine ({model}) ...");
    let gt_model = Arc::new(ExecPerfModel::new(&root, &model)?);
    let gt2 = gt_model.clone();
    let mut gt_sim = Simulation::builder(cfg.clone())
        .with_perf_factory(move |_, _, _| {
            Ok(gt2.clone() as Arc<dyn llmservingsim::perf::PerfModel>)
        })
        .build()?;
    let gt_report = gt_sim.run();

    // Simulator: trace-driven from a profiled DB.
    let trace_path = match args.str_flag("trace") {
        Some(p) => PathBuf::from(p),
        None => {
            let p = root.join(format!("traces/cpu-pjrt-{model}.json"));
            if !p.exists() {
                println!("no trace at {}; profiling first ...", p.display());
                profile_to_file(&root, &model, &p, &ProfileOptions::default())?;
            }
            p
        }
    };
    cfg.perf = PerfBackend::Trace {
        path: trace_path.to_string_lossy().into_owned(),
    };
    println!("running trace-driven simulation ...");
    let (sim_report, _) = run_config(cfg)?;

    let err = sim_report.error_vs(&gt_report);
    let mut t = Table::new(&["metric", "ground truth", "simulated", "error %"]);
    t.row(&[
        "TPOT mean (ms)".into(),
        format!("{:.3}", gt_report.tpot_ns.mean / 1e6),
        format!("{:.3}", sim_report.tpot_ns.mean / 1e6),
        format!("{:.2}", err.tpot_pct),
    ]);
    t.row(&[
        "ITL mean (ms)".into(),
        format!("{:.3}", gt_report.itl_ns.mean / 1e6),
        format!("{:.3}", sim_report.itl_ns.mean / 1e6),
        format!("{:.2}", err.itl_pct),
    ]);
    t.row(&[
        "throughput (tok/s)".into(),
        format!("{:.1}", gt_report.throughput_tps),
        format!("{:.1}", sim_report.throughput_tps),
        format!("{:.2}", err.throughput_pct),
    ]);
    t.print();
    println!("mean error: {:.2} %", err.mean());
    Ok(())
}

fn cmd_gen_trace(args: &Args) -> anyhow::Result<()> {
    let out = args
        .str_flag("out")
        .ok_or_else(|| anyhow::anyhow!("gen-trace needs --out FILE"))?;
    let mut spec = workload::WorkloadSpec::sharegpt_100(args.f64_or("rate", 10.0)?);
    spec.num_requests = args.u64_or("requests", 100)? as usize;
    apply_workload_flags(args, &mut spec)?;
    spec.seed = args.u64_or("seed", spec.seed)?;
    let reqs = spec.generate()?;
    workload::save_trace(Path::new(out), &reqs)?;
    println!("wrote {} requests to {out}", reqs.len());
    Ok(())
}

fn cmd_presets() -> anyhow::Result<()> {
    println!("models:");
    for m in ModelSpec::preset_names() {
        let s = ModelSpec::preset(m).unwrap();
        println!(
            "  {m}: hidden={} heads={} layers={} experts={}",
            s.hidden, s.heads, s.layers, s.experts
        );
    }
    println!("hardware (registry; imported bundles appear here too):");
    let hw_registry = hardware::snapshot();
    for h in hw_registry.names() {
        let b = hw_registry.bundle(&h).expect("listed name resolves");
        let s = &b.spec;
        let profiled = match &b.trace {
            Some(db) => format!(", {} profiled op kinds", db.kinds().count()),
            None => String::new(),
        };
        println!(
            "  {h}: {:.0} TFLOP/s, {:.0} GB/s, {} GB{profiled}",
            s.peak_flops / 1e12,
            s.mem_bw / 1e9,
            s.mem_capacity >> 30
        );
    }
    println!("serving configs (Table II):");
    for p in presets::serving_preset_names() {
        println!("  {p}");
    }
    let registry = policy::snapshot();
    println!("policies (registry; custom registrations appear here too):");
    println!("  router:  {}", registry.route_names().join(", "));
    println!("  sched:   {}", registry.sched_names().join(", "));
    println!("  evict:   {}", registry.evict_names().join(", "));
    println!("  traffic: {}", registry.traffic_names().join(", "));
    println!("  cluster: {}", registry.controller_names().join(", "));
    Ok(())
}
