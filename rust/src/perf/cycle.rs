//! Cycle-level systolic-array NPU simulator.
//!
//! This is the in-repo stand-in for LLMServingSim 1.0's cycle-accurate
//! hardware-simulator integration (Table III / Fig. 3 baseline). Every
//! operator is decomposed into GEMM tiles on a `pe_dim x pe_dim` systolic
//! array plus vector-unit passes; the simulator walks the tile schedule
//! tile-by-tile, modeling the double-buffered weight pipeline (compute
//! overlaps the next tile's DMA; the visible cost per tile is
//! `max(compute, dma)` after the first).
//!
//! Walking the schedule makes pricing one op O(#tiles) instead of the trace
//! model's O(1) lookup — which is exactly the cost structure the paper
//! measures: cycle-level simulation is orders of magnitude slower per
//! simulated request than trace-driven replay.

use super::PerfModel;
use crate::model::{ModelSpec, OpInvocation, OpKind};
use crate::sim::Nanos;

/// Systolic-array hardware parameters.
#[derive(Debug, Clone)]
pub struct SystolicSpec {
    /// PE array dimension (classic TPU-style 128x128).
    pub pe_dim: u64,
    /// Core clock, Hz.
    pub freq_hz: f64,
    /// Vector unit lanes (element ops per cycle).
    pub vector_lanes: u64,
    /// DRAM bandwidth in bytes per cycle.
    pub dram_bytes_per_cycle: f64,
    /// Fixed per-op dispatch cost in cycles.
    pub dispatch_cycles: u64,
}

impl Default for SystolicSpec {
    fn default() -> Self {
        SystolicSpec {
            pe_dim: 128,
            freq_hz: 1.0e9,
            vector_lanes: 256,
            dram_bytes_per_cycle: 64.0,
            dispatch_cycles: 500,
        }
    }
}

/// Cycle-level performance model for one model architecture.
#[derive(Debug, Clone)]
pub struct CycleSim {
    pub spec: SystolicSpec,
    pub model: ModelSpec,
    name: String,
}

impl CycleSim {
    pub fn new(spec: SystolicSpec, model: ModelSpec) -> Self {
        let name = format!("cycle[{}]", model.name);
        CycleSim { spec, model, name }
    }

    /// Cycles for a tiled GEMM `(m x k) @ (k x n)`: walks the tile schedule
    /// AND every cycle within each tile's visible window, advancing a small
    /// pipeline state machine (fill -> stream -> drain, DMA countdown) one
    /// cycle at a time.
    ///
    /// Walking individual cycles is what makes this model *cycle-level* —
    /// and what makes its simulation cost proportional to simulated
    /// hardware time, exactly the cost structure the paper's Fig. 3 / Table
    /// III measure against trace-driven O(1) lookups.
    pub fn gemm_cycles(&self, m: u64, k: u64, n: u64) -> u64 {
        let p = self.spec.pe_dim;
        let tm = m.div_ceil(p);
        let tk = k.div_ceil(p);
        let tn = n.div_ceil(p);
        let mut cycles = 0u64;
        let mut pending_dma = 0u64; // DMA issued for the next tile
        let mut state = 0u64; // pipeline occupancy word (kept live)
        for mi in 0..tm {
            let rows = (m - mi * p).min(p);
            for ni in 0..tn {
                let cols = (n - ni * p).min(p);
                for ki in 0..tk {
                    let depth = (k - ki * p).min(p);
                    // Weight-stationary pass: fill the array with the weight
                    // tile (depth cycles), then stream `rows` activations
                    // through; results drain over `cols` cycles.
                    let compute = depth + rows + cols;
                    // DMA for this tile's weights (depth x cols elements,
                    // 2 bytes each) overlaps the previous tile's compute;
                    // the visible stall is the excess.
                    let dma =
                        ((depth * cols * 2) as f64 / self.spec.dram_bytes_per_cycle)
                            .ceil() as u64;
                    let visible = compute.max(pending_dma);
                    // per-cycle walk of the visible window
                    let mut dma_left = pending_dma;
                    for c in 0..visible {
                        // fill phase occupies the weight bus; stream phase
                        // clocks one activation row; drain emits partials.
                        let phase = if c < depth {
                            1
                        } else if c < depth + rows {
                            2
                        } else {
                            3
                        };
                        dma_left = dma_left.saturating_sub(1);
                        state = state
                            .rotate_left(phase)
                            .wrapping_add(c ^ dma_left);
                    }
                    std::hint::black_box(state);
                    cycles += visible;
                    pending_dma = dma.saturating_sub(compute);
                }
            }
        }
        cycles + pending_dma
    }

    /// Cycles for an elementwise/vector pass over `elems` elements,
    /// walked per cycle like the GEMM path.
    pub fn vector_cycles(&self, elems: u64, passes: u64) -> u64 {
        let total = elems.div_ceil(self.spec.vector_lanes) * passes;
        let mut state = 0u64;
        for c in 0..total {
            state = state.rotate_left(1).wrapping_add(c);
        }
        std::hint::black_box(state);
        total
    }

    /// Total cycles for one operator invocation.
    pub fn op_cycles(&self, inv: OpInvocation) -> u64 {
        let m = &self.model;
        let h = m.hidden;
        let d = m.head_dim();
        let nh = m.heads;
        let kvh = m.kv_heads * d;
        let t = inv.tokens.max(1);
        let base = self.spec.dispatch_cycles;
        base + match inv.kind {
            OpKind::QkvProj => self.gemm_cycles(t, h, h + 2 * kvh),
            OpKind::AttnPrefill => {
                let s = t;
                let mut c = 0;
                for _head in 0..nh {
                    c += self.gemm_cycles(s, d, s); // QK^T
                    c += self.vector_cycles(s * s, 3); // mask+softmax
                    c += self.gemm_cycles(s, s, d); // PV
                }
                c
            }
            OpKind::AttnDecode => {
                let batch = t;
                let ctx = inv.ctx.max(1);
                let mut c = 0;
                for _b in 0..batch {
                    for _head in 0..nh {
                        c += self.gemm_cycles(1, d, ctx);
                        c += self.vector_cycles(ctx, 2);
                        c += self.gemm_cycles(1, ctx, d);
                    }
                }
                c
            }
            OpKind::OutProj => self.gemm_cycles(t, h, h),
            OpKind::Ffn => {
                self.gemm_cycles(t, h, m.ffn) * 2
                    + self.vector_cycles(t * m.ffn, 2)
                    + self.gemm_cycles(t, m.ffn, h)
            }
            OpKind::MoeGate => {
                self.gemm_cycles(t, h, m.experts.max(1))
                    + self.vector_cycles(t * m.experts.max(1), 2)
            }
            OpKind::ExpertFfn => {
                self.gemm_cycles(t, h, m.expert_ffn) * 2
                    + self.vector_cycles(t * m.expert_ffn, 2)
                    + self.gemm_cycles(t, m.expert_ffn, h)
            }
            OpKind::LmHead => self.gemm_cycles(t, h, m.vocab),
            OpKind::RmsNorm => self.vector_cycles(t * h, 3),
        }
    }
}

impl PerfModel for CycleSim {
    fn op_latency(&self, inv: OpInvocation) -> Nanos {
        let cycles = self.op_cycles(inv);
        (cycles as f64 / self.spec.freq_hz * 1e9).round() as Nanos
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sim() -> CycleSim {
        CycleSim::new(SystolicSpec::default(), ModelSpec::tiny_dense())
    }

    #[test]
    fn gemm_cycles_scale_with_size() {
        let s = sim();
        assert!(s.gemm_cycles(256, 256, 256) > s.gemm_cycles(128, 128, 128));
        assert!(s.gemm_cycles(1, 128, 128) > 0);
    }

    #[test]
    fn gemm_tile_count_dominates_large_shapes() {
        let s = sim();
        // doubling n roughly doubles cycles for tile-aligned shapes
        let a = s.gemm_cycles(128, 128, 1024);
        let b = s.gemm_cycles(128, 128, 2048);
        let ratio = b as f64 / a as f64;
        assert!((1.7..2.3).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn latency_positive_for_all_kinds() {
        let s = sim();
        for &k in OpKind::all() {
            let inv = if k == OpKind::AttnDecode {
                OpInvocation::decode(2, 128)
            } else {
                OpInvocation::tokens(k, 16)
            };
            assert!(s.op_latency(inv) > 0, "{k}");
        }
    }

    #[test]
    fn decode_scales_with_batch_and_ctx() {
        let s = sim();
        let l1 = s.op_latency(OpInvocation::decode(1, 64));
        let l2 = s.op_latency(OpInvocation::decode(4, 64));
        let l3 = s.op_latency(OpInvocation::decode(4, 512));
        assert!(l2 > l1);
        assert!(l3 > l2);
    }

    #[test]
    fn moe_ops_need_moe_model() {
        let s = CycleSim::new(SystolicSpec::default(), ModelSpec::tiny_moe());
        assert!(s.op_latency(OpInvocation::tokens(OpKind::ExpertFfn, 8)) > 0);
    }

    #[test]
    fn deterministic() {
        let s = sim();
        let inv = OpInvocation::tokens(OpKind::Ffn, 64);
        assert_eq!(s.op_latency(inv), s.op_latency(inv));
    }

    #[test]
    fn prop_gemm_monotone_in_each_dim() {
        let s = sim();
        prop::check(
            "gemm-monotone",
            64,
            |rng| {
                (
                    1 + rng.below(512),
                    1 + rng.below(512),
                    1 + rng.below(512),
                )
            },
            |&(m, k, n)| {
                let base = s.gemm_cycles(m, k, n);
                if s.gemm_cycles(m + 128, k, n) < base {
                    return Err(format!("not monotone in m at ({m},{k},{n})"));
                }
                if s.gemm_cycles(m, k + 128, n) < base {
                    return Err(format!("not monotone in k at ({m},{k},{n})"));
                }
                if s.gemm_cycles(m, k, n + 128) < base {
                    return Err(format!("not monotone in n at ({m},{k},{n})"));
                }
                Ok(())
            },
        );
    }
}
