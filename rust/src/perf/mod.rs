//! Performance models: how the simulator prices an operator invocation.
//!
//! The paper's key advancement is **trace-driven performance modeling**
//! ([`trace::TraceDb`], fed by the operator-level profiler). Alongside it we
//! implement the comparison baselines from §III:
//!
//! * [`analytical`] — roofline model, also used to extend traces to
//!   paper-scale models via a measured calibration factor;
//! * [`cycle`] — a cycle-level systolic-array NPU simulator standing in for
//!   LLMServingSim 1.0's cycle-accurate hardware simulation;
//! * [`replay`] — cycle results memoized and replayed (LLMServingSim+).

pub mod analytical;
pub mod cycle;
pub mod hardware;
pub mod replay;
pub mod trace;

use crate::model::OpInvocation;
use crate::sim::Nanos;

/// Prices one operator invocation on one hardware target.
///
/// Implementations must be deterministic: the same invocation always costs
/// the same latency (variance enters the simulation through batching and
/// queueing dynamics, as in the paper).
///
/// `Send + Sync` is part of the contract: performance models are shared
/// behind `Arc` by every instance of a simulation, and whole simulations
/// move across worker threads in the sweep engine (DESIGN.md §5). Models
/// with internal caches must use thread-safe interior mutability
/// ([`replay::Replay`] uses `Mutex`, the ground-truth engine wraps its
/// runtime the same way).
pub trait PerfModel: Send + Sync {
    /// Latency of running `inv` on this hardware.
    fn op_latency(&self, inv: OpInvocation) -> Nanos;

    /// Human-readable name for reports.
    fn name(&self) -> &str;
}

/// Hardware description used by the analytical/cycle models and the memory
/// and network layers. Mirrors the paper's per-instance device config
/// (§III-A: memory capacity, bandwidth, interconnect).
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareSpec {
    pub name: String,
    /// Peak compute throughput, FLOP/s (fp16/bf16 tensor math).
    pub peak_flops: f64,
    /// Device memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Device memory capacity, bytes.
    pub mem_capacity: u64,
    /// Host<->device interconnect bandwidth, bytes/s (PCIe/ICI).
    pub host_bw: f64,
    /// Fixed per-kernel launch/dispatch overhead, ns.
    pub kernel_overhead: Nanos,
}

impl HardwareSpec {
    /// RTX 3090-like device (paper's GPU baseline: 24 GB, 936 GB/s).
    pub fn rtx3090() -> HardwareSpec {
        HardwareSpec {
            name: "rtx3090".into(),
            peak_flops: 71e12, // fp16 tensor
            mem_bw: 936e9,
            mem_capacity: 24 * (1 << 30),
            host_bw: 32e9, // PCIe 4.0 x16
            kernel_overhead: 8_000,
        }
    }

    /// TPU-v6e-1-like device (paper's §III-A: 32 GB, 1.6 TB/s, 800 GB/s ICI).
    pub fn tpu_v6e() -> HardwareSpec {
        HardwareSpec {
            name: "tpu-v6e".into(),
            peak_flops: 918e12, // bf16
            mem_bw: 1.6e12,
            mem_capacity: 32 * (1 << 30),
            host_bw: 800e9,
            kernel_overhead: 5_000,
        }
    }

    /// The CPU PJRT backend this repo actually profiles (tiny models).
    /// peak/bw estimated from a few cores of AVX f32 math; the trace DB is
    /// the authoritative source — this spec only seeds the roofline
    /// fallback and the memory model.
    pub fn cpu_pjrt() -> HardwareSpec {
        HardwareSpec {
            name: "cpu-pjrt".into(),
            peak_flops: 2.0e11,
            mem_bw: 2.0e10,
            mem_capacity: 8 * (1 << 30),
            host_bw: 1.0e10,
            kernel_overhead: 20_000,
        }
    }

    /// PIM-like memory-bound accelerator for expert offloading studies
    /// (Duplex-style: modest compute, very high internal bandwidth).
    pub fn pim() -> HardwareSpec {
        HardwareSpec {
            name: "pim".into(),
            peak_flops: 4e12,
            mem_bw: 4.8e12,
            mem_capacity: 48 * (1 << 30),
            host_bw: 64e9,
            kernel_overhead: 3_000,
        }
    }

    /// The four *built-in* presets only; user-profiled hardware resolves
    /// through [`HardwareSpec::resolve`] / the [`hardware`] registry.
    pub fn preset(name: &str) -> Option<HardwareSpec> {
        match name {
            "rtx3090" => Some(Self::rtx3090()),
            "tpu-v6e" => Some(Self::tpu_v6e()),
            "cpu-pjrt" => Some(Self::cpu_pjrt()),
            "pim" => Some(Self::pim()),
            _ => None,
        }
    }

    pub fn preset_names() -> &'static [&'static str] {
        &["rtx3090", "tpu-v6e", "cpu-pjrt", "pim"]
    }

    /// Resolve `name` against the global [`hardware`] registry: built-in
    /// presets plus every registered bundle (profiled devices). Unknown
    /// names error with the full candidate list — this is the resolution
    /// path behind config validation, sweep axes, and the CLI.
    pub fn resolve(name: &str) -> anyhow::Result<HardwareSpec> {
        hardware::resolve(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for n in HardwareSpec::preset_names() {
            let h = HardwareSpec::preset(n).unwrap();
            assert!(h.peak_flops > 0.0 && h.mem_bw > 0.0);
        }
        assert!(HardwareSpec::preset("abacus").is_none());
    }

    #[test]
    fn resolve_covers_builtins_and_errors_with_candidates() {
        for n in HardwareSpec::preset_names() {
            assert_eq!(
                HardwareSpec::resolve(n).unwrap(),
                HardwareSpec::preset(n).unwrap()
            );
        }
        let e = HardwareSpec::resolve("abacus").unwrap_err().to_string();
        assert!(e.contains("abacus") && e.contains("rtx3090"), "{e}");
    }

    #[test]
    fn paper_device_specs() {
        let g = HardwareSpec::rtx3090();
        assert_eq!(g.mem_capacity, 24 * (1 << 30));
        let t = HardwareSpec::tpu_v6e();
        assert_eq!(t.mem_capacity, 32 * (1 << 30));
        assert!((t.host_bw - 800e9).abs() < 1.0);
    }
}
