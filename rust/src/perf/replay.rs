//! Replay performance model: "LLMServingSim+" baseline.
//!
//! LLMServingSim 1.0 mitigated cycle-simulation cost by *computation reuse*:
//! each distinct operator shape is simulated once and replayed from a cache
//! afterwards. [`Replay`] wraps any inner [`PerfModel`] with exactly that
//! memoization; wrapping [`super::cycle::CycleSim`] reproduces the
//! LLMServingSim+ baseline of §III-D (Fig. 3).
//!
//! The cache key quantizes nothing — only exact shape repeats hit, matching
//! the original's behaviour (autoregressive decode repeats shapes heavily,
//! prefill rarely).

use std::sync::Mutex;

use crate::util::fxhash::FxHashMap;

use super::PerfModel;
use crate::model::OpInvocation;
use crate::sim::Nanos;

/// Memoizing wrapper around a slow inner model.
pub struct Replay<M: PerfModel> {
    inner: M,
    cache: Mutex<FxHashMap<(u8, u64, u64), Nanos>>,
    hits: Mutex<u64>,
    misses: Mutex<u64>,
    name: String,
}

impl<M: PerfModel> Replay<M> {
    pub fn new(inner: M) -> Self {
        let name = format!("replay[{}]", inner.name());
        Replay {
            inner,
            cache: Mutex::new(FxHashMap::default()),
            hits: Mutex::new(0),
            misses: Mutex::new(0),
            name,
        }
    }

    fn key(inv: OpInvocation) -> (u8, u64, u64) {
        let kind = crate::model::OpKind::all()
            .iter()
            .position(|&k| k == inv.kind)
            // simlint: allow(S01) — OpKind::all() enumerates every variant by construction
            .unwrap() as u8;
        (kind, inv.tokens, inv.ctx)
    }

    /// (cache hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        // simlint: allow(S01) — a poisoned counter mutex is unrecoverable; abort loudly
        (*self.hits.lock().unwrap(), *self.misses.lock().unwrap())
    }
}

impl<M: PerfModel> PerfModel for Replay<M> {
    fn op_latency(&self, inv: OpInvocation) -> Nanos {
        let key = Self::key(inv);
        // simlint: allow(S01) — a poisoned memo mutex is unrecoverable; abort loudly
        if let Some(&ns) = self.cache.lock().unwrap().get(&key) {
            // simlint: allow(S01) — a poisoned counter mutex is unrecoverable; abort loudly
            *self.hits.lock().unwrap() += 1;
            return ns;
        }
        let ns = self.inner.op_latency(inv);
        // simlint: allow(S01) — a poisoned memo mutex is unrecoverable; abort loudly
        self.cache.lock().unwrap().insert(key, ns);
        // simlint: allow(S01) — a poisoned counter mutex is unrecoverable; abort loudly
        *self.misses.lock().unwrap() += 1;
        ns
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelSpec, OpKind};
    use crate::perf::cycle::{CycleSim, SystolicSpec};

    #[test]
    fn replay_matches_inner() {
        let inner = CycleSim::new(SystolicSpec::default(), ModelSpec::tiny_dense());
        let expect = inner.op_latency(OpInvocation::tokens(OpKind::Ffn, 32));
        let replay = Replay::new(inner);
        assert_eq!(
            replay.op_latency(OpInvocation::tokens(OpKind::Ffn, 32)),
            expect
        );
    }

    #[test]
    fn second_lookup_hits_cache() {
        let inner = CycleSim::new(SystolicSpec::default(), ModelSpec::tiny_dense());
        let replay = Replay::new(inner);
        let inv = OpInvocation::decode(4, 256);
        let a = replay.op_latency(inv);
        let b = replay.op_latency(inv);
        assert_eq!(a, b);
        assert_eq!(replay.stats(), (1, 1));
    }

    #[test]
    fn different_shapes_miss() {
        let inner = CycleSim::new(SystolicSpec::default(), ModelSpec::tiny_dense());
        let replay = Replay::new(inner);
        replay.op_latency(OpInvocation::decode(4, 256));
        replay.op_latency(OpInvocation::decode(4, 257));
        replay.op_latency(OpInvocation::decode(5, 256));
        assert_eq!(replay.stats(), (0, 3));
    }
}
