//! Hardware registry + bundle format: the third first-class plugin axis.
//!
//! PR 2 made policies name-registered, PR 3 did the same for traffic
//! sources; this module closes the loop for the paper's headline claim —
//! "integration of new accelerators with a single command" (§II-A). A
//! device becomes usable *by name* everywhere a built-in preset is
//! (configs, `simulate --hardware`, `sweep --hardware all`,
//! heterogeneous-fleet instance configs) through two pieces:
//!
//! * [`HardwareRegistry`] — mirrors [`PolicyRegistry`](crate::policy):
//!   a global `OnceLock<RwLock<..>>` pre-seeded with the four built-in
//!   [`HardwareSpec`] presets, `BTreeMap` storage so enumeration is
//!   deterministic, [`register_hardware`] for customs, and candidate-list
//!   errors for unknown names.
//! * [`HardwareBundle`] — the serializable artifact of the profile
//!   pipeline: one JSON file carrying the [`HardwareSpec`], the device's
//!   profiled [`TraceDb`] samples, and the derived per-op calibration
//!   factors (measured / roofline). `profile --emit-bundle FILE` writes
//!   one; `import-hardware` / `--hardware-dir DIR` load them back into the
//!   registry.
//!
//! Pricing semantics ([`HardwareBundle::perf_on`]): where the bundle's
//! trace has samples for the simulated model, invocations are priced by
//! trace interpolation; everywhere else (unprofiled op kinds, or a
//! different model than the one profiled) the calibrated roofline takes
//! over, scaled by the bundle's measured efficiency factors. Built-in
//! presets carry no trace, so their pricing under every backend is exactly
//! what it was before this module existed.
//!
//! Determinism: registry reads are lock-guarded snapshots of immutable
//! `Arc<HardwareBundle>` entries, so sweep workers resolving the same name
//! always see the same bytes — sweeps over registered hardware stay
//! byte-identical at any worker count.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, OnceLock, RwLock};

use super::analytical::{Calibrated, Roofline};
use super::trace::TraceDb;
use super::{HardwareSpec, PerfModel};
use crate::model::{ModelSpec, OpInvocation, OpKind};
use crate::sim::Nanos;
use crate::util::json::{self, Value};

/// Schema tag stamped into every bundle file; loads reject anything else.
pub const BUNDLE_SCHEMA: &str = "hardware-bundle-v1";

// ---------------------------------------------------------------------------
// HardwareSpec JSON (lives here so perf/mod.rs stays a pure data module)
// ---------------------------------------------------------------------------

/// Serialize a [`HardwareSpec`] to the bundle's `hardware` object.
pub fn spec_to_json(spec: &HardwareSpec) -> Value {
    Value::obj(vec![
        ("name", Value::str(spec.name.clone())),
        ("peak_flops", Value::float(spec.peak_flops)),
        ("mem_bw", Value::float(spec.mem_bw)),
        ("mem_capacity", Value::int(spec.mem_capacity as i64)),
        ("host_bw", Value::float(spec.host_bw)),
        ("kernel_overhead_ns", Value::int(spec.kernel_overhead as i64)),
    ])
}

/// Parse a [`HardwareSpec`] from the bundle's `hardware` object, rejecting
/// missing names and non-positive / non-finite rates.
pub fn spec_from_json(v: &Value) -> anyhow::Result<HardwareSpec> {
    let name = v
        .get("name")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("hardware spec missing 'name'"))?
        .to_string();
    let spec = HardwareSpec {
        name,
        peak_flops: v.get("peak_flops").as_f64().unwrap_or(0.0),
        mem_bw: v.get("mem_bw").as_f64().unwrap_or(0.0),
        mem_capacity: v.get("mem_capacity").as_u64().unwrap_or(0),
        host_bw: v.get("host_bw").as_f64().unwrap_or(0.0),
        kernel_overhead: v.get("kernel_overhead_ns").as_u64().unwrap_or(0),
    };
    validate_spec(&spec)?;
    Ok(spec)
}

fn validate_spec(spec: &HardwareSpec) -> anyhow::Result<()> {
    if spec.name.is_empty() {
        anyhow::bail!("hardware spec has an empty name");
    }
    for (field, v) in [
        ("peak_flops", spec.peak_flops),
        ("mem_bw", spec.mem_bw),
        ("host_bw", spec.host_bw),
    ] {
        if !(v.is_finite() && v > 0.0) {
            anyhow::bail!(
                "hardware '{}': {field} must be finite and > 0 (got {v})",
                spec.name
            );
        }
    }
    if spec.mem_capacity == 0 {
        anyhow::bail!("hardware '{}': mem_capacity must be > 0", spec.name);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// HardwareBundle
// ---------------------------------------------------------------------------

/// One hardware target, fully described: spec + profiled trace samples +
/// derived per-op calibration factors. Built-in presets are spec-only
/// bundles; `profile --emit-bundle` produces trace-backed ones.
#[derive(Debug, Clone)]
pub struct HardwareBundle {
    pub spec: HardwareSpec,
    /// Profiled samples for this device (for one model); `None` for
    /// spec-only bundles. `Arc`-shared so every simulation instance (and
    /// every sweep grid point) prices through the same immutable DB
    /// instead of deep-copying the sample vectors.
    pub trace: Option<Arc<TraceDb>>,
    /// Measured/roofline efficiency per op kind, derived from the trace at
    /// bundle-emission time and stored in the file so loaders do not need
    /// the profiled model preset to recompute it.
    pub calibration: Vec<(OpKind, f64)>,
}

impl HardwareBundle {
    /// A bundle carrying only the spec (how built-ins are registered).
    pub fn spec_only(spec: HardwareSpec) -> HardwareBundle {
        HardwareBundle {
            spec,
            trace: None,
            calibration: vec![],
        }
    }

    /// Build a bundle from a profiled trace DB: derives the calibration
    /// factors against the roofline of `spec` for the profiled model. The
    /// trace's hardware tag must match `spec.name` (that name is the
    /// registry key), and the profiled model must be a known preset.
    pub fn from_trace(spec: HardwareSpec, trace: TraceDb) -> anyhow::Result<HardwareBundle> {
        validate_spec(&spec)?;
        if trace.hardware != spec.name {
            anyhow::bail!(
                "trace was profiled on '{}' but the bundle spec is named '{}'",
                trace.hardware,
                spec.name
            );
        }
        let model = ModelSpec::preset(&trace.model).ok_or_else(|| {
            anyhow::anyhow!(
                "trace profiled unknown model '{}' (known: {:?})",
                trace.model,
                ModelSpec::preset_names()
            )
        })?;
        let roofline = Roofline::new(spec.clone(), model);
        let calibration = trace.calibration(&roofline);
        let bundle = HardwareBundle {
            spec,
            trace: Some(Arc::new(trace)),
            calibration,
        };
        bundle.validate()?;
        Ok(bundle)
    }

    /// True when the bundle carries profiled data (trace samples and/or
    /// calibration factors) — i.e. pricing through it differs from the
    /// pure roofline of its spec.
    pub fn has_perf_data(&self) -> bool {
        self.trace.is_some() || !self.calibration.is_empty()
    }

    /// Full consistency check, applied on construction and on every load:
    /// valid spec, matching trace tag, non-empty + duplicate-free trace
    /// grids, finite positive calibration factors.
    pub fn validate(&self) -> anyhow::Result<()> {
        validate_spec(&self.spec)?;
        if let Some(db) = &self.trace {
            if db.hardware != self.spec.name {
                anyhow::bail!(
                    "bundle '{}': trace hardware tag is '{}'",
                    self.spec.name,
                    db.hardware
                );
            }
            if db.is_empty() {
                anyhow::bail!(
                    "bundle '{}': trace section has no samples (drop it or re-profile)",
                    self.spec.name
                );
            }
            for kind in db.kinds().collect::<Vec<_>>() {
                let samples = db.samples(kind);
                for w in samples.windows(2) {
                    if w[0].0 == w[1].0 && w[0].1 == w[1].1 {
                        anyhow::bail!(
                            "bundle '{}': duplicate {kind} sample at grid point \
                             ({}, {})",
                            self.spec.name,
                            w[0].0,
                            w[0].1
                        );
                    }
                }
            }
        }
        for (kind, f) in &self.calibration {
            if !(f.is_finite() && *f > 0.0) {
                anyhow::bail!(
                    "bundle '{}': calibration factor for {kind} must be finite \
                     and > 0 (got {f})",
                    self.spec.name
                );
            }
        }
        Ok(())
    }

    /// The performance model this bundle implies for `model` on the
    /// (possibly override-adjusted) spec `hw`: trace interpolation where
    /// the profiled samples apply, calibrated roofline everywhere else.
    pub fn perf_on(&self, hw: &HardwareSpec, model: &ModelSpec) -> Arc<dyn PerfModel> {
        Arc::new(BundlePerf::new(self, hw, model))
    }

    // ---- persistence ------------------------------------------------------

    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("schema", Value::str(BUNDLE_SCHEMA)),
            ("hardware", spec_to_json(&self.spec)),
            (
                "calibration",
                Value::obj(
                    self.calibration
                        .iter()
                        .map(|(k, f)| (k.as_str(), Value::float(*f)))
                        .collect(),
                ),
            ),
        ];
        if let Some(db) = &self.trace {
            fields.push(("trace", db.to_json()));
        }
        Value::obj(fields)
    }

    pub fn from_json(v: &Value) -> anyhow::Result<HardwareBundle> {
        match v.get("schema").as_str() {
            Some(BUNDLE_SCHEMA) => {}
            Some(other) => anyhow::bail!(
                "unsupported hardware-bundle schema '{other}' (expected \
                 '{BUNDLE_SCHEMA}')"
            ),
            None => anyhow::bail!(
                "not a hardware bundle: missing 'schema' field (expected \
                 '{BUNDLE_SCHEMA}')"
            ),
        }
        let spec = spec_from_json(v.get("hardware"))?;
        // Bundle files are canonical artifacts straight from the profiler:
        // grid points arrive sorted and duplicate-free. Reject scrambled
        // files (usually a hand-edit or truncation) instead of silently
        // re-sorting them.
        if let Some(ops) = v.get("trace").get("ops").as_obj() {
            for (op_name, op) in ops {
                let grid = op.get("grid").as_str().unwrap_or("tokens");
                let pts = op.get("points").as_arr().unwrap_or(&[]);
                for i in 1..pts.len() {
                    let coord = |p: &Value| -> (i64, i64) {
                        match grid {
                            "batch_ctx" => (
                                p.idx(0).as_i64().unwrap_or(0),
                                p.idx(1).as_i64().unwrap_or(0),
                            ),
                            _ => (p.idx(0).as_i64().unwrap_or(0), 0),
                        }
                    };
                    if coord(&pts[i]) <= coord(&pts[i - 1]) {
                        anyhow::bail!(
                            "bundle trace op '{op_name}': grid points must be \
                             strictly increasing (sorted, duplicate-free); \
                             point {i} is out of order"
                        );
                    }
                }
            }
        }
        let trace = if v.get("trace").is_null() {
            None
        } else {
            Some(Arc::new(TraceDb::from_json(v.get("trace"))?))
        };
        let mut calibration = vec![];
        if let Some(obj) = v.get("calibration").as_obj() {
            for (name, fv) in obj {
                let kind = OpKind::from_str(name).ok_or_else(|| {
                    anyhow::anyhow!("calibration names unknown op kind '{name}'")
                })?;
                let f = fv
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("calibration factor for '{name}' is not a number"))?;
                calibration.push((kind, f));
            }
        }
        // canonical order = OpKind declaration order (what
        // `TraceDb::calibration` emits), not the JSON object's
        // string-sorted key order — keeps round trips exact
        calibration.sort_by_key(|&(k, _)| k);
        let bundle = HardwareBundle {
            spec,
            trace,
            calibration,
        };
        bundle.validate()?;
        Ok(bundle)
    }

    pub fn load(path: &Path) -> anyhow::Result<HardwareBundle> {
        Self::from_json(&json::load_file(path)?)
            .map_err(|e| anyhow::anyhow!("loading bundle {}: {e}", path.display()))
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        json::save_file(path, &self.to_json())
    }
}

/// Bundle-backed performance model: per-invocation trace lookup with
/// calibrated-roofline fallback. The trace applies only when it was
/// profiled for the simulated model; otherwise every op falls back.
pub struct BundlePerf {
    trace: Option<Arc<TraceDb>>,
    fallback: Calibrated,
    name: String,
}

impl BundlePerf {
    pub fn new(bundle: &HardwareBundle, hw: &HardwareSpec, model: &ModelSpec) -> BundlePerf {
        // Arc clone: every instance shares the bundle's immutable DB.
        let trace = match &bundle.trace {
            Some(db) if db.model == model.name => Some(Arc::clone(db)),
            _ => None,
        };
        let fallback = Calibrated::new(
            Roofline::new(hw.clone(), model.clone()),
            bundle.calibration.clone(),
        );
        let name = format!("bundle[{}/{}]", bundle.spec.name, model.name);
        BundlePerf {
            trace,
            fallback,
            name,
        }
    }
}

impl PerfModel for BundlePerf {
    fn op_latency(&self, inv: OpInvocation) -> Nanos {
        if let Some(db) = &self.trace {
            if let Some(ns) = db.lookup(inv) {
                return ns.round() as Nanos;
            }
        }
        self.fallback.op_latency(inv)
    }

    fn name(&self) -> &str {
        &self.name
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Maps hardware names to bundles. Entries are `Arc`-shared so cloning the
/// registry (snapshots) is cheap and resolved bundles are immutable.
#[derive(Debug, Clone)]
pub struct HardwareRegistry {
    entries: BTreeMap<String, Arc<HardwareBundle>>,
}

impl Default for HardwareRegistry {
    /// The built-in registry ([`HardwareRegistry::builtins`]).
    fn default() -> Self {
        Self::builtins()
    }
}

impl HardwareRegistry {
    /// A registry with no entries.
    pub fn empty() -> Self {
        HardwareRegistry {
            entries: BTreeMap::new(),
        }
    }

    /// A registry pre-seeded with the four built-in presets (spec-only —
    /// their pricing is whatever the selected perf backend computes).
    pub fn builtins() -> Self {
        let mut r = Self::empty();
        for name in HardwareSpec::preset_names() {
            // simlint: allow(S01) — preset_names() and preset() cover the same fixed set
            let spec = HardwareSpec::preset(name).expect("built-in preset resolves");
            r.entries
                .insert(spec.name.clone(), Arc::new(HardwareBundle::spec_only(spec)));
        }
        r
    }

    /// Register (or replace — last wins) a bundle under its spec name.
    ///
    /// Replacing a **built-in** preset is allowed (re-profiling `cpu-pjrt`
    /// itself is the honest default workflow) but logged loudly: from that
    /// point the name prices through the bundle, not the pure roofline.
    pub fn register(&mut self, bundle: HardwareBundle) -> anyhow::Result<()> {
        bundle.validate()?;
        if HardwareSpec::preset_names().contains(&bundle.spec.name.as_str())
            && bundle.has_perf_data()
        {
            log::warn!(
                "hardware bundle '{}' shadows the built-in preset of the same \
                 name: it now prices through the bundle's trace/calibration \
                 instead of the pure roofline",
                bundle.spec.name
            );
        }
        self.entries
            .insert(bundle.spec.name.clone(), Arc::new(bundle));
        Ok(())
    }

    pub fn has(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// All registered hardware names, sorted (deterministic enumeration —
    /// this is what `sweep --hardware all` expands to).
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    /// The full bundle registered under `name`.
    pub fn bundle(&self, name: &str) -> Option<Arc<HardwareBundle>> {
        self.entries.get(name).cloned()
    }

    /// Resolve `name` to its spec, erroring with the candidate list.
    pub fn resolve(&self, name: &str) -> anyhow::Result<HardwareSpec> {
        match self.entries.get(name) {
            Some(b) => Ok(b.spec.clone()),
            None => Err(self.unknown(name)),
        }
    }

    /// Error (with the candidate list) unless `name` is registered.
    /// Existence check only — nothing is cloned.
    pub fn check(&self, name: &str) -> anyhow::Result<()> {
        if self.has(name) {
            Ok(())
        } else {
            Err(self.unknown(name))
        }
    }

    fn unknown(&self, name: &str) -> anyhow::Error {
        anyhow::anyhow!(
            "unknown hardware '{name}' (registered: {}; profile a device and \
             load its bundle with `import-hardware` or `--hardware-dir`)",
            self.names().join("|")
        )
    }
}

// ---------------------------------------------------------------------------
// Global registry
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<RwLock<HardwareRegistry>> = OnceLock::new();

/// The process-wide hardware registry, pre-seeded with the built-ins.
pub fn global() -> &'static RwLock<HardwareRegistry> {
    GLOBAL.get_or_init(|| RwLock::new(HardwareRegistry::builtins()))
}

/// A point-in-time copy of the global registry (cheap: bundles are
/// `Arc`-shared).
pub fn snapshot() -> HardwareRegistry {
    global()
        .read()
        // simlint: allow(S01) — poisoned global registry is unrecoverable; abort loudly
        .expect("hardware registry lock poisoned")
        .clone()
}

/// Register a hardware bundle in the global registry (last wins). After
/// this call the device's name resolves in configs, `simulate --hardware`,
/// and `sweep --hardware all` exactly like a built-in preset.
pub fn register_hardware(bundle: HardwareBundle) -> anyhow::Result<()> {
    global()
        .write()
        // simlint: allow(S01) — poisoned global registry is unrecoverable; abort loudly
        .expect("hardware registry lock poisoned")
        .register(bundle)
}

/// Resolve a hardware name against the global registry, erroring with the
/// candidate list. This is the single resolution path behind
/// [`HardwareSpec::resolve`] and every config/sweep lookup.
pub fn resolve(name: &str) -> anyhow::Result<HardwareSpec> {
    global()
        .read()
        // simlint: allow(S01) — poisoned global registry is unrecoverable; abort loudly
        .expect("hardware registry lock poisoned")
        .resolve(name)
}

/// The bundle registered under `name` in the global registry, if any.
pub fn bundle_for(name: &str) -> Option<Arc<HardwareBundle>> {
    global()
        .read()
        // simlint: allow(S01) — poisoned global registry is unrecoverable; abort loudly
        .expect("hardware registry lock poisoned")
        .bundle(name)
}

/// All hardware names registered globally, sorted.
pub fn registered_names() -> Vec<String> {
    global()
        .read()
        // simlint: allow(S01) — poisoned global registry is unrecoverable; abort loudly
        .expect("hardware registry lock poisoned")
        .names()
}

/// Load every `*.json` bundle in `dir` (sorted by file name, so
/// registration order — and last-wins conflicts — are deterministic) into
/// the global registry. Returns the registered hardware names.
pub fn load_bundle_dir(dir: &Path) -> anyhow::Result<Vec<String>> {
    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading hardware dir {}: {e}", dir.display()))?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    files.sort();
    let mut names = vec![];
    for path in files {
        let bundle = HardwareBundle::load(&path)?;
        names.push(bundle.spec.name.clone());
        register_hardware(bundle)?;
    }
    Ok(names)
}

/// Load, validate, and globally register a single bundle file. Returns the
/// bundle for reporting.
pub fn import_bundle_file(path: &Path) -> anyhow::Result<HardwareBundle> {
    let bundle = HardwareBundle::load(path)?;
    register_hardware(bundle.clone())?;
    Ok(bundle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::OpKind;

    fn trace_for(hw: &str) -> TraceDb {
        let mut db = TraceDb::new(hw, "tiny-dense");
        for t in [1u64, 4, 16, 64] {
            db.add_tokens(OpKind::Ffn, t, 2_000 * t);
            db.add_tokens(OpKind::QkvProj, t, 1_000 * t);
        }
        for b in [1u64, 2, 4] {
            for c in [64u64, 256] {
                db.add_batch_ctx(OpKind::AttnDecode, b, c, 40 * b * c);
            }
        }
        db
    }

    fn spec_named(name: &str) -> HardwareSpec {
        HardwareSpec {
            name: name.to_string(),
            ..HardwareSpec::cpu_pjrt()
        }
    }

    #[test]
    fn builtins_preseeded_and_sorted() {
        let reg = HardwareRegistry::builtins();
        let names = reg.names();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
        for n in HardwareSpec::preset_names() {
            assert!(reg.has(n), "built-in '{n}' missing");
            assert_eq!(reg.resolve(n).unwrap(), HardwareSpec::preset(n).unwrap());
            assert!(!reg.bundle(n).unwrap().has_perf_data());
        }
    }

    #[test]
    fn unknown_names_error_with_candidates() {
        let reg = HardwareRegistry::builtins();
        let e = reg.resolve("abacus").unwrap_err().to_string();
        assert!(e.contains("abacus") && e.contains("rtx3090"), "{e}");
        let e = reg.check("warp-drive").unwrap_err().to_string();
        assert!(e.contains("warp-drive") && e.contains("tpu-v6e"), "{e}");
    }

    #[test]
    fn bundle_json_roundtrip() {
        let bundle =
            HardwareBundle::from_trace(spec_named("unit-npu"), trace_for("unit-npu"))
                .unwrap();
        assert!(bundle.has_perf_data());
        assert!(!bundle.calibration.is_empty());
        let back = HardwareBundle::from_json(&bundle.to_json()).unwrap();
        assert_eq!(back.spec, bundle.spec);
        assert_eq!(back.calibration, bundle.calibration);
        let (a, b) = (back.trace.unwrap(), bundle.trace.clone().unwrap());
        assert_eq!(a.samples(OpKind::Ffn), b.samples(OpKind::Ffn));
        assert_eq!(a.samples(OpKind::AttnDecode), b.samples(OpKind::AttnDecode));
    }

    #[test]
    fn bundle_rejects_malformed() {
        // wrong/missing schema
        let e = HardwareBundle::from_json(&json::parse("{}").unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("schema"), "{e}");
        let e = HardwareBundle::from_json(
            &json::parse(r#"{"schema": "hardware-bundle-v0"}"#).unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("hardware-bundle-v0"), "{e}");
        // tag mismatch
        let e = HardwareBundle::from_trace(spec_named("npu-a"), trace_for("npu-b"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("npu-a") && e.contains("npu-b"), "{e}");
        // degenerate spec numbers
        let mut spec = spec_named("npu-a");
        spec.peak_flops = 0.0;
        assert!(HardwareBundle::spec_only(spec).validate().is_err());
        // empty trace section
        let empty = HardwareBundle {
            spec: spec_named("npu-a"),
            trace: Some(Arc::new(TraceDb::new("npu-a", "tiny-dense"))),
            calibration: vec![],
        };
        let e = empty.validate().unwrap_err().to_string();
        assert!(e.contains("no samples"), "{e}");
        // non-positive calibration
        let bad = HardwareBundle {
            spec: spec_named("npu-a"),
            trace: None,
            calibration: vec![(OpKind::Ffn, -1.0)],
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn bundle_perf_prefers_trace_and_falls_back() {
        let bundle =
            HardwareBundle::from_trace(spec_named("unit-npu"), trace_for("unit-npu"))
                .unwrap();
        let model = ModelSpec::tiny_dense();
        let perf = bundle.perf_on(&bundle.spec, &model);
        assert!(perf.name().starts_with("bundle[unit-npu/"));
        // profiled op at a grid point: exact trace value
        assert_eq!(perf.op_latency(OpInvocation::tokens(OpKind::Ffn, 16)), 32_000);
        // unprofiled op kind: calibrated roofline, not a panic
        assert!(perf.op_latency(OpInvocation::tokens(OpKind::LmHead, 16)) > 0);
        // different model: trace does not apply, fallback prices everything
        let other = ModelSpec::llama31_8b();
        let perf_other = bundle.perf_on(&bundle.spec, &other);
        assert!(perf_other.op_latency(OpInvocation::tokens(OpKind::Ffn, 16)) > 0);
    }

    #[test]
    fn global_registration_resolves_and_lists() {
        let bundle =
            HardwareBundle::from_trace(spec_named("unit-global-npu"), trace_for("unit-global-npu"))
                .unwrap();
        register_hardware(bundle).unwrap();
        assert!(registered_names().contains(&"unit-global-npu".to_string()));
        let spec = resolve("unit-global-npu").unwrap();
        assert_eq!(spec.name, "unit-global-npu");
        assert!(bundle_for("unit-global-npu").unwrap().has_perf_data());
        // unknown names list the custom entry among the candidates now
        let e = resolve("nonexistent-npu").unwrap_err().to_string();
        assert!(e.contains("unit-global-npu"), "{e}");
    }

    #[test]
    fn bundle_dir_loads_sorted() {
        let dir = std::env::temp_dir().join("llmss_hw_unit_dir");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for name in ["unit-dir-b", "unit-dir-a"] {
            let bundle =
                HardwareBundle::from_trace(spec_named(name), trace_for(name)).unwrap();
            bundle.save(&dir.join(format!("{name}.json"))).unwrap();
        }
        // non-json files are ignored
        std::fs::write(dir.join("notes.txt"), "not a bundle").unwrap();
        let names = load_bundle_dir(&dir).unwrap();
        assert_eq!(names, vec!["unit-dir-a", "unit-dir-b"], "sorted by file name");
        assert!(resolve("unit-dir-a").is_ok() && resolve("unit-dir-b").is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
