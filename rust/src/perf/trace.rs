//! Trace-driven performance model (the paper's §II-A headline feature).
//!
//! A [`TraceDb`] holds per-operator latency measurements on a grid of shapes
//! (produced by the operator-level profiler, `runtime::profiler`) and prices
//! arbitrary invocations by piecewise-linear interpolation:
//!
//! * 1-D operators (GEMMs, norms): linear in token count between grid
//!   points, linear extrapolation beyond the last segment;
//! * decode attention: bilinear in (batch, context).
//!
//! The DB also derives per-op **calibration factors** (measured / roofline)
//! so the analytical model can extend this hardware's behaviour to model
//! configs that were never profiled (paper-scale Llama/Phi presets).

use std::collections::BTreeMap;
use std::path::Path;

use super::analytical::Roofline;
use super::PerfModel;
use crate::model::{OpInvocation, OpKind};
use crate::sim::Nanos;
use crate::util::json::{self, Value};

/// Latency samples for one operator.
#[derive(Debug, Clone, PartialEq)]
pub enum OpTrace {
    /// `(tokens, ns)`, sorted by tokens.
    Tokens(Vec<(u64, u64)>),
    /// `(batch, ctx, ns)`, sorted by (batch, ctx); forms a full grid.
    BatchCtx(Vec<(u64, u64, u64)>),
}

/// Profiled operator-latency database for one (hardware, model) pair.
#[derive(Debug, Clone, Default)]
pub struct TraceDb {
    pub hardware: String,
    pub model: String,
    ops: BTreeMap<OpKind, OpTrace>,
    /// Distinct `(batches, ctxs)` axis values per decode-grid op, maintained
    /// on insertion so the per-invocation interpolation never re-derives
    /// (and never allocates) them.
    axes: BTreeMap<OpKind, (Vec<u64>, Vec<u64>)>,
    name: String,
}

impl TraceDb {
    pub fn new(hardware: &str, model: &str) -> Self {
        TraceDb {
            hardware: hardware.to_string(),
            model: model.to_string(),
            ops: BTreeMap::new(),
            axes: BTreeMap::new(),
            name: format!("trace[{hardware}/{model}]"),
        }
    }

    /// Insert a 1-D sample.
    pub fn add_tokens(&mut self, kind: OpKind, tokens: u64, ns: u64) {
        match self
            .ops
            .entry(kind)
            .or_insert_with(|| OpTrace::Tokens(vec![]))
        {
            OpTrace::Tokens(v) => {
                v.push((tokens, ns));
                v.sort();
            }
            // simlint: allow(S01) — mixing grid shapes for one op kind is a caller bug
            _ => panic!("{kind} is a batch/ctx op"),
        }
    }

    /// Insert a decode-grid sample.
    pub fn add_batch_ctx(&mut self, kind: OpKind, batch: u64, ctx: u64, ns: u64) {
        match self
            .ops
            .entry(kind)
            .or_insert_with(|| OpTrace::BatchCtx(vec![]))
        {
            OpTrace::BatchCtx(v) => {
                v.push((batch, ctx, ns));
                v.sort();
                // Re-derive the grid axes here (insertion is load/profile
                // time) so `lookup` stays allocation-free on the hot path.
                let mut batches: Vec<u64> = v.iter().map(|p| p.0).collect();
                batches.dedup(); // already sorted by batch first
                let mut ctxs: Vec<u64> = v.iter().map(|p| p.1).collect();
                ctxs.sort();
                ctxs.dedup();
                self.axes.insert(kind, (batches, ctxs));
            }
            // simlint: allow(S01) — mixing grid shapes for one op kind is a caller bug
            _ => panic!("{kind} is a tokens op"),
        }
    }

    pub fn kinds(&self) -> impl Iterator<Item = OpKind> + '_ {
        self.ops.keys().copied()
    }

    pub fn has(&self, kind: OpKind) -> bool {
        self.ops.contains_key(&kind)
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Raw samples for one kind, normalized to `(a, b, ns)` triples:
    /// `(tokens, 0, ns)` for 1-D ops, `(batch, ctx, ns)` for decode.
    pub fn samples(&self, kind: OpKind) -> Vec<(u64, u64, u64)> {
        match self.ops.get(&kind) {
            None => vec![],
            Some(OpTrace::Tokens(v)) => v.iter().map(|&(t, ns)| (t, 0, ns)).collect(),
            Some(OpTrace::BatchCtx(v)) => v.clone(),
        }
    }

    // ---- interpolation ----------------------------------------------------

    fn interp_tokens(points: &[(u64, u64)], t: u64) -> f64 {
        debug_assert!(!points.is_empty());
        let t = t as f64;
        if points.len() == 1 {
            // single point: scale proportionally (latency ~ tokens for GEMMs)
            let (t0, l0) = points[0];
            return l0 as f64 * (t / t0 as f64).max(0.0);
        }
        // clamp below: linear from first segment through origin-ish region
        let idx = points.partition_point(|&(x, _)| (x as f64) < t);
        let (i0, i1) = if idx == 0 {
            (0, 1)
        } else if idx >= points.len() {
            (points.len() - 2, points.len() - 1)
        } else {
            (idx - 1, idx)
        };
        let (x0, y0) = (points[i0].0 as f64, points[i0].1 as f64);
        let (x1, y1) = (points[i1].0 as f64, points[i1].1 as f64);
        let slope = (y1 - y0) / (x1 - x0);
        (y0 + slope * (t - x0)).max(0.0)
    }

    /// `batches`/`ctxs` are the precomputed distinct axis values of the
    /// (assumed full) grid, maintained by [`TraceDb::add_batch_ctx`].
    fn interp_batch_ctx(
        points: &[(u64, u64, u64)],
        batches: &[u64],
        ctxs: &[u64],
        b: u64,
        c: u64,
    ) -> f64 {
        let lookup = |bb: u64, cc: u64| -> Option<f64> {
            points
                .iter()
                .find(|p| p.0 == bb && p.1 == cc)
                .map(|p| p.2 as f64)
        };
        // 1-D interpolation helper over an axis.
        let bracket = |axis: &[u64], x: u64| -> (u64, u64, f64) {
            let xf = x as f64;
            if axis.len() == 1 {
                return (axis[0], axis[0], 0.0);
            }
            let idx = axis.partition_point(|&a| (a as f64) < xf);
            let (i0, i1) = if idx == 0 {
                (0, 1)
            } else if idx >= axis.len() {
                (axis.len() - 2, axis.len() - 1)
            } else {
                (idx - 1, idx)
            };
            let (a0, a1) = (axis[i0] as f64, axis[i1] as f64);
            let w = if a1 > a0 { (xf - a0) / (a1 - a0) } else { 0.0 };
            (axis[i0], axis[i1], w)
        };
        let (b0, b1, wb) = bracket(batches, b);
        let (c0, c1, wc) = bracket(ctxs, c);
        let get = |bb, cc| lookup(bb, cc).unwrap_or_else(|| {
            // sparse grid fallback: nearest by batch then ctx
            points
                .iter()
                .min_by_key(|p| {
                    (p.0 as i64 - bb as i64).abs() * 1_000_000
                        + (p.1 as i64 - cc as i64).abs()
                })
                .map(|p| p.2 as f64)
                .unwrap_or(0.0)
        });
        let y00 = get(b0, c0);
        let y01 = get(b0, c1);
        let y10 = get(b1, c0);
        let y11 = get(b1, c1);
        let y0 = y00 * (1.0 - wc) + y01 * wc;
        let y1 = y10 * (1.0 - wc) + y11 * wc;
        (y0 * (1.0 - wb) + y1 * wb).max(0.0)
    }

    /// Interpolated latency for `inv`; `None` if the op was never profiled.
    pub fn lookup(&self, inv: OpInvocation) -> Option<f64> {
        match self.ops.get(&inv.kind)? {
            OpTrace::Tokens(pts) => Some(Self::interp_tokens(pts, inv.tokens)),
            OpTrace::BatchCtx(pts) => {
                // The axes entry is written by the only place that creates a
                // BatchCtx trace (`add_batch_ctx`); a miss means no samples.
                let (batches, ctxs) = self.axes.get(&inv.kind)?;
                Some(Self::interp_batch_ctx(pts, batches, ctxs, inv.tokens, inv.ctx))
            }
        }
    }

    // ---- calibration -------------------------------------------------------

    /// Mean measured/roofline ratio per op kind, for extending this
    /// hardware's behaviour to unprofiled model configs.
    pub fn calibration(&self, roofline: &Roofline) -> Vec<(OpKind, f64)> {
        let mut out = vec![];
        for (&kind, tr) in &self.ops {
            let mut ratios = vec![];
            let mut push = |inv: OpInvocation, ns: u64| {
                let ideal = roofline.raw_latency(inv) * 1e9;
                if ideal > 0.0 && ns > 0 {
                    ratios.push(ns as f64 / ideal);
                }
            };
            match tr {
                OpTrace::Tokens(pts) => {
                    for &(t, ns) in pts {
                        let inv = if kind == OpKind::AttnPrefill {
                            OpInvocation::prefill(t)
                        } else {
                            OpInvocation::tokens(kind, t)
                        };
                        push(inv, ns);
                    }
                }
                OpTrace::BatchCtx(pts) => {
                    for &(b, c, ns) in pts {
                        push(OpInvocation::decode(b, c), ns);
                    }
                }
            }
            if !ratios.is_empty() {
                let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
                out.push((kind, mean));
            }
        }
        out
    }

    // ---- persistence -------------------------------------------------------

    pub fn to_json(&self) -> Value {
        let mut ops = Vec::new();
        for (kind, tr) in &self.ops {
            let (grid, pts) = match tr {
                OpTrace::Tokens(v) => (
                    "tokens",
                    v.iter()
                        .map(|&(t, ns)| {
                            Value::arr(vec![Value::int(t as i64), Value::int(ns as i64)])
                        })
                        .collect::<Vec<_>>(),
                ),
                OpTrace::BatchCtx(v) => (
                    "batch_ctx",
                    v.iter()
                        .map(|&(b, c, ns)| {
                            Value::arr(vec![
                                Value::int(b as i64),
                                Value::int(c as i64),
                                Value::int(ns as i64),
                            ])
                        })
                        .collect::<Vec<_>>(),
                ),
            };
            ops.push((
                kind.as_str(),
                Value::obj(vec![
                    ("grid", Value::str(grid)),
                    ("points", Value::arr(pts)),
                ]),
            ));
        }
        Value::obj(vec![
            ("hardware", Value::str(self.hardware.clone())),
            ("model", Value::str(self.model.clone())),
            ("ops", Value::obj(ops)),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<TraceDb> {
        let hardware = v
            .get("hardware")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("trace missing 'hardware'"))?
            .to_string();
        let model = v
            .get("model")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("trace missing 'model'"))?
            .to_string();
        let mut db = TraceDb::new(&hardware, &model);
        let ops = v
            .get("ops")
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("trace missing 'ops'"))?;
        for (name, op) in ops {
            let kind = OpKind::from_str(name)
                .ok_or_else(|| anyhow::anyhow!("unknown op kind '{name}'"))?;
            let grid = op.get("grid").as_str().unwrap_or("tokens");
            let pts = op
                .get("points")
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("op '{name}' missing points"))?;
            for p in pts {
                match grid {
                    "tokens" => db.add_tokens(
                        kind,
                        p.idx(0)
                            .as_u64()
                            .ok_or_else(|| anyhow::anyhow!("bad point in '{name}'"))?,
                        p.idx(1)
                            .as_u64()
                            .ok_or_else(|| anyhow::anyhow!("bad point in '{name}'"))?,
                    ),
                    "batch_ctx" => db.add_batch_ctx(
                        kind,
                        p.idx(0)
                            .as_u64()
                            .ok_or_else(|| anyhow::anyhow!("bad point in '{name}'"))?,
                        p.idx(1)
                            .as_u64()
                            .ok_or_else(|| anyhow::anyhow!("bad point in '{name}'"))?,
                        p.idx(2)
                            .as_u64()
                            .ok_or_else(|| anyhow::anyhow!("bad point in '{name}'"))?,
                    ),
                    g => anyhow::bail!("unknown grid kind '{g}'"),
                }
            }
        }
        // Reject ambiguous grids: duplicate coordinates would make
        // interpolation divide by a zero-width segment (inf/NaN latencies
        // downstream). Insertion sorts samples, so duplicates are adjacent.
        for kind in db.kinds().collect::<Vec<_>>() {
            let samples = db.samples(kind);
            for w in samples.windows(2) {
                if w[0].0 == w[1].0 && w[0].1 == w[1].1 {
                    anyhow::bail!(
                        "trace op '{kind}' has duplicate grid point ({}, {})",
                        w[0].0,
                        w[0].1
                    );
                }
            }
        }
        Ok(db)
    }

    pub fn load(path: &Path) -> anyhow::Result<TraceDb> {
        Self::from_json(&json::load_file(path)?)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        json::save_file(path, &self.to_json())
    }
}

impl PerfModel for TraceDb {
    fn op_latency(&self, inv: OpInvocation) -> Nanos {
        match self.lookup(inv) {
            Some(ns) => ns.round() as Nanos,
            // simlint: allow(S01) — documented contract: a trace miss is unpriceable; the
            // message names the remediation (re-profile or calibrated model)
            None => panic!(
                "trace[{}/{}] has no samples for op {} — re-run the profiler \
                 or use the calibrated analytical model",
                self.hardware, self.model, inv.kind
            ),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use crate::perf::HardwareSpec;
    use crate::util::prop;

    fn db_linear() -> TraceDb {
        let mut db = TraceDb::new("test-hw", "tiny-dense");
        for t in [1u64, 2, 4, 8, 16, 32, 64] {
            db.add_tokens(OpKind::Ffn, t, 1000 * t); // exactly linear
        }
        db
    }

    #[test]
    fn interpolates_exactly_on_grid() {
        let db = db_linear();
        assert_eq!(db.op_latency(OpInvocation::tokens(OpKind::Ffn, 8)), 8000);
    }

    #[test]
    fn interpolates_between_points() {
        let db = db_linear();
        let l = db.op_latency(OpInvocation::tokens(OpKind::Ffn, 12));
        assert_eq!(l, 12_000);
    }

    #[test]
    fn extrapolates_above_grid() {
        let db = db_linear();
        let l = db.op_latency(OpInvocation::tokens(OpKind::Ffn, 128));
        assert_eq!(l, 128_000);
    }

    #[test]
    fn bilinear_decode_grid() {
        let mut db = TraceDb::new("hw", "m");
        for b in [1u64, 2, 4] {
            for c in [64u64, 128] {
                db.add_batch_ctx(OpKind::AttnDecode, b, c, b * c * 10);
            }
        }
        // exact on grid
        assert_eq!(db.op_latency(OpInvocation::decode(2, 128)), 2560);
        // between batches: b=3, c=64 → between 640*3=1920 (linear)
        assert_eq!(db.op_latency(OpInvocation::decode(3, 64)), 1920);
        // between ctx: b=1, c=96 → 960
        assert_eq!(db.op_latency(OpInvocation::decode(1, 96)), 960);
    }

    #[test]
    fn lookup_missing_returns_none() {
        let db = db_linear();
        assert!(db.lookup(OpInvocation::tokens(OpKind::LmHead, 4)).is_none());
    }

    #[test]
    #[should_panic(expected = "no samples")]
    fn perfmodel_panics_on_missing_op() {
        let db = db_linear();
        db.op_latency(OpInvocation::tokens(OpKind::LmHead, 4));
    }

    #[test]
    fn json_roundtrip() {
        let mut db = db_linear();
        db.add_batch_ctx(OpKind::AttnDecode, 1, 64, 5000);
        db.add_batch_ctx(OpKind::AttnDecode, 2, 64, 9000);
        let v = db.to_json();
        let back = TraceDb::from_json(&v).unwrap();
        assert_eq!(back.hardware, "test-hw");
        assert_eq!(
            back.op_latency(OpInvocation::tokens(OpKind::Ffn, 12)),
            db.op_latency(OpInvocation::tokens(OpKind::Ffn, 12))
        );
        assert_eq!(back.op_latency(OpInvocation::decode(1, 64)), 5000);
    }

    #[test]
    fn calibration_recovers_known_factor() {
        // Build a trace that is exactly 3x the roofline.
        let model = ModelSpec::tiny_dense();
        let hw = HardwareSpec::cpu_pjrt();
        let roof = Roofline::new(hw, model);
        let mut db = TraceDb::new("cpu-pjrt", "tiny-dense");
        for t in [4u64, 16, 64, 256] {
            let inv = OpInvocation::tokens(OpKind::Ffn, t);
            let ns = (roof.raw_latency(inv) * 3.0 * 1e9).round() as u64;
            db.add_tokens(OpKind::Ffn, t, ns);
        }
        let cal = db.calibration(&roof);
        let (_, f) = cal.iter().find(|(k, _)| *k == OpKind::Ffn).unwrap();
        assert!((f - 3.0).abs() < 0.05, "factor={f}");
    }

    #[test]
    fn prop_interpolation_within_bracket_bounds() {
        prop::check(
            "trace-interp-bounded",
            128,
            |rng| {
                let n = 2 + rng.below(6) as usize;
                let mut pts: Vec<(u64, u64)> = (0..n)
                    .map(|i| {
                        (
                            (i as u64 + 1) * (1 + rng.below(8)),
                            1000 + rng.below(1_000_000),
                        )
                    })
                    .collect();
                pts.sort();
                pts.dedup_by_key(|p| p.0);
                let q = 1 + rng.below(pts.last().unwrap().0);
                (pts, q)
            },
            |(pts, q)| {
                let y = TraceDb::interp_tokens(pts, *q);
                // inside the grid, interpolation is bounded by segment endpoints
                let idx = pts.partition_point(|&(x, _)| x < *q);
                if idx > 0 && idx < pts.len() {
                    let lo = pts[idx - 1].1.min(pts[idx].1) as f64;
                    let hi = pts[idx - 1].1.max(pts[idx].1) as f64;
                    if y < lo - 1e-6 || y > hi + 1e-6 {
                        return Err(format!("y={y} outside [{lo},{hi}] q={q}"));
                    }
                }
                if !y.is_finite() || y < 0.0 {
                    return Err(format!("y={y} invalid"));
                }
                Ok(())
            },
        );
    }
}
