//! Roofline performance model + the calibrated analytical extension.
//!
//! `latency = max(flops / peak_flops, bytes / mem_bw) + kernel_overhead`
//!
//! Pure roofline is the fallback when no trace covers an operator. The
//! [`Calibrated`] wrapper scales roofline by a measured efficiency factor
//! (profiled latency / roofline latency, averaged over the trace grid),
//! which is how the simulator extends a tiny-model trace DB to paper-scale
//! model configs on the same hardware (DESIGN.md §1).

use super::{HardwareSpec, PerfModel};
use crate::model::{ModelSpec, OpInvocation, OpKind, DTYPE_BYTES};
use crate::sim::Nanos;

/// FLOPs and bytes moved for one operator invocation of `model`.
///
/// Byte counts assume weights stream from device memory once per invocation
/// (no cross-batch weight reuse within an op) and activations are read +
/// written — the same accounting `aot.py` records in the manifest.
pub fn op_cost(model: &ModelSpec, inv: OpInvocation) -> (u64, u64) {
    let h = model.hidden;
    let d = model.head_dim();
    let nh = model.heads;
    let kvh = model.kv_heads * d;
    let f = model.ffn.max(1);
    let fe = model.expert_ffn.max(1);
    let e = model.experts.max(1);
    let v = model.vocab;
    let t = inv.tokens.max(1);
    let b = DTYPE_BYTES;
    match inv.kind {
        OpKind::QkvProj => (
            2 * t * h * (h + 2 * kvh),
            b * (t * h + h * (h + 2 * kvh) + t * (h + 2 * kvh)),
        ),
        OpKind::AttnPrefill => {
            let s = t;
            (2 * nh * s * s * d * 2, b * nh * s * d * 4)
        }
        OpKind::AttnDecode => {
            let batch = t;
            let c = inv.ctx.max(1);
            (
                2 * batch * nh * c * d * 2,
                b * batch * model.kv_heads * (2 * c * d) + b * batch * nh * 2 * d,
            )
        }
        OpKind::OutProj => (2 * t * h * h, b * (2 * t * h + h * h)),
        OpKind::Ffn => (2 * t * h * f * 3, b * (2 * t * h + 3 * h * f)),
        OpKind::MoeGate => (2 * t * h * e, b * (t * h + h * e + t * e)),
        OpKind::ExpertFfn => (2 * t * h * fe * 3, b * (2 * t * h + 3 * h * fe)),
        OpKind::LmHead => (2 * t * h * v, b * (t * h + h * v + t * v)),
        OpKind::RmsNorm => (4 * t * h, b * (2 * t * h + h)),
    }
}

/// Pure roofline model.
#[derive(Debug, Clone)]
pub struct Roofline {
    pub hw: HardwareSpec,
    pub model: ModelSpec,
    name: String,
}

impl Roofline {
    pub fn new(hw: HardwareSpec, model: ModelSpec) -> Self {
        let name = format!("roofline[{}/{}]", hw.name, model.name);
        Roofline { hw, model, name }
    }

    /// Latency without the fixed overhead (used by calibration).
    pub fn raw_latency(&self, inv: OpInvocation) -> f64 {
        let (flops, bytes) = op_cost(&self.model, inv);
        let compute = flops as f64 / self.hw.peak_flops;
        let memory = bytes as f64 / self.hw.mem_bw;
        compute.max(memory)
    }
}

impl PerfModel for Roofline {
    fn op_latency(&self, inv: OpInvocation) -> Nanos {
        let secs = self.raw_latency(inv);
        crate::sim::secs_to_nanos(secs) + self.hw.kernel_overhead
    }

    fn name(&self) -> &str {
        &self.name
    }
}

/// Roofline scaled by per-op-kind efficiency factors measured from a trace
/// DB (see `trace::TraceDb::calibration`).
#[derive(Debug, Clone)]
pub struct Calibrated {
    base: Roofline,
    /// Multiplier per op kind: measured / roofline. Indexed by `OpKind::all()`
    /// position; 1.0 where no measurement exists.
    factors: Vec<f64>,
    name: String,
}

impl Calibrated {
    pub fn new(base: Roofline, factors: Vec<(OpKind, f64)>) -> Self {
        let mut table = vec![1.0; OpKind::all().len()];
        for (k, f) in factors {
            // simlint: allow(S01) — OpKind::all() enumerates every variant by construction
        let idx = OpKind::all().iter().position(|&x| x == k).unwrap();
            table[idx] = f.max(1e-3);
        }
        let name = format!("calibrated[{}]", base.name);
        Calibrated {
            base,
            factors: table,
            name,
        }
    }

    pub fn factor(&self, kind: OpKind) -> f64 {
        // simlint: allow(S01) — OpKind::all() enumerates every variant by construction
        let idx = OpKind::all().iter().position(|&x| x == kind).unwrap();
        self.factors[idx]
    }
}

impl PerfModel for Calibrated {
    fn op_latency(&self, inv: OpInvocation) -> Nanos {
        let secs = self.base.raw_latency(inv) * self.factor(inv.kind);
        crate::sim::secs_to_nanos(secs) + self.base.hw.kernel_overhead
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelSpec {
        ModelSpec::tiny_dense()
    }

    #[test]
    fn latency_monotone_in_tokens() {
        let r = Roofline::new(HardwareSpec::rtx3090(), model());
        let l1 = r.op_latency(OpInvocation::tokens(OpKind::Ffn, 8));
        let l2 = r.op_latency(OpInvocation::tokens(OpKind::Ffn, 512));
        assert!(l2 > l1);
    }

    #[test]
    fn decode_latency_grows_with_ctx() {
        let r = Roofline::new(HardwareSpec::rtx3090(), model());
        let l1 = r.op_latency(OpInvocation::decode(4, 64));
        let l2 = r.op_latency(OpInvocation::decode(4, 4096));
        assert!(l2 > l1);
    }

    #[test]
    fn overhead_floors_latency() {
        let hw = HardwareSpec::rtx3090();
        let r = Roofline::new(hw.clone(), model());
        let l = r.op_latency(OpInvocation::tokens(OpKind::RmsNorm, 1));
        assert!(l >= hw.kernel_overhead);
    }

    #[test]
    fn memory_bound_decode() {
        // Decode attention at batch 1 must be memory-bound on a GPU.
        let r = Roofline::new(HardwareSpec::rtx3090(), ModelSpec::llama31_8b());
        let inv = OpInvocation::decode(1, 2048);
        let (flops, bytes) = op_cost(&r.model, inv);
        let compute = flops as f64 / r.hw.peak_flops;
        let memory = bytes as f64 / r.hw.mem_bw;
        assert!(memory > compute);
    }

    #[test]
    fn calibration_scales() {
        let base = Roofline::new(HardwareSpec::cpu_pjrt(), model());
        let plain = base.op_latency(OpInvocation::tokens(OpKind::Ffn, 64));
        let cal = Calibrated::new(base, vec![(OpKind::Ffn, 2.0)]);
        let scaled = cal.op_latency(OpInvocation::tokens(OpKind::Ffn, 64));
        let overhead = HardwareSpec::cpu_pjrt().kernel_overhead;
        let raw_plain = plain - overhead;
        let raw_scaled = scaled - overhead;
        assert!(
            (raw_scaled as f64 / raw_plain as f64 - 2.0).abs() < 0.01,
            "{raw_plain} vs {raw_scaled}"
        );
        // unmeasured kinds keep factor 1.0
        assert_eq!(cal.factor(OpKind::LmHead), 1.0);
    }

    #[test]
    fn moe_ops_priced() {
        let r = Roofline::new(HardwareSpec::rtx3090(), ModelSpec::tiny_moe());
        let gate = r.op_latency(OpInvocation::tokens(OpKind::MoeGate, 16));
        let expert = r.op_latency(OpInvocation::tokens(OpKind::ExpertFfn, 16));
        assert!(gate > 0 && expert > gate);
    }
}
