//! Workload engine: requests, SLO classes, tenants, arrival processes,
//! traffic sources, and trace I/O.
//!
//! The paper evaluates with 100 requests sampled from ShareGPT and Poisson
//! arrivals at 10 req/s, but positions the simulator as infrastructure that
//! "captures the breadth of approaches in modern LLM serving". This module
//! is therefore a composable engine rather than a flat generator:
//!
//! * [`Arrival`] — the open-loop timestamp process (Poisson, fixed-gap,
//!   burst, bursty MMPP on/off, diurnal rate curve). All processes share
//!   one clock implementation that guarantees **monotone non-decreasing**
//!   arrival times, saturating instead of wrapping at extreme rates.
//! * [`Traffic`] — what a workload *is*: an open-loop process, closed-loop
//!   multi-turn [sessions](Traffic::Sessions), a [replay](Traffic::Replay)
//!   of a JSON trace, or a [custom](Traffic::Custom) source registered in
//!   the [policy registry](crate::policy) under a name (exactly like
//!   routing/scheduling/eviction policies).
//! * [`TrafficSource`](source::TrafficSource) — the streaming contract:
//!   sources are pulled one request at a time by the coordinator, so
//!   million-request scenarios run in bounded memory. Eager generation
//!   ([`WorkloadSpec::generate`]) is defined as collecting the stream, so
//!   the two can never diverge.
//! * [`TenantSpec`]/[`SloClass`] — every request carries a tenant and an
//!   SLO class (interactive/batch with TTFT/TPOT targets) that flow into
//!   scheduler priority and per-tenant / per-class report breakdowns.
//!
//! ShareGPT itself is an external dataset; per the substitution rule we
//! ship a deterministic sampler whose prompt/output length marginals are
//! log-normal fits to published ShareGPT statistics (median prompt ≈ 130
//! tokens, heavy right tail; median output ≈ 200 tokens). Real traces load
//! from JSON with the same schema the generator writes.

pub mod source;

pub use source::{OpenLoopSource, ReplaySource, SessionSource, TrafficSource};

use crate::sim::{secs_to_nanos, Nanos};
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// Service-level-objective class of a request. Targets follow common
/// serving-SLO studies: interactive traffic is latency-bound, batch traffic
/// is throughput-bound with loose latency targets.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SloClass {
    /// Chat-style traffic: tight TTFT/TPOT targets.
    #[default]
    Interactive,
    /// Offline/analytics traffic: loose targets, throughput-oriented.
    Batch,
}

impl SloClass {
    pub fn all() -> &'static [SloClass] {
        &[SloClass::Interactive, SloClass::Batch]
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }

    /// Time-to-first-token target.
    pub fn ttft_target_ns(self) -> Nanos {
        match self {
            SloClass::Interactive => 500 * crate::sim::MILLI,
            SloClass::Batch => 30 * crate::sim::SECOND,
        }
    }

    /// Time-per-output-token target.
    pub fn tpot_target_ns(self) -> Nanos {
        match self {
            SloClass::Interactive => 100 * crate::sim::MILLI,
            SloClass::Batch => crate::sim::SECOND,
        }
    }
}

impl std::str::FromStr for SloClass {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<SloClass, Self::Err> {
        Ok(match s {
            "interactive" => SloClass::Interactive,
            "batch" => SloClass::Batch,
            _ => anyhow::bail!("unknown SLO class '{s}' (interactive|batch)"),
        })
    }
}

/// One tenant sharing the deployment: requests are attributed to tenants by
/// weighted draw, and every tenant pins an SLO class for its traffic.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Unnormalized share of the request stream (must be > 0).
    pub weight: f64,
    pub slo: SloClass,
}

impl TenantSpec {
    pub fn new(name: &str, weight: f64, slo: SloClass) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            weight,
            slo,
        }
    }

    /// A skewed `n`-tenant mix alternating interactive/batch classes
    /// (tenant i has weight 1/(i+1) — a few heavy tenants, a long tail).
    pub fn mix(n: usize) -> Vec<TenantSpec> {
        (0..n)
            .map(|i| {
                TenantSpec::new(
                    &format!("tenant{i}"),
                    1.0 / (i + 1) as f64,
                    if i % 2 == 0 {
                        SloClass::Interactive
                    } else {
                        SloClass::Batch
                    },
                )
            })
            .collect()
    }
}

/// One inference request.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time at the global router.
    pub arrival: Nanos,
    /// Prompt length in tokens.
    pub prompt_tokens: u64,
    /// Number of tokens to generate (oracle length, as in all LLM serving
    /// simulators — the simulator does not sample real text).
    pub output_tokens: u64,
    /// Session/user key for affinity routing and prefix sharing; requests
    /// with the same key share a system-prompt prefix of `shared_prefix`
    /// tokens.
    pub session: u64,
    /// Tokens of the prompt shared with other requests in the same session.
    pub shared_prefix: u64,
    /// Tenant index (into [`WorkloadSpec::tenants`]; 0 when single-tenant).
    pub tenant: u32,
    /// SLO class driving scheduler priority and attainment accounting.
    pub slo_class: SloClass,
}

impl Request {
    pub fn total_tokens(&self) -> u64 {
        self.prompt_tokens + self.output_tokens
    }

    /// Synthetic prompt token ids for prefix-cache modeling: the first
    /// `shared_prefix` tokens are a deterministic function of the session
    /// (so session-mates share them), the remainder unique to the request.
    pub fn token_ids(&self) -> Vec<u32> {
        let mut out = vec![];
        self.fill_token_ids(&mut out);
        out
    }

    /// [`token_ids`](Self::token_ids) into a caller-owned scratch buffer —
    /// hot paths (admission-time cache lookups, post-prefill inserts) reuse
    /// one buffer per instance instead of allocating a `Vec` per request.
    pub fn fill_token_ids(&self, buf: &mut Vec<u32>) {
        let mix = |a: u64, b: u64| -> u32 {
            let mut x = a
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(b.wrapping_mul(0xBF58476D1CE4E5B9));
            x ^= x >> 31;
            x = x.wrapping_mul(0x94D049BB133111EB);
            (x >> 33) as u32
        };
        buf.clear();
        buf.reserve(self.prompt_tokens as usize);
        for i in 0..self.prompt_tokens {
            buf.push(if i < self.shared_prefix {
                mix(self.session.wrapping_add(1) << 1, i)
            } else {
                mix((self.id << 1) | 1, i) | 0x8000_0000 // disjoint space
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Arrival processes
// ---------------------------------------------------------------------------

/// Open-loop arrival process for synthesizing request timestamps.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// Poisson process with `rate` requests/second (the paper's setup).
    Poisson { rate: f64 },
    /// Fixed inter-arrival gap.
    Uniform { rate: f64 },
    /// Everything arrives at t=0 (offline/batch evaluation).
    Burst,
    /// Markov-modulated Poisson on/off process: exponential dwell times in
    /// an on state (`rate_on`) and an off state (`rate_off`, may be 0) —
    /// the classic bursty-traffic model.
    Mmpp {
        rate_on: f64,
        rate_off: f64,
        mean_on_s: f64,
        mean_off_s: f64,
    },
    /// Inhomogeneous Poisson with a sinusoidal (diurnal) rate curve:
    /// `rate(t) = base_rate * (1 + amplitude * sin(2πt / period_s))`,
    /// sampled by thinning against the peak rate.
    Diurnal {
        base_rate: f64,
        amplitude: f64,
        period_s: f64,
    },
}

impl Arrival {
    /// Registry-style name of this process kind.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Arrival::Poisson { .. } => "poisson",
            Arrival::Uniform { .. } => "uniform",
            Arrival::Burst => "burst",
            Arrival::Mmpp { .. } => "mmpp",
            Arrival::Diurnal { .. } => "diurnal",
        }
    }

    /// Reject parameters that would produce a degenerate process.
    pub fn validate(&self) -> anyhow::Result<()> {
        let pos = |v: f64, what: &str| -> anyhow::Result<()> {
            if !v.is_finite() || v <= 0.0 {
                anyhow::bail!("{} arrival: {what} must be finite and > 0, got {v}",
                    self.kind_name());
            }
            Ok(())
        };
        match self {
            Arrival::Poisson { rate } | Arrival::Uniform { rate } => {
                pos(*rate, "rate")
            }
            Arrival::Burst => Ok(()),
            Arrival::Mmpp {
                rate_on,
                rate_off,
                mean_on_s,
                mean_off_s,
            } => {
                pos(*rate_on, "rate_on")?;
                if !rate_off.is_finite() || *rate_off < 0.0 {
                    anyhow::bail!(
                        "mmpp arrival: rate_off must be finite and >= 0, got {rate_off}"
                    );
                }
                pos(*mean_on_s, "mean_on_s")?;
                pos(*mean_off_s, "mean_off_s")
            }
            Arrival::Diurnal {
                base_rate,
                amplitude,
                period_s,
            } => {
                pos(*base_rate, "base_rate")?;
                if !amplitude.is_finite() || !(0.0..=1.0).contains(amplitude) {
                    anyhow::bail!(
                        "diurnal arrival: amplitude must be in [0,1], got {amplitude}"
                    );
                }
                pos(*period_s, "period_s")
            }
        }
    }

    /// Generate `n` arrival timestamps. Guaranteed monotone non-decreasing
    /// (saturating at `u64::MAX` ns rather than wrapping or going
    /// backwards), even at extreme rates.
    pub fn timestamps(&self, n: usize, rng: &mut Rng) -> Vec<Nanos> {
        let mut clock = ArrivalClock::new(self.clone());
        (0..n).map(|_| clock.next(rng)).collect()
    }
}

/// Streaming clock over an [`Arrival`] process: the single implementation
/// behind both [`Arrival::timestamps`] and the pull-based traffic sources,
/// so eager and incremental generation can never diverge.
///
/// Invariant: `next` never returns a value smaller than the previous one.
/// Non-finite or negative gaps (degenerate parameters at extreme rates)
/// saturate to `u64::MAX` ns instead of corrupting the order.
#[derive(Debug, Clone)]
pub struct ArrivalClock {
    arrival: Arrival,
    /// Elapsed seconds (the sampling domain).
    t: f64,
    /// Last emitted timestamp — the monotonicity clamp.
    prev: Nanos,
    /// MMPP state: currently in the on state, remaining dwell seconds.
    mmpp_on: bool,
    dwell_left: f64,
}

impl ArrivalClock {
    pub fn new(arrival: Arrival) -> ArrivalClock {
        ArrivalClock {
            arrival,
            t: 0.0,
            prev: 0,
            mmpp_on: true,
            dwell_left: f64::NAN, // initialized lazily from the rng
        }
    }

    /// Advance the clock by one arrival and return its timestamp.
    pub fn next(&mut self, rng: &mut Rng) -> Nanos {
        let gap = self.next_gap(rng);
        // Degenerate gaps (NaN from pathological parameters) saturate the
        // clock; negative gaps are impossible from the samplers but are
        // clamped anyway so monotonicity is unconditional.
        if gap.is_nan() {
            self.t = f64::INFINITY;
        } else if gap > 0.0 {
            self.t += gap;
        }
        let at = secs_to_nanos(self.t).max(self.prev);
        self.prev = at;
        at
    }

    fn next_gap(&mut self, rng: &mut Rng) -> f64 {
        match &self.arrival {
            Arrival::Poisson { rate } => rng.exp(rate.max(f64::MIN_POSITIVE)),
            Arrival::Uniform { rate } => 1.0 / rate,
            Arrival::Burst => 0.0,
            Arrival::Mmpp {
                rate_on,
                rate_off,
                mean_on_s,
                mean_off_s,
            } => {
                if self.dwell_left.is_nan() {
                    self.dwell_left = rng.exp(1.0 / mean_on_s.max(f64::MIN_POSITIVE));
                }
                let mut gap = 0.0f64;
                // A sane process sees O(1) phase switches per arrival; if
                // thousands of dwell periods pass without one (rates
                // vanishingly small vs. dwell times), the next arrival is
                // effectively "never" — saturate instead of spinning.
                for _ in 0..10_000 {
                    let rate = if self.mmpp_on { *rate_on } else { *rate_off };
                    let to_arrival = if rate > 0.0 {
                        rng.exp(rate)
                    } else {
                        f64::INFINITY
                    };
                    if to_arrival <= self.dwell_left {
                        self.dwell_left -= to_arrival;
                        return gap + to_arrival;
                    }
                    // phase switch before the next arrival
                    gap += self.dwell_left;
                    self.mmpp_on = !self.mmpp_on;
                    let mean = if self.mmpp_on { *mean_on_s } else { *mean_off_s };
                    self.dwell_left = rng.exp(1.0 / mean.max(f64::MIN_POSITIVE));
                    if !gap.is_finite() {
                        return gap; // saturated; caller clamps
                    }
                }
                f64::INFINITY
            }
            Arrival::Diurnal {
                base_rate,
                amplitude,
                period_s,
            } => {
                // Thinning against the peak rate.
                let peak = base_rate * (1.0 + amplitude);
                if !peak.is_finite() {
                    return 0.0; // effectively infinite rate: back-to-back
                }
                let mut gap = 0.0f64;
                loop {
                    gap += rng.exp(peak.max(f64::MIN_POSITIVE));
                    let phase = (self.t + gap) / period_s * std::f64::consts::TAU;
                    let r = base_rate * (1.0 + amplitude * phase.sin());
                    if !gap.is_finite() || rng.chance((r / peak).clamp(0.0, 1.0)) {
                        return gap;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Traffic selection
// ---------------------------------------------------------------------------

/// What kind of traffic a workload produces. Open-loop processes wrap an
/// [`Arrival`]; sessions model closed-loop multi-turn conversations; replay
/// streams a JSON trace; custom names resolve through the
/// [policy registry](crate::policy) like every other pluggable decision.
#[derive(Debug, Clone, PartialEq)]
pub enum Traffic {
    /// Independent requests from an open-loop arrival process.
    Open(Arrival),
    /// Closed-loop multi-turn conversations: sessions start from `start`,
    /// each runs `turns` turns spaced by exponential think times (mean
    /// `think_s` seconds), and each turn's prompt re-sends the growing
    /// conversation context as a shared prefix (so the radix prefix cache
    /// sees realistic multi-turn reuse).
    Sessions {
        start: Arrival,
        turns: u32,
        think_s: f64,
    },
    /// Replay a JSON request trace (see [`load_trace`]).
    Replay { path: String },
    /// A source registered under `name` via
    /// [`crate::policy::register_traffic_source`].
    Custom { name: String },
}

impl Traffic {
    pub fn poisson(rate: f64) -> Traffic {
        Traffic::Open(Arrival::Poisson { rate })
    }

    pub fn uniform(rate: f64) -> Traffic {
        Traffic::Open(Arrival::Uniform { rate })
    }

    pub fn burst() -> Traffic {
        Traffic::Open(Arrival::Burst)
    }

    /// Bursty on/off traffic alternating `rate_on` and `rate_off` phases.
    pub fn mmpp(rate_on: f64, rate_off: f64, mean_on_s: f64, mean_off_s: f64) -> Traffic {
        Traffic::Open(Arrival::Mmpp {
            rate_on,
            rate_off,
            mean_on_s,
            mean_off_s,
        })
    }

    /// Sinusoidal diurnal rate curve around `base_rate`.
    pub fn diurnal(base_rate: f64, amplitude: f64, period_s: f64) -> Traffic {
        Traffic::Open(Arrival::Diurnal {
            base_rate,
            amplitude,
            period_s,
        })
    }

    /// Multi-turn sessions starting at Poisson `rate` sessions/second.
    pub fn sessions(rate: f64, turns: u32, think_s: f64) -> Traffic {
        Traffic::Sessions {
            start: Arrival::Poisson { rate },
            turns,
            think_s,
        }
    }

    /// The registry name of this traffic kind (custom traffic reports its
    /// registered name).
    pub fn kind_name(&self) -> &str {
        match self {
            Traffic::Open(a) => a.kind_name(),
            Traffic::Sessions { .. } => "sessions",
            Traffic::Replay { .. } => "replay",
            Traffic::Custom { name } => name,
        }
    }

    /// Built-in source names sweepable without extra parameters (replay
    /// needs a trace path, so it is configured explicitly instead).
    pub fn builtin_names() -> &'static [&'static str] {
        &["burst", "diurnal", "mmpp", "poisson", "sessions", "uniform"]
    }

    /// Default-parameter traffic for a built-in name at `rate` req/s —
    /// the mapping behind the sweep engine's `--workloads` axis.
    pub fn for_name(name: &str, rate: f64) -> Option<Traffic> {
        Some(match name {
            "poisson" => Traffic::poisson(rate),
            "uniform" => Traffic::uniform(rate),
            "burst" => Traffic::burst(),
            // on at 4x the nominal rate for 1/4 of the time: same average
            // load as `poisson`, very different queueing behavior.
            "mmpp" => Traffic::mmpp(rate * 4.0, 0.0, 2.0, 6.0),
            "diurnal" => Traffic::diurnal(rate, 0.8, 60.0),
            "sessions" => Traffic::sessions(rate / 4.0, 4, 2.0),
            _ => return None,
        })
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        match self {
            Traffic::Open(a) => a.validate(),
            Traffic::Sessions {
                start,
                turns,
                think_s,
            } => {
                start.validate()?;
                if *turns == 0 {
                    anyhow::bail!("sessions traffic: turns must be >= 1");
                }
                if !think_s.is_finite() || *think_s < 0.0 {
                    anyhow::bail!(
                        "sessions traffic: think_s must be finite and >= 0, got {think_s}"
                    );
                }
                Ok(())
            }
            Traffic::Replay { path } => {
                if path.is_empty() {
                    anyhow::bail!("replay traffic: path must not be empty");
                }
                Ok(())
            }
            Traffic::Custom { name } => {
                if name.is_empty() {
                    anyhow::bail!("custom traffic: name must not be empty");
                }
                Ok(())
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Length distribution
// ---------------------------------------------------------------------------

/// Length distribution configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct LengthDist {
    /// log-normal mu/sigma for prompt tokens.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    /// log-normal mu/sigma for output tokens.
    pub output_mu: f64,
    pub output_sigma: f64,
    pub min_tokens: u64,
    pub max_tokens: u64,
}

impl LengthDist {
    /// Fit to published ShareGPT marginals (median prompt ~130 tok, p90 ~900;
    /// median output ~200 tok, p90 ~700), clamped to the simulator's tiny
    /// model context by default.
    pub fn sharegpt() -> LengthDist {
        LengthDist {
            prompt_mu: 4.87, // e^4.87 ≈ 130
            prompt_sigma: 1.4,
            output_mu: 5.3, // e^5.3 ≈ 200
            output_sigma: 1.0,
            min_tokens: 4,
            max_tokens: 1536,
        }
    }

    /// Short-form variant for fast tests.
    pub fn short() -> LengthDist {
        LengthDist {
            prompt_mu: 3.4,
            prompt_sigma: 0.7,
            output_mu: 3.0,
            output_sigma: 0.6,
            min_tokens: 2,
            max_tokens: 256,
        }
    }

    pub(crate) fn sample_prompt(&self, rng: &mut Rng) -> u64 {
        self.sample(self.prompt_mu, self.prompt_sigma, rng)
    }

    pub(crate) fn sample_output(&self, rng: &mut Rng) -> u64 {
        self.sample(self.output_mu, self.output_sigma, rng)
    }

    fn sample(&self, mu: f64, sigma: f64, rng: &mut Rng) -> u64 {
        let x = rng.lognormal(mu, sigma).round() as u64;
        x.clamp(self.min_tokens, self.max_tokens)
    }
}

// ---------------------------------------------------------------------------
// Workload specification
// ---------------------------------------------------------------------------

/// Workload generator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub num_requests: usize,
    pub traffic: Traffic,
    pub lengths: LengthDist,
    /// Number of distinct sessions for open-loop traffic; requests are
    /// assigned Zipf-1.0 over sessions. 0 disables sessions (every request
    /// unique). Session traffic manages its own conversation ids instead.
    pub sessions: usize,
    /// Shared system-prompt prefix length per session (tokens); enables
    /// prefix-caching studies.
    pub shared_prefix: u64,
    /// Tenants sharing the deployment; empty = a single anonymous
    /// interactive tenant.
    pub tenants: Vec<TenantSpec>,
    pub seed: u64,
}

impl WorkloadSpec {
    pub fn sharegpt_100(rate: f64) -> WorkloadSpec {
        WorkloadSpec {
            num_requests: 100,
            traffic: Traffic::poisson(rate),
            lengths: LengthDist::sharegpt(),
            sessions: 0,
            shared_prefix: 0,
            tenants: vec![],
            seed: 0x5EED,
        }
    }

    /// Display names for tenant indices (index 0.. maps to the spec's
    /// tenants; out-of-range indices name themselves).
    pub fn tenant_names(&self) -> Vec<String> {
        if self.tenants.is_empty() {
            vec!["default".to_string()]
        } else {
            self.tenants.iter().map(|t| t.name.clone()).collect()
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        self.traffic.validate()?;
        for t in &self.tenants {
            if !t.weight.is_finite() || t.weight <= 0.0 {
                anyhow::bail!(
                    "tenant '{}': weight must be finite and > 0, got {}",
                    t.name,
                    t.weight
                );
            }
        }
        Ok(())
    }

    /// Build the streaming source for this spec, resolving custom traffic
    /// names against a snapshot of the global policy registry.
    pub fn source(&self) -> anyhow::Result<Box<dyn TrafficSource>> {
        crate::policy::snapshot().make_traffic(self)
    }

    /// Generate the full request list eagerly. Defined as collecting the
    /// streaming source, so eager and incremental generation are
    /// byte-identical by construction.
    pub fn generate(&self) -> anyhow::Result<Vec<Request>> {
        let mut src = self.source()?;
        let mut out = Vec::new();
        while let Some(r) = src.next_request() {
            out.push(r);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Trace I/O
// ---------------------------------------------------------------------------

/// Serialize requests to the JSON trace schema.
pub fn to_json(reqs: &[Request]) -> Value {
    Value::arr(
        reqs.iter()
            .map(|r| {
                Value::obj(vec![
                    ("id", Value::int(r.id as i64)),
                    ("arrival_ns", Value::int(r.arrival as i64)),
                    ("prompt_tokens", Value::int(r.prompt_tokens as i64)),
                    ("output_tokens", Value::int(r.output_tokens as i64)),
                    ("session", Value::int(r.session as i64)),
                    ("shared_prefix", Value::int(r.shared_prefix as i64)),
                    ("tenant", Value::int(r.tenant as i64)),
                    ("slo", Value::str(r.slo_class.as_str())),
                ])
            })
            .collect(),
    )
}

/// Parse requests from the JSON trace schema. `tenant`/`slo` are optional
/// (default: tenant 0, interactive) so pre-multi-tenant traces still load;
/// present-but-malformed values are rejected.
pub fn from_json(v: &Value) -> anyhow::Result<Vec<Request>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("trace must be a JSON array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        let field = |k: &str| -> anyhow::Result<u64> {
            item.get(k)
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("request {i}: missing/invalid '{k}'"))
        };
        let tenant = match item.get("tenant") {
            Value::Null => 0,
            t => t
                .as_u64()
                .and_then(|v| u32::try_from(v).ok())
                .ok_or_else(|| {
                    anyhow::anyhow!("request {i}: invalid 'tenant' (want u32)")
                })?,
        };
        let slo_class = match item.get("slo") {
            Value::Null => SloClass::Interactive,
            s => s
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("request {i}: invalid 'slo' (want string)"))?
                .parse::<SloClass>()
                .map_err(|e| anyhow::anyhow!("request {i}: {e}"))?,
        };
        out.push(Request {
            id: field("id")?,
            arrival: field("arrival_ns")?,
            prompt_tokens: field("prompt_tokens")?,
            output_tokens: field("output_tokens")?,
            session: item.get("session").as_u64().unwrap_or(i as u64),
            shared_prefix: item.get("shared_prefix").as_u64().unwrap_or(0),
            tenant,
            slo_class,
        });
    }
    out.sort_by_key(|r| r.arrival);
    Ok(out)
}

/// Load a trace file.
pub fn load_trace(path: &std::path::Path) -> anyhow::Result<Vec<Request>> {
    from_json(&json::load_file(path)?)
}

/// Save a trace file.
pub fn save_trace(path: &std::path::Path, reqs: &[Request]) -> anyhow::Result<()> {
    json::save_file(path, &to_json(reqs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approx() {
        let mut rng = Rng::new(1);
        let ts = Arrival::Poisson { rate: 10.0 }.timestamps(5000, &mut rng);
        let span = crate::sim::nanos_to_secs(*ts.last().unwrap());
        let rate = 5000.0 / span;
        assert!((rate - 10.0).abs() < 0.7, "rate={rate}");
    }

    #[test]
    fn mmpp_average_rate_between_phases() {
        let mut rng = Rng::new(4);
        let a = Arrival::Mmpp {
            rate_on: 40.0,
            rate_off: 0.0,
            mean_on_s: 2.0,
            mean_off_s: 6.0,
        };
        let ts = a.timestamps(5000, &mut rng);
        let span = crate::sim::nanos_to_secs(*ts.last().unwrap());
        let rate = 5000.0 / span;
        // duty cycle 2/(2+6) = 0.25 → average ≈ 10 req/s
        assert!((5.0..20.0).contains(&rate), "rate={rate}");
    }

    #[test]
    fn diurnal_rate_oscillates_around_base() {
        let mut rng = Rng::new(5);
        let a = Arrival::Diurnal {
            base_rate: 20.0,
            amplitude: 0.8,
            period_s: 10.0,
        };
        let ts = a.timestamps(4000, &mut rng);
        let span = crate::sim::nanos_to_secs(*ts.last().unwrap());
        let rate = 4000.0 / span;
        assert!((12.0..30.0).contains(&rate), "rate={rate}");
    }

    fn all_arrivals(rate: f64) -> Vec<Arrival> {
        vec![
            Arrival::Poisson { rate },
            Arrival::Uniform { rate },
            Arrival::Burst,
            Arrival::Mmpp {
                rate_on: rate,
                rate_off: 0.0,
                mean_on_s: 1.0,
                mean_off_s: 1.0,
            },
            Arrival::Diurnal {
                base_rate: rate,
                amplitude: 0.9,
                period_s: 30.0,
            },
        ]
    }

    #[test]
    fn arrivals_monotone() {
        let mut rng = Rng::new(2);
        for arrival in all_arrivals(100.0) {
            let ts = arrival.timestamps(200, &mut rng);
            assert!(
                ts.windows(2).all(|w| w[0] <= w[1]),
                "{} not monotone",
                arrival.kind_name()
            );
        }
    }

    #[test]
    fn arrivals_monotone_at_extreme_rates() {
        // Boundary satellite: rate → 0 must saturate (not wrap or go
        // backwards), and enormous rates must stay non-decreasing even
        // when every gap rounds to the same nanosecond.
        let mut rng = Rng::new(3);
        for rate in [1e-300, 1e-12, 1e12, 1e300, f64::MAX] {
            for arrival in all_arrivals(rate) {
                let ts = arrival.timestamps(64, &mut rng);
                assert!(
                    ts.windows(2).all(|w| w[0] <= w[1]),
                    "{} unsorted at rate {rate}",
                    arrival.kind_name()
                );
            }
        }
        // rate so small every timestamp saturates
        let ts = Arrival::Poisson { rate: 1e-300 }.timestamps(4, &mut rng);
        assert!(ts.iter().all(|&t| t == u64::MAX), "{ts:?}");
    }

    #[test]
    fn degenerate_rates_rejected_by_validate() {
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(Arrival::Poisson { rate: bad }.validate().is_err(), "{bad}");
            assert!(Arrival::Uniform { rate: bad }.validate().is_err(), "{bad}");
        }
        assert!(Arrival::Burst.validate().is_ok());
        assert!(Arrival::Diurnal {
            base_rate: 10.0,
            amplitude: 1.5,
            period_s: 60.0
        }
        .validate()
        .is_err());
        assert!(Traffic::sessions(1.0, 0, 1.0).validate().is_err());
        assert!(Traffic::Replay { path: String::new() }.validate().is_err());
    }

    #[test]
    fn burst_all_zero() {
        let mut rng = Rng::new(3);
        let ts = Arrival::Burst.timestamps(10, &mut rng);
        assert!(ts.iter().all(|&t| t == 0));
    }

    #[test]
    fn generate_deterministic() {
        let spec = WorkloadSpec::sharegpt_100(10.0);
        assert_eq!(spec.generate().unwrap(), spec.generate().unwrap());
    }

    #[test]
    fn sharegpt_lengths_plausible() {
        let mut spec = WorkloadSpec::sharegpt_100(10.0);
        spec.num_requests = 2000;
        let reqs = spec.generate().unwrap();
        let mut prompts: Vec<f64> =
            reqs.iter().map(|r| r.prompt_tokens as f64).collect();
        prompts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = prompts[prompts.len() / 2];
        assert!((60.0..260.0).contains(&median), "median={median}");
        // heavy tail: p95 well above median
        let p95 = prompts[(prompts.len() as f64 * 0.95) as usize];
        assert!(p95 > 2.0 * median, "p95={p95} median={median}");
        // bounds respected
        assert!(reqs.iter().all(|r| r.prompt_tokens <= 1536));
        assert!(reqs.iter().all(|r| r.output_tokens >= 4));
    }

    #[test]
    fn sessions_and_prefix() {
        let spec = WorkloadSpec {
            num_requests: 200,
            traffic: Traffic::burst(),
            lengths: LengthDist::short(),
            sessions: 5,
            shared_prefix: 32,
            tenants: vec![],
            seed: 9,
        };
        let reqs = spec.generate().unwrap();
        let distinct: std::collections::HashSet<u64> =
            reqs.iter().map(|r| r.session).collect();
        assert!(distinct.len() <= 5);
        assert!(distinct.len() >= 2); // Zipf over 5 sessions hits several
        for r in &reqs {
            assert!(r.shared_prefix <= r.prompt_tokens);
            assert!(r.shared_prefix <= 32);
        }
    }

    #[test]
    fn tenant_mix_assigns_classes_and_weights() {
        let mut spec = WorkloadSpec::sharegpt_100(10.0);
        spec.num_requests = 400;
        spec.lengths = LengthDist::short();
        spec.tenants = TenantSpec::mix(3);
        let reqs = spec.generate().unwrap();
        let mut counts = [0usize; 3];
        for r in &reqs {
            counts[r.tenant as usize] += 1;
            let expect = spec.tenants[r.tenant as usize].slo;
            assert_eq!(r.slo_class, expect, "class must follow the tenant");
        }
        // weights 1, 1/2, 1/3 → tenant0 busiest, tenant2 quietest
        assert!(counts[0] > counts[1] && counts[1] > counts[2], "{counts:?}");
    }

    #[test]
    fn trace_roundtrip() {
        let mut spec = WorkloadSpec::sharegpt_100(10.0);
        spec.tenants = TenantSpec::mix(2);
        let reqs = spec.generate().unwrap();
        assert!(reqs.iter().any(|r| r.slo_class == SloClass::Batch));
        let v = to_json(&reqs);
        let parsed = from_json(&v).unwrap();
        assert_eq!(reqs, parsed);
    }

    #[test]
    fn trace_file_roundtrip() {
        let dir = std::env::temp_dir().join("llmss_test_trace");
        let path = dir.join("t.json");
        let reqs = WorkloadSpec::sharegpt_100(5.0).generate().unwrap();
        save_trace(&path, &reqs).unwrap();
        let loaded = load_trace(&path).unwrap();
        assert_eq!(reqs, loaded);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn token_ids_share_session_prefix() {
        let mk = |id, session, shared| Request {
            id,
            prompt_tokens: 64,
            output_tokens: 8,
            session,
            shared_prefix: shared,
            ..Request::default()
        };
        let a = mk(1, 7, 32);
        let b = mk(2, 7, 32);
        let c = mk(3, 8, 32);
        let (ta, tb, tc) = (a.token_ids(), b.token_ids(), c.token_ids());
        assert_eq!(ta[..32], tb[..32], "same session shares prefix");
        assert_ne!(ta[..32], tc[..32], "different session differs");
        assert_ne!(ta[32..], tb[32..], "suffixes unique per request");
        assert_eq!(ta.len(), 64);
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(from_json(&Value::int(3)).is_err());
        let bad = json::parse(r#"[{"id": 1}]"#).unwrap();
        assert!(from_json(&bad).is_err());
        // malformed tenant/slo are rejected, not defaulted
        let bad = json::parse(
            r#"[{"id":1,"arrival_ns":0,"prompt_tokens":4,"output_tokens":2,"slo":"gold"}]"#,
        )
        .unwrap();
        assert!(from_json(&bad).unwrap_err().to_string().contains("gold"));
        let bad = json::parse(
            r#"[{"id":1,"arrival_ns":0,"prompt_tokens":4,"output_tokens":2,"tenant":"a"}]"#,
        )
        .unwrap();
        assert!(from_json(&bad).is_err());
    }

    #[test]
    fn slo_class_targets_and_parse() {
        assert!(SloClass::Interactive.ttft_target_ns() < SloClass::Batch.ttft_target_ns());
        assert!(SloClass::Interactive.tpot_target_ns() < SloClass::Batch.tpot_target_ns());
        for c in SloClass::all() {
            assert_eq!(c.as_str().parse::<SloClass>().unwrap(), *c);
        }
        assert!("gold".parse::<SloClass>().is_err());
    }

    #[test]
    fn traffic_names_roundtrip_through_for_name() {
        for name in Traffic::builtin_names() {
            let t = Traffic::for_name(name, 12.0)
                .unwrap_or_else(|| panic!("builtin '{name}' has no default"));
            assert_eq!(t.kind_name(), *name);
            t.validate().unwrap();
        }
        assert!(Traffic::for_name("replay", 1.0).is_none());
        assert_eq!(
            Traffic::Custom { name: "surge".into() }.kind_name(),
            "surge"
        );
    }
}
