//! Workload generation: requests, arrival processes, and trace I/O.
//!
//! The paper evaluates with 100 requests sampled from ShareGPT and Poisson
//! arrivals at 10 req/s. ShareGPT itself is an external dataset; per the
//! substitution rule we ship a deterministic sampler whose prompt/output
//! length marginals are log-normal fits to published ShareGPT statistics
//! (median prompt ≈ 130 tokens, heavy right tail; median output ≈ 200
//! tokens). Real traces can be loaded from JSON with the same schema the
//! generator writes, so users can substitute the genuine dataset.

use crate::sim::{secs_to_nanos, Nanos};
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time at the global router.
    pub arrival: Nanos,
    /// Prompt length in tokens.
    pub prompt_tokens: u64,
    /// Number of tokens to generate (oracle length, as in all LLM serving
    /// simulators — the simulator does not sample real text).
    pub output_tokens: u64,
    /// Session/user key for affinity routing and prefix sharing; requests
    /// with the same key share a system-prompt prefix of `shared_prefix`
    /// tokens.
    pub session: u64,
    /// Tokens of the prompt shared with other requests in the same session.
    pub shared_prefix: u64,
}

impl Request {
    pub fn total_tokens(&self) -> u64 {
        self.prompt_tokens + self.output_tokens
    }

    /// Synthetic prompt token ids for prefix-cache modeling: the first
    /// `shared_prefix` tokens are a deterministic function of the session
    /// (so session-mates share them), the remainder unique to the request.
    pub fn token_ids(&self) -> Vec<u32> {
        let mix = |a: u64, b: u64| -> u32 {
            let mut x = a
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(b.wrapping_mul(0xBF58476D1CE4E5B9));
            x ^= x >> 31;
            x = x.wrapping_mul(0x94D049BB133111EB);
            (x >> 33) as u32
        };
        (0..self.prompt_tokens)
            .map(|i| {
                if i < self.shared_prefix {
                    mix(self.session.wrapping_add(1) << 1, i)
                } else {
                    mix((self.id << 1) | 1, i) | 0x8000_0000 // disjoint space
                }
            })
            .collect()
    }
}

/// Arrival process for synthesizing request timestamps.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// Poisson process with `rate` requests/second (the paper's setup).
    Poisson { rate: f64 },
    /// Fixed inter-arrival gap.
    Uniform { rate: f64 },
    /// Everything arrives at t=0 (offline/batch evaluation).
    Burst,
}

impl Arrival {
    /// Generate `n` monotone arrival timestamps.
    pub fn timestamps(&self, n: usize, rng: &mut Rng) -> Vec<Nanos> {
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for _ in 0..n {
            match self {
                Arrival::Poisson { rate } => t += rng.exp(*rate),
                Arrival::Uniform { rate } => t += 1.0 / rate,
                Arrival::Burst => {}
            }
            out.push(secs_to_nanos(t));
        }
        out
    }
}

/// Length distribution configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct LengthDist {
    /// log-normal mu/sigma for prompt tokens.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    /// log-normal mu/sigma for output tokens.
    pub output_mu: f64,
    pub output_sigma: f64,
    pub min_tokens: u64,
    pub max_tokens: u64,
}

impl LengthDist {
    /// Fit to published ShareGPT marginals (median prompt ~130 tok, p90 ~900;
    /// median output ~200 tok, p90 ~700), clamped to the simulator's tiny
    /// model context by default.
    pub fn sharegpt() -> LengthDist {
        LengthDist {
            prompt_mu: 4.87, // e^4.87 ≈ 130
            prompt_sigma: 1.4,
            output_mu: 5.3, // e^5.3 ≈ 200
            output_sigma: 1.0,
            min_tokens: 4,
            max_tokens: 1536,
        }
    }

    /// Short-form variant for fast tests.
    pub fn short() -> LengthDist {
        LengthDist {
            prompt_mu: 3.4,
            prompt_sigma: 0.7,
            output_mu: 3.0,
            output_sigma: 0.6,
            min_tokens: 2,
            max_tokens: 256,
        }
    }

    fn sample(&self, mu: f64, sigma: f64, rng: &mut Rng) -> u64 {
        let x = rng.lognormal(mu, sigma).round() as u64;
        x.clamp(self.min_tokens, self.max_tokens)
    }
}

/// Workload generator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub num_requests: usize,
    pub arrival: Arrival,
    pub lengths: LengthDist,
    /// Number of distinct sessions; requests are assigned Zipf-1.0 over
    /// sessions. 0 disables sessions (every request unique).
    pub sessions: usize,
    /// Shared system-prompt prefix length per session (tokens); enables
    /// prefix-caching studies.
    pub shared_prefix: u64,
    pub seed: u64,
}

impl WorkloadSpec {
    pub fn sharegpt_100(rate: f64) -> WorkloadSpec {
        WorkloadSpec {
            num_requests: 100,
            arrival: Arrival::Poisson { rate },
            lengths: LengthDist::sharegpt(),
            sessions: 0,
            shared_prefix: 0,
            seed: 0x5EED,
        }
    }

    /// Generate the request list (sorted by arrival).
    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.seed);
        let times = self.arrival.timestamps(self.num_requests, &mut rng);
        let zipf = if self.sessions > 0 {
            Some(crate::util::rng::ZipfTable::new(self.sessions, 1.0))
        } else {
            None
        };
        times
            .into_iter()
            .enumerate()
            .map(|(i, arrival)| {
                let prompt = self.lengths.sample(
                    self.lengths.prompt_mu,
                    self.lengths.prompt_sigma,
                    &mut rng,
                );
                let output = self.lengths.sample(
                    self.lengths.output_mu,
                    self.lengths.output_sigma,
                    &mut rng,
                );
                let session = match &zipf {
                    Some(z) => z.sample(&mut rng) as u64,
                    None => i as u64,
                };
                let shared = if self.sessions > 0 {
                    self.shared_prefix.min(prompt)
                } else {
                    0
                };
                Request {
                    id: i as u64,
                    arrival,
                    prompt_tokens: prompt.max(shared + 1),
                    output_tokens: output,
                    session,
                    shared_prefix: shared,
                }
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Trace I/O
// ---------------------------------------------------------------------------

/// Serialize requests to the JSON trace schema.
pub fn to_json(reqs: &[Request]) -> Value {
    Value::arr(
        reqs.iter()
            .map(|r| {
                Value::obj(vec![
                    ("id", Value::int(r.id as i64)),
                    ("arrival_ns", Value::int(r.arrival as i64)),
                    ("prompt_tokens", Value::int(r.prompt_tokens as i64)),
                    ("output_tokens", Value::int(r.output_tokens as i64)),
                    ("session", Value::int(r.session as i64)),
                    ("shared_prefix", Value::int(r.shared_prefix as i64)),
                ])
            })
            .collect(),
    )
}

/// Parse requests from the JSON trace schema.
pub fn from_json(v: &Value) -> anyhow::Result<Vec<Request>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("trace must be a JSON array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        let field = |k: &str| -> anyhow::Result<u64> {
            item.get(k)
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("request {i}: missing/invalid '{k}'"))
        };
        out.push(Request {
            id: field("id")?,
            arrival: field("arrival_ns")?,
            prompt_tokens: field("prompt_tokens")?,
            output_tokens: field("output_tokens")?,
            session: item.get("session").as_u64().unwrap_or(i as u64),
            shared_prefix: item.get("shared_prefix").as_u64().unwrap_or(0),
        });
    }
    out.sort_by_key(|r| r.arrival);
    Ok(out)
}

/// Load a trace file.
pub fn load_trace(path: &std::path::Path) -> anyhow::Result<Vec<Request>> {
    from_json(&json::load_file(path)?)
}

/// Save a trace file.
pub fn save_trace(path: &std::path::Path, reqs: &[Request]) -> anyhow::Result<()> {
    json::save_file(path, &to_json(reqs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approx() {
        let mut rng = Rng::new(1);
        let ts = Arrival::Poisson { rate: 10.0 }.timestamps(5000, &mut rng);
        let span = crate::sim::nanos_to_secs(*ts.last().unwrap());
        let rate = 5000.0 / span;
        assert!((rate - 10.0).abs() < 0.7, "rate={rate}");
    }

    #[test]
    fn arrivals_monotone() {
        let mut rng = Rng::new(2);
        for arrival in [
            Arrival::Poisson { rate: 100.0 },
            Arrival::Uniform { rate: 100.0 },
            Arrival::Burst,
        ] {
            let ts = arrival.timestamps(100, &mut rng);
            assert!(ts.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    #[test]
    fn burst_all_zero() {
        let mut rng = Rng::new(3);
        let ts = Arrival::Burst.timestamps(10, &mut rng);
        assert!(ts.iter().all(|&t| t == 0));
    }

    #[test]
    fn generate_deterministic() {
        let spec = WorkloadSpec::sharegpt_100(10.0);
        assert_eq!(spec.generate(), spec.generate());
    }

    #[test]
    fn sharegpt_lengths_plausible() {
        let mut spec = WorkloadSpec::sharegpt_100(10.0);
        spec.num_requests = 2000;
        let reqs = spec.generate();
        let mut prompts: Vec<f64> =
            reqs.iter().map(|r| r.prompt_tokens as f64).collect();
        prompts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = prompts[prompts.len() / 2];
        assert!((60.0..260.0).contains(&median), "median={median}");
        // heavy tail: p95 well above median
        let p95 = prompts[(prompts.len() as f64 * 0.95) as usize];
        assert!(p95 > 2.0 * median, "p95={p95} median={median}");
        // bounds respected
        assert!(reqs.iter().all(|r| r.prompt_tokens <= 1536));
        assert!(reqs.iter().all(|r| r.output_tokens >= 4));
    }

    #[test]
    fn sessions_and_prefix() {
        let spec = WorkloadSpec {
            num_requests: 200,
            arrival: Arrival::Burst,
            lengths: LengthDist::short(),
            sessions: 5,
            shared_prefix: 32,
            seed: 9,
        };
        let reqs = spec.generate();
        let distinct: std::collections::HashSet<u64> =
            reqs.iter().map(|r| r.session).collect();
        assert!(distinct.len() <= 5);
        assert!(distinct.len() >= 2); // Zipf over 5 sessions hits several
        for r in &reqs {
            assert!(r.shared_prefix <= r.prompt_tokens);
            assert!(r.shared_prefix <= 32);
        }
    }

    #[test]
    fn trace_roundtrip() {
        let spec = WorkloadSpec::sharegpt_100(10.0);
        let reqs = spec.generate();
        let v = to_json(&reqs);
        let parsed = from_json(&v).unwrap();
        assert_eq!(reqs, parsed);
    }

    #[test]
    fn trace_file_roundtrip() {
        let dir = std::env::temp_dir().join("llmss_test_trace");
        let path = dir.join("t.json");
        let reqs = WorkloadSpec::sharegpt_100(5.0).generate();
        save_trace(&path, &reqs).unwrap();
        let loaded = load_trace(&path).unwrap();
        assert_eq!(reqs, loaded);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn token_ids_share_session_prefix() {
        let mk = |id, session, shared| Request {
            id,
            arrival: 0,
            prompt_tokens: 64,
            output_tokens: 8,
            session,
            shared_prefix: shared,
        };
        let a = mk(1, 7, 32);
        let b = mk(2, 7, 32);
        let c = mk(3, 8, 32);
        let (ta, tb, tc) = (a.token_ids(), b.token_ids(), c.token_ids());
        assert_eq!(ta[..32], tb[..32], "same session shares prefix");
        assert_ne!(ta[..32], tc[..32], "different session differs");
        assert_ne!(ta[32..], tb[32..], "suffixes unique per request");
        assert_eq!(ta.len(), 64);
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(from_json(&Value::int(3)).is_err());
        let bad = json::parse(r#"[{"id": 1}]"#).unwrap();
        assert!(from_json(&bad).is_err());
    }
}
