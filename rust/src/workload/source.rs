//! Streaming traffic sources: the pull-based generators behind the
//! workload engine.
//!
//! A [`TrafficSource`] yields requests one at a time in non-decreasing
//! arrival order. The coordinator pulls the next request only after
//! scheduling the previous one, so a million-request scenario never holds
//! an upfront `Vec<Request>` — memory is bounded by in-flight state.
//!
//! Sources are `Send` (they ride inside a `Simulation` across sweep worker
//! threads) and deterministic: all randomness comes from a [`Rng`] seeded
//! by the [`WorkloadSpec`], and a given seed produces the same stream
//! whether the source is drained eagerly or pulled incrementally (there is
//! only one code path).
//!
//! Built-ins (registered in the [policy registry](crate::policy) under the
//! names of [`Traffic::builtin_names`]):
//! * [`OpenLoopSource`] — independent requests from any [`Arrival`]
//!   process (`poisson`, `uniform`, `burst`, `mmpp`, `diurnal`).
//! * [`SessionSource`] — closed-loop multi-turn conversations
//!   (`sessions`): each turn re-sends the growing conversation context as
//!   a shared prefix, so radix prefix caches see realistic reuse.
//! * [`ReplaySource`] — streams a JSON trace loaded via
//!   [`load_trace`](super::load_trace).
//!
//! Custom sources implement the trait in their own file and register via
//! [`crate::policy::register_traffic_source`]; configs select them with
//! [`Traffic::Custom`] and sweeps enumerate them alongside built-ins.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::sim::{secs_to_nanos, Nanos};
use crate::util::rng::{Rng, ZipfTable};

use super::{
    Arrival, ArrivalClock, LengthDist, Request, SloClass, TenantSpec, Traffic,
    WorkloadSpec,
};

/// A pull-based request stream (see module docs). Implementations must
/// yield non-decreasing `arrival` timestamps and unique request ids.
pub trait TrafficSource: Send {
    /// Registry/report name of this source (e.g. `"mmpp"`).
    fn name(&self) -> &str;

    /// The next request, or `None` when the stream is exhausted.
    fn next_request(&mut self) -> Option<Request>;
}

impl Iterator for Box<dyn TrafficSource> {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        self.next_request()
    }
}

/// Build the source for `traffic` with the shared knobs from `spec`.
/// [`Traffic::Custom`] cannot be built structurally — resolve it through
/// [`crate::policy::PolicyRegistry::make_traffic`] instead.
pub fn build(
    traffic: &Traffic,
    spec: &WorkloadSpec,
) -> anyhow::Result<Box<dyn TrafficSource>> {
    traffic.validate()?;
    spec.validate()?;
    Ok(match traffic {
        Traffic::Open(arrival) => Box::new(OpenLoopSource::new(arrival.clone(), spec)),
        Traffic::Sessions {
            start,
            turns,
            think_s,
        } => Box::new(SessionSource::new(start.clone(), *turns, *think_s, spec)),
        Traffic::Replay { path } => Box::new(ReplaySource::load(
            std::path::Path::new(path),
            spec.num_requests,
        )?),
        Traffic::Custom { name } => anyhow::bail!(
            "custom traffic '{name}' must resolve through the policy registry"
        ),
    })
}

/// Registry factory for the built-in source named `name`: uses the spec's
/// own traffic when it already is that kind, otherwise default parameters
/// at 10 req/s (the sweep axis path, where the name arrives as a
/// [`Traffic::Custom`] selection).
pub fn build_builtin(
    name: &str,
    spec: &WorkloadSpec,
) -> anyhow::Result<Box<dyn TrafficSource>> {
    let structural = !matches!(spec.traffic, Traffic::Custom { .. });
    let traffic = if structural && spec.traffic.kind_name() == name {
        spec.traffic.clone()
    } else {
        Traffic::for_name(name, 10.0)
            .ok_or_else(|| anyhow::anyhow!("no default parameters for traffic '{name}'"))?
    };
    build(&traffic, spec)
}

/// Per-request body sampling shared by the synthetic sources: lengths,
/// Zipf session assignment, and weighted tenant attribution.
struct BodySampler {
    lengths: LengthDist,
    sessions: usize,
    zipf: Option<ZipfTable>,
    shared_prefix: u64,
    tenants: Vec<TenantSpec>,
    weights: Vec<f64>,
}

impl BodySampler {
    fn new(spec: &WorkloadSpec) -> BodySampler {
        BodySampler {
            lengths: spec.lengths.clone(),
            sessions: spec.sessions,
            zipf: if spec.sessions > 0 {
                Some(ZipfTable::new(spec.sessions, 1.0))
            } else {
                None
            },
            shared_prefix: spec.shared_prefix,
            tenants: spec.tenants.clone(),
            weights: spec.tenants.iter().map(|t| t.weight).collect(),
        }
    }

    /// Weighted tenant draw; single-tenant specs consume no randomness.
    fn tenant(&self, rng: &mut Rng) -> (u32, SloClass) {
        if self.tenants.is_empty() {
            return (0, SloClass::Interactive);
        }
        let i = rng.weighted(&self.weights);
        (i as u32, self.tenants[i].slo)
    }

    /// One open-loop request body (draw order: prompt, output, session,
    /// tenant — keep stable, it is part of the determinism contract).
    fn request(&self, id: u64, arrival: Nanos, rng: &mut Rng) -> Request {
        let prompt = self.lengths.sample_prompt(rng);
        let output = self.lengths.sample_output(rng);
        let session = match &self.zipf {
            Some(z) => z.sample(rng) as u64,
            None => id,
        };
        let shared = if self.sessions > 0 {
            self.shared_prefix.min(prompt)
        } else {
            0
        };
        let (tenant, slo_class) = self.tenant(rng);
        Request {
            id,
            arrival,
            prompt_tokens: prompt.max(shared + 1),
            output_tokens: output,
            session,
            shared_prefix: shared,
            tenant,
            slo_class,
        }
    }
}

// ---------------------------------------------------------------------------
// Open-loop
// ---------------------------------------------------------------------------

/// Independent requests from an open-loop [`Arrival`] process.
pub struct OpenLoopSource {
    name: &'static str,
    remaining: usize,
    clock: ArrivalClock,
    body: BodySampler,
    rng: Rng,
    next_id: u64,
}

impl OpenLoopSource {
    pub fn new(arrival: Arrival, spec: &WorkloadSpec) -> OpenLoopSource {
        OpenLoopSource {
            name: arrival.kind_name(),
            remaining: spec.num_requests,
            clock: ArrivalClock::new(arrival),
            body: BodySampler::new(spec),
            rng: Rng::new(spec.seed),
            next_id: 0,
        }
    }
}

impl TrafficSource for OpenLoopSource {
    fn name(&self) -> &str {
        self.name
    }

    fn next_request(&mut self) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let at = self.clock.next(&mut self.rng);
        let id = self.next_id;
        self.next_id += 1;
        Some(self.body.request(id, at, &mut self.rng))
    }
}

// ---------------------------------------------------------------------------
// Closed-loop sessions
// ---------------------------------------------------------------------------

/// A conversation turn waiting to be emitted.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct PendingTurn {
    at: Nanos,
    /// Session ordinal — the deterministic tie-break at equal times.
    session: u64,
    turn: u32,
    /// Conversation context (prompt + output tokens of all prior turns),
    /// re-sent as the shared prefix of the next turn.
    ctx_tokens: u64,
    tenant: u32,
    slo: SloClass,
}

/// Closed-loop multi-turn conversations. Sessions start from an arrival
/// process; each session runs a fixed number of turns spaced by
/// exponential think times (an approximation of user think time — the
/// generator does not observe simulated completions). Turn `k` re-sends
/// the conversation context of turns `0..k` as its shared prefix, so
/// sessions exercise the radix prefix cache exactly like multi-turn chat.
pub struct SessionSource {
    remaining: usize,
    turns: u32,
    think_s: f64,
    clock: ArrivalClock,
    /// Next session start time (pre-drawn so the merge is one comparison).
    next_start: Nanos,
    pending: BinaryHeap<Reverse<PendingTurn>>,
    body: BodySampler,
    /// Context cap: conversations stop growing past this many tokens.
    ctx_cap: u64,
    rng: Rng,
    next_id: u64,
    next_session: u64,
    prev_at: Nanos,
}

impl SessionSource {
    pub fn new(
        start: Arrival,
        turns: u32,
        think_s: f64,
        spec: &WorkloadSpec,
    ) -> SessionSource {
        let mut rng = Rng::new(spec.seed);
        let mut clock = ArrivalClock::new(start);
        let first = clock.next(&mut rng);
        SessionSource {
            remaining: spec.num_requests,
            turns: turns.max(1),
            think_s,
            clock,
            next_start: first,
            pending: BinaryHeap::new(),
            body: BodySampler::new(spec),
            ctx_cap: spec.lengths.max_tokens.saturating_mul(4),
            rng,
            next_id: 0,
            next_session: 0,
            prev_at: 0,
        }
    }

    fn think_gap(&mut self) -> Nanos {
        if self.think_s <= 0.0 {
            return 0;
        }
        secs_to_nanos(self.rng.exp(1.0 / self.think_s))
    }
}

impl TrafficSource for SessionSource {
    fn name(&self) -> &str {
        "sessions"
    }

    fn next_request(&mut self) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;

        // Merge: earliest of (next session start, earliest pending turn);
        // ties go to the pending turn (older session) for determinism.
        let take_pending = self
            .pending
            .peek()
            .is_some_and(|r| r.0.at <= self.next_start);
        let turn = if take_pending {
            // simlint: allow(S01) — take_pending is only true when peek() returned Some
            self.pending.pop().unwrap().0
        } else {
            // Open a new session at `next_start`.
            let (tenant, slo) = self.body.tenant(&mut self.rng);
            let t = PendingTurn {
                at: self.next_start,
                session: self.next_session,
                turn: 0,
                ctx_tokens: 0,
                tenant,
                slo,
            };
            self.next_session += 1;
            self.next_start = self.clock.next(&mut self.rng);
            t
        };

        let fresh = self.body.lengths.sample_prompt(&mut self.rng);
        let output = self.body.lengths.sample_output(&mut self.rng);
        let shared = if turn.turn == 0 {
            // first turn: system prompt only (if the spec shares one)
            self.body.shared_prefix
        } else {
            turn.ctx_tokens.min(self.ctx_cap)
        };
        let prompt = shared + fresh.max(1);
        // arrivals must be globally monotone even if heap/start interleave
        // at saturated times
        let at = turn.at.max(self.prev_at);
        self.prev_at = at;
        let id = self.next_id;
        self.next_id += 1;

        if turn.turn + 1 < self.turns {
            let gap = self.think_gap();
            self.pending.push(Reverse(PendingTurn {
                at: at.saturating_add(gap),
                session: turn.session,
                turn: turn.turn + 1,
                ctx_tokens: prompt + output,
                tenant: turn.tenant,
                slo: turn.slo,
            }));
        }

        Some(Request {
            id,
            arrival: at,
            prompt_tokens: prompt,
            output_tokens: output,
            session: turn.session,
            shared_prefix: shared,
            tenant: turn.tenant,
            slo_class: turn.slo,
        })
    }
}

// ---------------------------------------------------------------------------
// Trace replay
// ---------------------------------------------------------------------------

/// Streams a pre-loaded request trace (arrival-sorted by
/// [`from_json`](super::from_json)), truncated to the spec's request
/// budget when the trace is longer.
pub struct ReplaySource {
    reqs: std::vec::IntoIter<Request>,
}

impl ReplaySource {
    pub fn load(path: &std::path::Path, limit: usize) -> anyhow::Result<ReplaySource> {
        let mut reqs = super::load_trace(path)?;
        if limit > 0 && reqs.len() > limit {
            reqs.truncate(limit);
        }
        Ok(ReplaySource {
            reqs: reqs.into_iter(),
        })
    }

    /// Replay an in-memory request list (must be arrival-sorted).
    pub fn from_requests(reqs: Vec<Request>) -> ReplaySource {
        ReplaySource {
            reqs: reqs.into_iter(),
        }
    }
}

impl TrafficSource for ReplaySource {
    fn name(&self) -> &str {
        "replay"
    }

    fn next_request(&mut self) -> Option<Request> {
        self.reqs.next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(traffic: Traffic) -> WorkloadSpec {
        WorkloadSpec {
            num_requests: 120,
            traffic,
            lengths: LengthDist::short(),
            sessions: 0,
            shared_prefix: 16,
            tenants: TenantSpec::mix(2),
            seed: 0xFEED,
        }
    }

    fn drain(src: &mut dyn TrafficSource) -> Vec<Request> {
        std::iter::from_fn(|| src.next_request()).collect()
    }

    #[test]
    fn every_builtin_streams_monotone_unique_ids() {
        for name in Traffic::builtin_names() {
            let s = spec(Traffic::for_name(name, 20.0).unwrap());
            let mut src = build(&s.traffic, &s).unwrap();
            assert_eq!(src.name(), *name);
            let reqs = drain(src.as_mut());
            assert_eq!(reqs.len(), 120, "{name}");
            assert!(
                reqs.windows(2).all(|w| w[0].arrival <= w[1].arrival),
                "{name} not monotone"
            );
            let ids: std::collections::HashSet<u64> =
                reqs.iter().map(|r| r.id).collect();
            assert_eq!(ids.len(), 120, "{name} ids not unique");
            assert!(src.next_request().is_none(), "{name} must stay exhausted");
        }
    }

    #[test]
    fn eager_equals_incremental() {
        for name in Traffic::builtin_names() {
            let s = spec(Traffic::for_name(name, 15.0).unwrap());
            let eager = s.generate().unwrap();
            let mut src = build(&s.traffic, &s).unwrap();
            let mut pulled = Vec::new();
            while let Some(r) = src.next_request() {
                pulled.push(r);
            }
            assert_eq!(eager, pulled, "{name} eager != incremental");
        }
    }

    #[test]
    fn sessions_share_growing_prefixes() {
        let s = spec(Traffic::sessions(2.0, 4, 1.0));
        let reqs = s.generate().unwrap();
        // group by session; later turns must carry the prior context
        use std::collections::HashMap;
        let mut by_session: HashMap<u64, Vec<&Request>> = HashMap::new();
        for r in &reqs {
            by_session.entry(r.session).or_default().push(r);
        }
        let mut grew = false;
        let mut saw_multi_turn = false;
        for turns in by_session.values() {
            for pair in turns.windows(2) {
                saw_multi_turn = true;
                assert!(
                    pair[1].shared_prefix >= pair[0].shared_prefix,
                    "conversation context must not shrink"
                );
                grew |= pair[1].shared_prefix > pair[0].shared_prefix;
                assert!(pair[1].arrival >= pair[0].arrival);
                // the session-deterministic prefix actually coincides in
                // token-id space (radix-cache contract)
                let a = pair[0].token_ids();
                let b = pair[1].token_ids();
                let n = pair[0].shared_prefix as usize;
                assert_eq!(a[..n], b[..n], "turns must share prefix token ids");
            }
        }
        assert!(saw_multi_turn, "expected at least one multi-turn session");
        assert!(grew, "context must grow across turns somewhere");
    }

    #[test]
    fn sessions_respect_turn_budget_and_tenancy() {
        let s = spec(Traffic::sessions(5.0, 3, 0.5));
        let reqs = s.generate().unwrap();
        use std::collections::HashMap;
        let mut turns: HashMap<u64, usize> = HashMap::new();
        for r in &reqs {
            *turns.entry(r.session).or_default() += 1;
            // a session's tenant/class never changes mid-conversation
        }
        assert!(turns.values().all(|&n| n <= 3), "{turns:?}");
        let mut tenant_of: HashMap<u64, (u32, SloClass)> = HashMap::new();
        for r in &reqs {
            let e = tenant_of.entry(r.session).or_insert((r.tenant, r.slo_class));
            assert_eq!(*e, (r.tenant, r.slo_class), "session switched tenant");
        }
    }

    #[test]
    fn replay_streams_trace_in_order() {
        let s = spec(Traffic::poisson(30.0));
        let reqs = s.generate().unwrap();
        let mut src = ReplaySource::from_requests(reqs.clone());
        assert_eq!(src.name(), "replay");
        let replayed = drain(&mut src);
        assert_eq!(replayed, reqs);
    }

    #[test]
    fn replay_truncates_to_budget() {
        let dir = std::env::temp_dir().join("llmss_replay_src");
        let path = dir.join("trace.json");
        let s = spec(Traffic::poisson(30.0));
        super::super::save_trace(&path, &s.generate().unwrap()).unwrap();
        let mut short = ReplaySource::load(&path, 7).unwrap();
        assert_eq!(drain(&mut short).len(), 7);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn custom_traffic_needs_registry() {
        let s = spec(Traffic::Custom { name: "surge".into() });
        assert!(build(&s.traffic, &s).is_err());
    }
}
