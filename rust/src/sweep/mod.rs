//! Parallel scenario-sweep engine (DESIGN.md §5).
//!
//! The paper positions LLMServingSim2.0 as a design-space-exploration
//! platform: its experiments are *grids* of serving configurations (Table
//! II presets x request rates x policies x hardware). This module makes
//! that a first-class workflow:
//!
//! 1. [`SweepSpec`] declares axes; [`SweepSpec::expand`] takes their
//!    cartesian product into named [`SimConfig`]s.
//! 2. [`run_sweep`] executes the grid on a `std::thread::scope` worker
//!    pool. Each worker pulls the next config off a shared atomic cursor,
//!    builds a [`Simulation`](crate::coordinator::Simulation) and runs it
//!    to completion. Simulations are individually sequential and
//!    deterministic, so per-config reports are **byte-identical** for any
//!    worker count — parallelism only changes wall-clock time.
//! 3. [`summarize`] aggregates the per-config reports into a comparative
//!    summary: best/worst config per metric plus percentage deltas against
//!    a baseline config.
//!
//! Empty axes inherit the preset's default for that dimension, so the grid
//! size is the product of the non-empty axes only.
//!
//! Policy axes (router / sched / evict) hold policy *names* resolved
//! through the [`policy registry`](crate::policy), so user-registered
//! policies sweep exactly like built-ins:
//! [`SweepAxes::with_all_policies`] enumerates every registry entry, and
//! [`SweepSpec::expand`] rejects unknown names up front with the candidate
//! list instead of failing mid-sweep.

pub mod manifest;
pub mod merge;
pub mod shard;

pub use manifest::{
    content_hash, replicate_seed, shard_point_indices, slice_hash,
    ExperimentManifest, MANIFEST_FORMAT,
};
pub use merge::{
    find_shard_files, merge, merge_files, render_aggregate_table, run_manifest,
    AGGREGATE_FORMAT,
};
pub use shard::{
    run_all_shards, run_shard, run_shard_to_file, shard_file_name, ShardOutcome,
    ShardResult, SHARD_FORMAT,
};

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::{presets, ChaosConfig, PerfBackend, SimConfig};
use crate::coordinator::{run_config, SimSummary};
use crate::metrics::Report;
use crate::policy::PolicyRegistry;
use crate::util::bench::Table;
use crate::util::json::Value;
use crate::workload::{LengthDist, Traffic};

/// Hardware preset substituted when the hardware axis is empty.
pub const DEFAULT_HARDWARE: &str = "rtx3090";

/// The swept dimensions. An empty axis means "keep the preset's default"
/// and contributes a factor of 1 to the grid size.
#[derive(Debug, Clone, Default)]
pub struct SweepAxes {
    /// Table II serving-config names ([`presets::by_name`]). Must be
    /// non-empty — it anchors every grid point.
    pub presets: Vec<String>,
    /// Hardware names, resolved through the global
    /// [`hardware registry`](crate::perf::hardware): built-in presets and
    /// registered bundles (profiled devices) sweep identically. Unknown
    /// names are rejected by [`SweepSpec::expand`] with the candidate list.
    pub hardware: Vec<String>,
    /// Poisson arrival rates, requests/second.
    pub rates: Vec<f64>,
    /// Global router-policy names (resolved through the policy registry).
    pub routers: Vec<String>,
    /// Per-instance batch-scheduling policy names.
    pub scheds: Vec<String>,
    /// Prefix-cache eviction-policy names, applied wherever an instance
    /// has a prefix cache. Only meaningful on `*+PC` presets —
    /// [`SweepSpec::expand`] errors if a grid point's preset has no
    /// prefix cache at all (the axis would be a silent no-op).
    pub evictions: Vec<String>,
    /// Performance-model backends.
    pub backends: Vec<PerfBackend>,
    /// Traffic-source names (resolved through the policy registry, like
    /// the policy axes): built-ins (`poisson`, `mmpp`, `diurnal`,
    /// `sessions`, ...) and user-registered sources sweep identically.
    /// Each grid point's source runs at the rate axis value in effect (or
    /// 10 req/s when the rate axis is empty).
    pub workloads: Vec<String>,
    /// Cluster-controller names (the fourth plugin axis, DESIGN.md §9):
    /// `static`, `queue-threshold`, `failure-replay`, and user
    /// registrations. Each grid point runs with that controller on the
    /// preset's `cluster` settings.
    pub controllers: Vec<String>,
    /// Chaos profile names ([`ChaosConfig::profile`]): each grid point
    /// runs under the `chaos` controller with that fault-injection
    /// profile. `"none"` is the inert profile — its report is
    /// byte-identical to the same point without the axis, making it the
    /// natural in-grid baseline for resilience comparisons.
    pub chaos: Vec<String>,
}

impl SweepAxes {
    /// Fill the three policy axes with *every* policy registered in
    /// `registry` — built-ins and user registrations alike. This is the
    /// registry-driven replacement for hard-coded `::all()` lists.
    ///
    /// Sweep execution resolves names through the **global** registry —
    /// each grid point runs via
    /// [`Simulation::new`](crate::coordinator::Simulation::new) — so pass
    /// [`crate::policy::snapshot`] here, or make sure any custom entries
    /// in a hand-built registry are also registered globally
    /// ([`crate::policy::register_sched_policy`] & friends) before
    /// expanding.
    pub fn with_all_policies(mut self, registry: &PolicyRegistry) -> Self {
        self.routers = registry.route_names();
        self.scheds = registry.sched_names();
        self.evictions = registry.evict_names();
        self
    }

    /// Fill the workload axis with every traffic source registered in
    /// `registry` (built-ins plus user registrations; the same global-
    /// registry caveat as [`with_all_policies`](Self::with_all_policies)
    /// applies).
    pub fn with_all_workloads(mut self, registry: &PolicyRegistry) -> Self {
        self.workloads = registry.traffic_names();
        self
    }

    /// Fill the controller axis with every cluster controller registered
    /// in `registry` (same global-registry caveat as the other axes).
    pub fn with_all_controllers(mut self, registry: &PolicyRegistry) -> Self {
        self.controllers = registry.controller_names();
        self
    }

    /// Fill the hardware axis with every device in `registry` — the four
    /// built-in presets plus every imported bundle. This is what the CLI's
    /// `sweep --hardware all` expands to. Sweep execution resolves names
    /// through the **global** hardware registry, so pass
    /// [`crate::perf::hardware::snapshot`] here (or globally register any
    /// custom entries first).
    pub fn with_all_hardware(mut self, registry: &crate::perf::hardware::HardwareRegistry) -> Self {
        self.hardware = registry.names();
        self
    }
}

/// A full sweep declaration: axes plus the knobs shared by every point.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub axes: SweepAxes,
    /// Dense / MoE model presets substituted into the serving presets.
    pub dense_model: String,
    pub moe_model: String,
    /// Requests per grid point.
    pub num_requests: usize,
    /// Seed applied to both the simulation and the workload generator of
    /// every point — the determinism anchor.
    pub seed: u64,
    /// Use the short length distribution (fast exploratory sweeps).
    pub quick: bool,
    /// Baseline config name for the comparative summary; defaults to the
    /// first grid point.
    pub baseline: Option<String>,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            axes: SweepAxes {
                presets: vec!["S(D)".to_string()],
                ..SweepAxes::default()
            },
            dense_model: "tiny-dense".to_string(),
            moe_model: "tiny-moe".to_string(),
            num_requests: 40,
            seed: 0xC0FFEE,
            quick: false,
            baseline: None,
        }
    }
}

/// `[None]` for an empty axis (inherit preset default), else each value.
fn axis<T>(values: &[T]) -> Vec<Option<&T>> {
    if values.is_empty() {
        vec![None]
    } else {
        values.iter().map(Some).collect()
    }
}

impl SweepSpec {
    /// Grid size without expanding (product of non-empty axes).
    pub fn grid_size(&self) -> usize {
        let f = |n: usize| n.max(1);
        f(self.axes.presets.len())
            * f(self.axes.hardware.len())
            * f(self.axes.rates.len())
            * f(self.axes.workloads.len())
            * f(self.axes.routers.len())
            * f(self.axes.scheds.len())
            * f(self.axes.evictions.len())
            * f(self.axes.backends.len())
            * f(self.axes.controllers.len())
            * f(self.axes.chaos.len())
    }

    /// Expand the cartesian product into named, validated [`SimConfig`]s.
    ///
    /// Point names are `preset|axis=value|...`, listing only the swept
    /// axes, so they are stable identifiers for baselines and reports.
    pub fn expand(&self) -> anyhow::Result<Vec<SimConfig>> {
        if self.axes.presets.is_empty() {
            anyhow::bail!("sweep needs at least one serving preset");
        }
        // Reject unknown policy names up front (with the registered
        // candidates) instead of failing on the first grid point mid-run.
        // Existence checks only — user factories may be stateful, so
        // nothing is instantiated here.
        let registry = crate::policy::snapshot();
        for r in &self.axes.routers {
            registry.check_route(r)?;
        }
        for s in &self.axes.scheds {
            registry.check_sched(s)?;
        }
        for e in &self.axes.evictions {
            registry.check_evict(e)?;
        }
        for w in &self.axes.workloads {
            // rejects unknown names with candidates, and 'replay' with a
            // pointer to its structural config spelling
            registry.check_traffic(w)?;
        }
        for c in &self.axes.controllers {
            registry.check_controller(c)?;
        }
        for p in &self.axes.chaos {
            // rejects unknown profiles with the candidate list
            ChaosConfig::profile(p)?;
        }
        // Hardware names resolve through their own registry (built-ins +
        // imported bundles); same up-front rejection with candidates.
        let hw_registry = crate::perf::hardware::snapshot();
        for h in &self.axes.hardware {
            hw_registry.check(h)?;
        }
        let mut out: Vec<SimConfig> = vec![];
        let mut seen: BTreeSet<String> = BTreeSet::new();
        for preset in &self.axes.presets {
            for hw in axis(&self.axes.hardware) {
                for rate in axis(&self.axes.rates) {
                    for workload in axis(&self.axes.workloads) {
                        for router in axis(&self.axes.routers) {
                            for sched in axis(&self.axes.scheds) {
                                for evict in axis(&self.axes.evictions) {
                                    for backend in axis(&self.axes.backends) {
                                        for ctrl in axis(&self.axes.controllers) {
                                            for chaos in axis(&self.axes.chaos) {
                                                let cfg = self.point(
                                                    preset, hw, rate, workload,
                                                    router, sched, evict,
                                                    backend, ctrl, chaos,
                                                )?;
                                                if !seen.insert(cfg.name.clone())
                                                {
                                                    anyhow::bail!(
                                                        "duplicate sweep point \
                                                         '{}' (repeated axis \
                                                         value?)",
                                                        cfg.name
                                                    );
                                                }
                                                out.push(cfg);
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    #[allow(clippy::too_many_arguments)]
    fn point(
        &self,
        preset: &str,
        hw: Option<&String>,
        rate: Option<&f64>,
        workload: Option<&String>,
        router: Option<&String>,
        sched: Option<&String>,
        evict: Option<&String>,
        backend: Option<&PerfBackend>,
        controller: Option<&String>,
        chaos: Option<&String>,
    ) -> anyhow::Result<SimConfig> {
        let hw_name = hw.map(String::as_str).unwrap_or(DEFAULT_HARDWARE);
        let mut cfg = presets::by_name(
            preset,
            &self.dense_model,
            &self.moe_model,
            hw_name,
        )
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown serving preset '{preset}' (expected one of {:?})",
                presets::serving_preset_names()
            )
        })?;

        let mut name = preset.to_string();
        if let Some(h) = hw {
            name.push_str(&format!("|hw={h}"));
        }
        if let Some(r) = rate {
            cfg.workload.traffic = Traffic::poisson(*r);
            name.push_str(&format!("|rate={r}"));
        }
        if let Some(w) = workload {
            // the workload axis consumes the rate axis value (or the
            // 10 req/s default) as its nominal rate
            let r = rate.copied().unwrap_or(10.0);
            cfg.workload.traffic = Traffic::for_name(w, r)
                .unwrap_or_else(|| Traffic::Custom { name: w.clone() });
            name.push_str(&format!("|wl={w}"));
        }
        if let Some(p) = router {
            cfg.router = p.clone();
            name.push_str(&format!("|router={p}"));
        }
        if let Some(s) = sched {
            for inst in &mut cfg.instances {
                inst.sched = s.clone();
            }
            name.push_str(&format!("|sched={s}"));
        }
        if let Some(e) = evict {
            let mut applied = false;
            for inst in &mut cfg.instances {
                if let Some(pc) = &mut inst.prefix_cache {
                    pc.policy = e.clone();
                    applied = true;
                }
            }
            // A silent no-op axis would expand into byte-identical grid
            // points differing only in name, presenting "eviction has zero
            // effect" as a result instead of an inapplicable dimension.
            if !applied {
                anyhow::bail!(
                    "eviction axis value '{e}' has no effect on preset \
                     '{preset}': no instance has a prefix cache (use a \
                     '+PC' preset or drop the eviction axis)"
                );
            }
            name.push_str(&format!("|evict={e}"));
        }
        if let Some(b) = backend {
            cfg.perf = b.clone();
            name.push_str(&format!("|perf={}", b.cli_str()));
        }
        if let Some(c) = controller {
            cfg.cluster.controller = c.clone();
            name.push_str(&format!("|ctrl={c}"));
        }
        if let Some(p) = chaos {
            // The chaos axis owns the controller slot for its points; a
            // combined controllers x chaos grid would make non-chaos
            // controllers silently run without their profile applied.
            if controller.is_some() {
                anyhow::bail!(
                    "the chaos axis sets the cluster controller to 'chaos'; \
                     drop the controller axis or the chaos axis"
                );
            }
            cfg.cluster.controller = "chaos".to_string();
            cfg.cluster.chaos = ChaosConfig::profile(p)?;
            name.push_str(&format!("|chaos={p}"));
        }

        cfg.name = name;
        cfg.seed = self.seed;
        cfg.workload.seed = self.seed;
        cfg.workload.num_requests = self.num_requests;
        if self.quick {
            cfg.workload.lengths = LengthDist::short();
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// One completed grid point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub name: String,
    pub report: Report,
    pub summary: SimSummary,
}

/// All grid points, in expansion order regardless of worker scheduling.
#[derive(Debug)]
pub struct SweepOutcome {
    pub points: Vec<SweepPoint>,
    pub threads: usize,
    /// Wall-clock of the whole sweep (diagnostics only — excluded from the
    /// deterministic per-point reports).
    pub wall_ns: u64,
}

/// Run every config on `threads` workers sharing an atomic work cursor.
///
/// Each point is built and run entirely by one worker (the `Send`-safe
/// core lets the `Simulation` live on that worker's stack), so results are
/// independent of the worker count and of scheduling order; slot `i` of
/// the outcome always corresponds to `cfgs[i]`.
pub fn run_sweep(cfgs: &[SimConfig], threads: usize) -> anyhow::Result<SweepOutcome> {
    if cfgs.is_empty() {
        anyhow::bail!("sweep has no grid points");
    }
    let threads = threads.clamp(1, cfgs.len());
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<anyhow::Result<SweepPoint>>>> =
        (0..cfgs.len()).map(|_| Mutex::new(None)).collect();
    // simlint: allow(D02) — wall-clock diagnostics only: wall_ns reports sweep
    // duration and is outside the byte-determinism contract (per-point reports
    // never depend on it)
    let t0 = std::time::Instant::now();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cfgs.len() {
                    break;
                }
                let cfg = cfgs[i].clone();
                let name = cfg.name.clone();
                let res = run_config(cfg).map(|(report, summary)| SweepPoint {
                    name,
                    report,
                    summary,
                });
                // simlint: allow(S01) — a poisoned result slot is unrecoverable; abort loudly
                *slots[i].lock().unwrap() = Some(res);
            });
        }
    });

    let mut points = Vec::with_capacity(cfgs.len());
    for slot in slots {
        let filled = slot
            .into_inner()
            // simlint: allow(S01) — a poisoned result slot is unrecoverable; abort loudly
            .expect("sweep slot mutex poisoned")
            // simlint: allow(S01) — the cursor hands every index to exactly one worker
            .expect("sweep worker exited without filling its slot");
        points.push(filled?);
    }
    Ok(SweepOutcome {
        points,
        threads,
        wall_ns: t0.elapsed().as_nanos() as u64,
    })
}

// ---------------------------------------------------------------------------
// Comparative summary
// ---------------------------------------------------------------------------

/// A headline metric extracted from a [`Report`] for cross-config ranking.
pub struct MetricDef {
    pub key: &'static str,
    pub higher_is_better: bool,
    extract: fn(&Report) -> f64,
}

fn m_ttft(r: &Report) -> f64 {
    r.ttft_ns.mean / 1e6
}
fn m_tpot(r: &Report) -> f64 {
    r.tpot_ns.mean / 1e6
}
fn m_itl(r: &Report) -> f64 {
    r.itl_ns.mean / 1e6
}
fn m_tps(r: &Report) -> f64 {
    r.throughput_tps
}
fn m_makespan(r: &Report) -> f64 {
    r.makespan as f64 / 1e9
}

/// The ranked metrics, in presentation order.
pub static METRICS: &[MetricDef] = &[
    MetricDef {
        key: "ttft_mean_ms",
        higher_is_better: false,
        extract: m_ttft,
    },
    MetricDef {
        key: "tpot_mean_ms",
        higher_is_better: false,
        extract: m_tpot,
    },
    MetricDef {
        key: "itl_mean_ms",
        higher_is_better: false,
        extract: m_itl,
    },
    MetricDef {
        key: "throughput_tps",
        higher_is_better: true,
        extract: m_tps,
    },
    MetricDef {
        key: "makespan_s",
        higher_is_better: false,
        extract: m_makespan,
    },
];

/// Best/worst grid point for one metric.
#[derive(Debug, Clone)]
pub struct Extreme {
    pub metric: &'static str,
    pub best_config: String,
    pub best: f64,
    pub worst_config: String,
    pub worst: f64,
}

/// Percentage deltas of one grid point against the baseline, keyed by
/// metric (`(value - baseline) / baseline * 100`).
#[derive(Debug, Clone)]
pub struct Delta {
    pub config: String,
    pub pct: Vec<(&'static str, f64)>,
}

/// Comparative view over a completed sweep.
#[derive(Debug, Clone)]
pub struct SweepSummary {
    pub baseline: String,
    pub extremes: Vec<Extreme>,
    pub deltas: Vec<Delta>,
}

/// Aggregate the outcome into best/worst per metric and deltas vs
/// `baseline` (name of a grid point; default: the first point).
pub fn summarize(
    outcome: &SweepOutcome,
    baseline: Option<&str>,
) -> anyhow::Result<SweepSummary> {
    let values: Vec<(String, Vec<f64>)> = outcome
        .points
        .iter()
        .map(|p| {
            (
                p.name.clone(),
                METRICS.iter().map(|m| (m.extract)(&p.report)).collect(),
            )
        })
        .collect();
    summarize_values(&values, baseline)
}

/// The ranking core behind [`summarize`], over pre-extracted metric
/// values (`points[i].1[j]` is `METRICS[j]` for point `i`).
///
/// The shard-merge path ([`merge`]) summarizes from round-tripped report
/// *files* rather than live [`Report`]s; both paths funnel through this
/// one function — same strict comparisons, same first-wins tie-break,
/// same delta arithmetic — so a merged aggregate ranks byte-identically
/// to the in-process sweep it reassembles.
pub fn summarize_values(
    points: &[(String, Vec<f64>)],
    baseline: Option<&str>,
) -> anyhow::Result<SweepSummary> {
    if points.is_empty() {
        anyhow::bail!("cannot summarize an empty sweep");
    }
    let base_name = baseline.unwrap_or(&points[0].0);
    let base = points
        .iter()
        .find(|(name, _)| name == base_name)
        .ok_or_else(|| {
            anyhow::anyhow!("baseline '{base_name}' is not a sweep point")
        })?;

    let mut extremes = vec![];
    for (mi, m) in METRICS.iter().enumerate() {
        let mut best = &points[0];
        let mut worst = &points[0];
        for p in &points[1..] {
            let v = p.1[mi];
            let better = if m.higher_is_better {
                v > best.1[mi]
            } else {
                v < best.1[mi]
            };
            let worse = if m.higher_is_better {
                v < worst.1[mi]
            } else {
                v > worst.1[mi]
            };
            if better {
                best = p;
            }
            if worse {
                worst = p;
            }
        }
        extremes.push(Extreme {
            metric: m.key,
            best_config: best.0.clone(),
            best: best.1[mi],
            worst_config: worst.0.clone(),
            worst: worst.1[mi],
        });
    }

    let deltas = points
        .iter()
        .filter(|(name, _)| name != &base.0)
        .map(|(name, vals)| Delta {
            config: name.clone(),
            pct: METRICS
                .iter()
                .enumerate()
                .map(|(mi, m)| {
                    let b = base.1[mi];
                    let v = vals[mi];
                    let pct = if b.abs() > 1e-12 {
                        (v - b) / b * 100.0
                    } else {
                        0.0
                    };
                    (m.key, pct)
                })
                .collect(),
        })
        .collect();

    Ok(SweepSummary {
        baseline: base.0.clone(),
        extremes,
        deltas,
    })
}

// ---------------------------------------------------------------------------
// Emission: JSON + terminal table
// ---------------------------------------------------------------------------

/// Serialize one completed grid point — the per-point record embedded in
/// both [`sweep_json`] and shard result files (identical bytes in each,
/// which is what lets a merged aggregate reproduce the single-process
/// output).
pub fn point_json(p: &SweepPoint) -> Value {
    let mut fields = vec![
        ("name", Value::str(p.name.clone())),
        ("steps", Value::int(p.summary.steps as i64)),
        ("events", Value::int(p.summary.events as i64)),
        (
            "inter_instance_bytes",
            Value::int(p.summary.inter_instance_bytes as i64),
        ),
    ];
    // Cluster-dynamics keys only when a controller ran, so static
    // sweep output stays byte-identical to pre-driver reports.
    if p.summary.controller != "static" {
        fields.push(("controller", Value::str(p.summary.controller.clone())));
        fields.push((
            "peak_instances",
            Value::int(p.summary.peak_instances as i64),
        ));
    }
    fields.push(("report", p.report.to_json()));
    Value::obj(fields)
}

/// Serialize a comparative summary — shared verbatim by [`sweep_json`]
/// and the shard-merge aggregate.
pub fn summary_json(summary: &SweepSummary) -> Value {
    let extremes = summary
        .extremes
        .iter()
        .map(|e| {
            Value::obj(vec![
                ("metric", Value::str(e.metric)),
                ("best_config", Value::str(e.best_config.clone())),
                ("best", Value::float(e.best)),
                ("worst_config", Value::str(e.worst_config.clone())),
                ("worst", Value::float(e.worst)),
            ])
        })
        .collect();
    let deltas = summary
        .deltas
        .iter()
        .map(|d| {
            Value::obj(vec![
                ("config", Value::str(d.config.clone())),
                (
                    "pct_vs_baseline",
                    Value::obj(
                        d.pct
                            .iter()
                            .map(|(k, v)| (*k, Value::float(*v)))
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    Value::obj(vec![
        ("baseline", Value::str(summary.baseline.clone())),
        ("extremes", Value::Arr(extremes)),
        ("deltas", Value::Arr(deltas)),
    ])
}

/// Serialize the full sweep (per-point reports + comparative summary).
pub fn sweep_json(outcome: &SweepOutcome, summary: &SweepSummary) -> Value {
    Value::obj(vec![
        ("threads", Value::int(outcome.threads as i64)),
        ("wall_ns", Value::int(outcome.wall_ns as i64)),
        (
            "points",
            Value::arr(outcome.points.iter().map(point_json).collect()),
        ),
        ("summary", summary_json(summary)),
    ])
}

/// Render the per-point metrics plus throughput delta vs the baseline.
pub fn render_table(outcome: &SweepOutcome, summary: &SweepSummary) -> Table {
    let mut t = Table::new(&[
        "config",
        "finished",
        "TTFT ms",
        "TPOT ms",
        "ITL ms",
        "tok/s",
        "Δ tok/s %",
    ]);
    for p in &outcome.points {
        let delta = if p.name == summary.baseline {
            "base".to_string()
        } else {
            summary
                .deltas
                .iter()
                .find(|d| d.config == p.name)
                .and_then(|d| {
                    d.pct
                        .iter()
                        .find(|(k, _)| *k == "throughput_tps")
                        .map(|(_, v)| format!("{v:+.1}"))
                })
                .unwrap_or_default()
        };
        t.row(&[
            p.name.clone(),
            p.report.num_finished.to_string(),
            format!("{:.3}", p.report.ttft_ns.mean / 1e6),
            format!("{:.3}", p.report.tpot_ns.mean / 1e6),
            format!("{:.3}", p.report.itl_ns.mean / 1e6),
            format!("{:.1}", p.report.throughput_tps),
            delta,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn quick_spec() -> SweepSpec {
        SweepSpec {
            num_requests: 10,
            quick: true,
            ..SweepSpec::default()
        }
    }

    #[test]
    fn empty_axes_yield_single_default_point() {
        let cfgs = quick_spec().expand().unwrap();
        assert_eq!(cfgs.len(), 1);
        assert_eq!(cfgs[0].name, "S(D)");
        assert_eq!(cfgs[0].workload.num_requests, 10);
    }

    #[test]
    fn grid_is_cartesian_with_stable_names() {
        let mut spec = quick_spec();
        spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
        spec.axes.rates = vec![5.0, 20.0];
        spec.axes.routers =
            vec!["round-robin".into(), "least-outstanding".into()];
        assert_eq!(spec.grid_size(), 8);
        let cfgs = spec.expand().unwrap();
        assert_eq!(cfgs.len(), 8);
        let names: HashSet<&str> = cfgs.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names.len(), 8, "names must be unique");
        assert!(names.contains("S(D)|rate=5|router=round-robin"));
        assert!(names.contains("M(D)|rate=20|router=least-outstanding"));
        // the axes actually landed in the configs
        for cfg in &cfgs {
            match &cfg.workload.traffic {
                Traffic::Open(crate::workload::Arrival::Poisson { rate }) => {
                    assert!(*rate == 5.0 || *rate == 20.0)
                }
                other => panic!("unexpected traffic {other:?}"),
            }
        }
    }

    #[test]
    fn workload_axis_expands_and_feeds_the_rate() {
        let mut spec = quick_spec();
        spec.axes.rates = vec![8.0];
        spec.axes.workloads = vec!["mmpp".into(), "sessions".into()];
        let cfgs = spec.expand().unwrap();
        assert_eq!(cfgs.len(), 2);
        assert_eq!(cfgs[0].name, "S(D)|rate=8|wl=mmpp");
        assert_eq!(cfgs[0].workload.traffic.kind_name(), "mmpp");
        match &cfgs[0].workload.traffic {
            Traffic::Open(crate::workload::Arrival::Mmpp { rate_on, .. }) => {
                assert_eq!(*rate_on, 32.0, "mmpp peaks at 4x the nominal rate")
            }
            other => panic!("unexpected traffic {other:?}"),
        }
        assert_eq!(cfgs[1].workload.traffic.kind_name(), "sessions");
        // unknown and non-sweepable names are rejected up front
        let mut spec = quick_spec();
        spec.axes.workloads = vec!["surge-nonexistent".into()];
        let e = spec.expand().unwrap_err().to_string();
        assert!(e.contains("surge-nonexistent") && e.contains("poisson"), "{e}");
        let mut spec = quick_spec();
        spec.axes.workloads = vec!["replay".into()];
        assert!(spec.expand().is_err());
    }

    #[test]
    fn all_workloads_axis_enumerates_registry() {
        let registry = crate::policy::snapshot();
        let mut spec = quick_spec();
        spec.axes = spec.axes.with_all_workloads(&registry);
        // drop any custom registrations without default params from other
        // tests: keep only names `for_name` understands plus customs, all
        // of which expand (customs resolve at build time)
        let cfgs = spec.expand().unwrap();
        assert_eq!(cfgs.len(), spec.axes.workloads.len());
        for name in Traffic::builtin_names() {
            assert!(
                cfgs.iter().any(|c| c.name.contains(&format!("wl={name}"))),
                "workload '{name}' missing from grid"
            );
        }
    }

    #[test]
    fn eviction_axis_applies_to_prefix_cache_presets() {
        let mut spec = quick_spec();
        spec.axes.presets = vec!["S(D)+PC".into()];
        spec.axes.evictions = vec!["lfu".into()];
        let cfgs = spec.expand().unwrap();
        assert_eq!(cfgs.len(), 1);
        let pc = cfgs[0].instances[0].prefix_cache.as_ref().unwrap();
        assert_eq!(pc.policy, "lfu");
        assert_eq!(cfgs[0].name, "S(D)+PC|evict=lfu");
    }

    #[test]
    fn unknown_preset_and_duplicates_rejected() {
        let mut spec = quick_spec();
        spec.axes.presets = vec!["X(Q)".into()];
        assert!(spec.expand().is_err());
        let mut spec = quick_spec();
        spec.axes.rates = vec![10.0, 10.0];
        assert!(spec.expand().is_err(), "duplicate grid point must error");
    }

    #[test]
    fn eviction_axis_on_cacheless_preset_rejected() {
        // S(D) has no prefix cache: the axis would be a silent no-op
        // producing byte-identical points, so expand refuses it.
        let mut spec = quick_spec();
        spec.axes.evictions = vec!["lru".into()];
        let e = spec.expand().unwrap_err().to_string();
        assert!(e.contains("prefix cache") && e.contains("S(D)"), "{e}");
    }

    #[test]
    fn hardware_axis_validates_against_registry() {
        let mut spec = quick_spec();
        spec.axes.hardware = vec!["warp-drive".into()];
        let e = spec.expand().unwrap_err().to_string();
        assert!(e.contains("warp-drive") && e.contains("rtx3090"), "{e}");
        // `with_all_hardware` enumerates at least the built-ins
        let mut spec = quick_spec();
        spec.axes = spec
            .axes
            .with_all_hardware(&crate::perf::hardware::snapshot());
        for n in crate::perf::HardwareSpec::preset_names() {
            assert!(spec.axes.hardware.contains(&n.to_string()), "{n} missing");
        }
        let cfgs = spec.expand().unwrap();
        assert_eq!(cfgs.len(), spec.axes.hardware.len());
    }

    #[test]
    fn unknown_policy_names_rejected_before_running() {
        let mut spec = quick_spec();
        spec.axes.routers = vec!["coin-flip".into()];
        let e = spec.expand().unwrap_err().to_string();
        assert!(e.contains("coin-flip") && e.contains("round-robin"), "{e}");
        let mut spec = quick_spec();
        spec.axes.scheds = vec!["lifo".into()];
        assert!(spec.expand().is_err());
        let mut spec = quick_spec();
        spec.axes.evictions = vec!["random".into()];
        assert!(spec.expand().is_err());
    }

    #[test]
    fn all_policies_axis_enumerates_registry() {
        let registry = crate::policy::snapshot();
        let mut spec = quick_spec();
        spec.axes = spec.axes.with_all_policies(&registry);
        spec.axes.presets = vec!["S(D)+PC".into()];
        let cfgs = spec.expand().unwrap();
        assert_eq!(
            cfgs.len(),
            registry.route_names().len()
                * registry.sched_names().len()
                * registry.evict_names().len()
        );
        // every built-in shows up in at least one point name
        for r in registry.route_names() {
            assert!(
                cfgs.iter().any(|c| c.name.contains(&format!("router={r}"))),
                "router '{r}' missing from grid"
            );
        }
    }

    #[test]
    fn controller_axis_expands_and_validates() {
        let mut spec = quick_spec();
        spec.axes.controllers = vec!["static".into(), "queue-threshold".into()];
        assert_eq!(spec.grid_size(), 2);
        let cfgs = spec.expand().unwrap();
        assert_eq!(cfgs.len(), 2);
        assert_eq!(cfgs[0].name, "S(D)|ctrl=static");
        assert_eq!(cfgs[0].cluster.controller, "static");
        assert_eq!(cfgs[1].name, "S(D)|ctrl=queue-threshold");
        assert_eq!(cfgs[1].cluster.controller, "queue-threshold");
        // unknown controllers are rejected up front with candidates
        let mut spec = quick_spec();
        spec.axes.controllers = vec!["chaos-monkey".into()];
        let e = spec.expand().unwrap_err().to_string();
        assert!(e.contains("chaos-monkey") && e.contains("failure-replay"), "{e}");
        // `with_all_controllers` enumerates the registry
        let registry = crate::policy::snapshot();
        let mut spec = quick_spec();
        spec.axes = spec.axes.with_all_controllers(&registry);
        for name in ["static", "queue-threshold", "failure-replay"] {
            assert!(
                spec.axes.controllers.contains(&name.to_string()),
                "{name} missing"
            );
        }
        let cfgs = spec.expand().unwrap();
        assert_eq!(cfgs.len(), spec.axes.controllers.len());
    }

    #[test]
    fn chaos_axis_expands_validates_and_excludes_controller_axis() {
        let mut spec = quick_spec();
        spec.axes.chaos = vec!["none".into(), "light".into()];
        assert_eq!(spec.grid_size(), 2);
        let cfgs = spec.expand().unwrap();
        assert_eq!(cfgs.len(), 2);
        assert_eq!(cfgs[0].name, "S(D)|chaos=none");
        assert_eq!(cfgs[0].cluster.controller, "chaos");
        assert!(!cfgs[0].cluster.chaos.enabled());
        assert_eq!(cfgs[1].name, "S(D)|chaos=light");
        assert!(cfgs[1].cluster.chaos.enabled());
        // unknown profile names rejected up front with candidates
        let mut spec = quick_spec();
        spec.axes.chaos = vec!["mayhem".into()];
        let e = spec.expand().unwrap_err().to_string();
        assert!(e.contains("mayhem") && e.contains("light"), "{e}");
        // combining with the controller axis is refused, not silently wrong
        let mut spec = quick_spec();
        spec.axes.chaos = vec!["light".into()];
        spec.axes.controllers = vec!["queue-threshold".into()];
        let e = spec.expand().unwrap_err().to_string();
        assert!(e.contains("chaos axis"), "{e}");
    }

    #[test]
    fn chaos_sweep_is_identical_across_worker_counts() {
        let mut spec = quick_spec();
        spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
        spec.axes.chaos = vec!["none".into(), "light".into(), "heavy".into()];
        let cfgs = spec.expand().unwrap();
        assert_eq!(cfgs.len(), 6);
        let solo = run_sweep(&cfgs, 1).unwrap();
        let pool = run_sweep(&cfgs, 8).unwrap();
        for (a, b) in solo.points.iter().zip(&pool.points) {
            assert_eq!(a.name, b.name);
            assert_eq!(
                a.report.to_json().to_string(),
                b.report.to_json().to_string(),
                "chaos point '{}' diverged across worker counts",
                a.name
            );
        }
        // the inert profile reproduces the profile-free point byte-for-byte
        let mut plain = quick_spec();
        plain.axes.presets = vec!["S(D)".into(), "M(D)".into()];
        let plain_cfgs = plain.expand().unwrap();
        let plain_run = run_sweep(&plain_cfgs, 1).unwrap();
        for plain_pt in &plain_run.points {
            let inert_name = format!("{}|chaos=none", plain_pt.name);
            let chaos_pt = solo
                .points
                .iter()
                .find(|p| p.name == inert_name)
                .unwrap_or_else(|| panic!("missing grid point '{inert_name}'"));
            assert_eq!(
                chaos_pt.report.to_json().to_string(),
                plain_pt.report.to_json().to_string(),
                "inert chaos must not perturb '{}'",
                plain_pt.name
            );
        }
    }

    #[test]
    fn controller_points_run_and_static_omits_cluster_keys() {
        let mut spec = quick_spec();
        spec.axes.controllers = vec!["static".into(), "queue-threshold".into()];
        let cfgs = spec.expand().unwrap();
        let outcome = run_sweep(&cfgs, 2).unwrap();
        let summary = summarize(&outcome, None).unwrap();
        let v = sweep_json(&outcome, &summary);
        let points = v.get("points").as_arr().unwrap();
        // static point: no controller/peak keys (byte-stable legacy shape)
        assert!(points[0].get("controller").is_null());
        assert!(points[0].get("report").get("controller").is_null());
        // controlled point: both keys present
        assert_eq!(points[1].get("controller").as_str(), Some("queue-threshold"));
        assert!(points[1].get("peak_instances").as_i64().is_some());
        assert_eq!(
            points[1].get("report").get("controller").as_str(),
            Some("queue-threshold")
        );
    }

    #[test]
    fn sweep_reports_identical_across_worker_counts() {
        let mut spec = quick_spec();
        spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
        spec.axes.rates = vec![8.0, 25.0];
        let cfgs = spec.expand().unwrap();
        let solo = run_sweep(&cfgs, 1).unwrap();
        let pool = run_sweep(&cfgs, 3).unwrap();
        assert_eq!(solo.points.len(), pool.points.len());
        for (a, b) in solo.points.iter().zip(&pool.points) {
            assert_eq!(a.name, b.name, "slot order must follow expansion");
            assert_eq!(
                a.report.to_json().to_string(),
                b.report.to_json().to_string(),
                "point '{}' diverged across worker counts",
                a.name
            );
            assert_eq!(a.summary.steps, b.summary.steps);
        }
    }

    #[test]
    fn summary_ranks_and_deltas() {
        let mut spec = quick_spec();
        spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
        let cfgs = spec.expand().unwrap();
        let outcome = run_sweep(&cfgs, 2).unwrap();
        let summary = summarize(&outcome, None).unwrap();
        assert_eq!(summary.baseline, "S(D)");
        assert_eq!(summary.extremes.len(), METRICS.len());
        for e in &summary.extremes {
            let m = METRICS.iter().find(|m| m.key == e.metric).unwrap();
            if m.higher_is_better {
                assert!(e.best >= e.worst, "{}: {} < {}", e.metric, e.best, e.worst);
            } else {
                assert!(e.best <= e.worst, "{}: {} > {}", e.metric, e.best, e.worst);
            }
        }
        assert_eq!(summary.deltas.len(), 1);
        assert_eq!(summary.deltas[0].config, "M(D)");
        // JSON + table render without panicking and carry the points
        let v = sweep_json(&outcome, &summary);
        assert_eq!(v.get("points").as_arr().unwrap().len(), 2);
        let table = render_table(&outcome, &summary).render();
        assert!(table.contains("M(D)"));
        // unknown baseline is an error
        assert!(summarize(&outcome, Some("nope")).is_err());
    }
}
