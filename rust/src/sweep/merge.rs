//! Shard-result merging (DESIGN.md §13): fold the result files of a
//! partitioned sweep back into one aggregate report.
//!
//! The determinism argument is compositional:
//!
//! 1. Per-point records are byte-identical at any worker count
//!    ([`run_sweep`](super::run_sweep)'s contract) and are serialized by the shared
//!    [`point_json`](super::point_json) in both the single-process path
//!    and every shard file.
//! 2. The partition is a deterministic function of the manifest
//!    ([`shard_point_indices`](super::shard_point_indices)), so
//!    reassembling shards in grid order reproduces the single-process
//!    point array element-for-element.
//! 3. The comparative summary is recomputed from those records through
//!    the same [`summarize_values`] core both paths share, and finite
//!    floats survive the JSON file round trip bit-exactly (shortest
//!    round-trip serialization), so the summary is byte-identical too.
//!
//! Therefore `merge(shards of any partition) == run_manifest(...)`,
//! byte for byte — which the integration suite asserts for N ∈ {1,2,7}.
//!
//! Every fold is guarded: shards must carry this manifest's content
//! hash, agree on the partition, cover every shard index exactly once,
//! and pass per-slice validation (names, order, slice hash) before any
//! aggregate is produced.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use crate::metrics::headline_from_json;
use crate::util::bench::Table;
use crate::util::json::Value;

use super::shard::{run_shard, ShardResult};
use super::{summarize_values, summary_json, ExperimentManifest, METRICS};

/// Format tag stamped on merged aggregates.
pub const AGGREGATE_FORMAT: &str = "sweep-aggregate-v1";

/// Fold shard results into the aggregate report.
///
/// `results` must hold exactly one result for every shard of one
/// partition of `m` — any gap, duplicate, foreign manifest, or tampered
/// slice is a hard error naming the offending shard.
pub fn merge(
    m: &ExperimentManifest,
    results: &[ShardResult],
) -> anyhow::Result<Value> {
    anyhow::ensure!(!results.is_empty(), "no shard results to merge");
    let shards = results[0].shards;
    for r in results {
        if r.shards != shards {
            anyhow::bail!(
                "cannot merge shard results from different partitions \
                 (found both /{shards} and /{} result files)",
                r.shards
            );
        }
    }
    let mut by_shard: Vec<Option<&ShardResult>> = vec![None; shards];
    for r in results {
        if r.shard >= shards {
            anyhow::bail!(
                "shard result has index {} but the partition is {shards}-way",
                r.shard
            );
        }
        if by_shard[r.shard].is_some() {
            anyhow::bail!(
                "two shard results claim shard {}/{shards}",
                r.shard + 1
            );
        }
        by_shard[r.shard] = Some(r);
    }
    let missing: Vec<String> = by_shard
        .iter()
        .enumerate()
        .filter(|(_, r)| r.is_none())
        .map(|(i, _)| format!("{}/{shards}", i + 1))
        .collect();
    if !missing.is_empty() {
        anyhow::bail!(
            "incomplete partition: missing shard result(s) {} — run the \
             missing shard(s) or resume with --out-dir",
            missing.join(", ")
        );
    }

    let grid = m.spec.expand()?;
    let grid_names: Vec<String> = grid.iter().map(|c| c.name.clone()).collect();
    let manifest_hash = m.hash();
    let replication = m.replication.max(1);
    let mut points: Vec<Option<&Value>> = vec![None; grid.len()];
    for r in by_shard.iter().flatten() {
        r.validate_against(&manifest_hash, replication, &grid_names)?;
        for (i, p) in &r.points {
            points[*i] = Some(p);
        }
    }
    // Validation guarantees coverage (each shard holds exactly its slice,
    // slices partition the grid); this is the belt-and-braces recheck.
    let holes: Vec<usize> = points
        .iter()
        .enumerate()
        .filter(|(_, p)| p.is_none())
        .map(|(i, _)| i)
        .collect();
    if !holes.is_empty() {
        anyhow::bail!("merged shards left grid indices {holes:?} uncovered");
    }
    let points: Vec<Value> =
        points.into_iter().flatten().cloned().collect();

    let values: Vec<(String, Vec<f64>)> = points
        .iter()
        .map(|p| Ok((point_name(p).to_string(), point_metric_values(p, replication)?)))
        .collect::<anyhow::Result<_>>()?;
    let summary = summarize_values(&values, m.spec.baseline.as_deref())?;

    let mut fields = vec![
        ("format", Value::str(AGGREGATE_FORMAT)),
        ("manifest_hash", Value::str(manifest_hash)),
        ("points", Value::arr(points)),
        ("summary", summary_json(&summary)),
    ];
    if replication > 1 {
        fields.push(("replication", Value::int(replication as i64)));
    }
    Ok(Value::obj(fields))
}

fn point_name(p: &Value) -> &str {
    p.get("name").as_str().unwrap_or("?")
}

/// METRICS-ordered headline values for one merged point record. Under
/// replication the summary ranks the per-point replicate **means**;
/// without it, the representative report's headline metrics directly
/// (bit-equal to what the in-process extractors produced).
fn point_metric_values(p: &Value, replication: usize) -> anyhow::Result<Vec<f64>> {
    METRICS
        .iter()
        .map(|m| {
            let v = if replication > 1 {
                p.get("replication")
                    .get("metrics")
                    .get(m.key)
                    .get("mean")
                    .as_f64()
            } else {
                headline_from_json(p.get("report"), m.key)
            };
            v.ok_or_else(|| {
                anyhow::anyhow!(
                    "merged point '{}' is missing metric '{}'",
                    point_name(p),
                    m.key
                )
            })
        })
        .collect()
}

/// Load shard result files and merge them.
pub fn merge_files(
    m: &ExperimentManifest,
    paths: &[PathBuf],
) -> anyhow::Result<Value> {
    let results = paths
        .iter()
        .map(|p| ShardResult::load(p))
        .collect::<anyhow::Result<Vec<_>>>()?;
    merge(m, &results)
}

/// Shard result files (`shard-*.json`) under `dir`, in deterministic
/// (name-sorted) order.
pub fn find_shard_files(dir: &Path) -> anyhow::Result<Vec<PathBuf>> {
    let mut names = BTreeSet::new();
    let entries = std::fs::read_dir(dir).map_err(|e| {
        anyhow::anyhow!("reading shard directory {}: {e}", dir.display())
    })?;
    for entry in entries {
        let entry = entry.map_err(|e| {
            anyhow::anyhow!("reading shard directory {}: {e}", dir.display())
        })?;
        if let Some(name) = entry.file_name().to_str() {
            if name.starts_with("shard-") && name.ends_with(".json") {
                names.insert(name.to_string());
            }
        }
    }
    Ok(names.into_iter().map(|n| dir.join(n)).collect())
}

/// The single-process reference run: execute the whole manifest as one
/// shard of a 1-way partition and merge it. Every distributed run of the
/// same manifest must reproduce this output byte-for-byte.
pub fn run_manifest(
    m: &ExperimentManifest,
    threads: usize,
) -> anyhow::Result<Value> {
    let result = run_shard(m, 0, 1, threads)?;
    merge(m, &[result])
}

/// Render a merged aggregate as the sweep's per-point table. Under
/// replication a `±95%` column (half-width of the CI on mean tok/s over
/// the replicates) is added.
pub fn render_aggregate_table(aggregate: &Value) -> Table {
    let replicated = aggregate.get("replication").as_i64().unwrap_or(1) > 1;
    let mut headers = vec![
        "config", "finished", "TTFT ms", "TPOT ms", "ITL ms", "tok/s",
    ];
    if replicated {
        headers.push("±95% tok/s");
    }
    headers.push("Δ tok/s %");
    let mut t = Table::new(&headers);
    let baseline = aggregate.get("summary").get("baseline").as_str().unwrap_or("");
    let deltas = aggregate.get("summary").get("deltas");
    let empty: Vec<Value> = vec![];
    for p in aggregate.get("points").as_arr().unwrap_or(&empty) {
        let name = point_name(p).to_string();
        let report = p.get("report");
        let ms = |key: &str| {
            report
                .get(key)
                .get("mean")
                .as_f64()
                .map(|v| format!("{:.3}", v / 1e6))
                .unwrap_or_default()
        };
        let tps = if replicated {
            p.get("replication")
                .get("metrics")
                .get("throughput_tps")
                .get("mean")
                .as_f64()
        } else {
            report.get("throughput_tps").as_f64()
        };
        let delta = if name == baseline {
            "base".to_string()
        } else {
            deltas
                .as_arr()
                .and_then(|ds| {
                    ds.iter().find(|d| d.get("config").as_str() == Some(&name))
                })
                .and_then(|d| {
                    d.get("pct_vs_baseline").get("throughput_tps").as_f64()
                })
                .map(|v| format!("{v:+.1}"))
                .unwrap_or_default()
        };
        let mut row = vec![
            name,
            report
                .get("num_finished")
                .as_i64()
                .map(|v| v.to_string())
                .unwrap_or_default(),
            ms("ttft_ns"),
            ms("tpot_ns"),
            ms("itl_ns"),
            tps.map(|v| format!("{v:.1}")).unwrap_or_default(),
        ];
        if replicated {
            row.push(
                p.get("replication")
                    .get("metrics")
                    .get("throughput_tps")
                    .get("ci95")
                    .as_f64()
                    .map(|v| format!("{v:.2}"))
                    .unwrap_or_default(),
            );
        }
        row.push(delta);
        t.row(&row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::{
        run_sweep, summarize, sweep_json, shard::run_all_shards, SweepSpec,
    };

    fn tiny_manifest() -> ExperimentManifest {
        let mut spec = SweepSpec {
            num_requests: 8,
            quick: true,
            ..SweepSpec::default()
        };
        spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
        spec.axes.rates = vec![6.0];
        ExperimentManifest::new(spec)
    }

    #[test]
    fn two_shard_merge_matches_single_process() {
        let m = tiny_manifest();
        let single = run_manifest(&m, 2).unwrap();
        let a = run_shard(&m, 0, 2, 1).unwrap();
        let b = run_shard(&m, 1, 2, 1).unwrap();
        // shard order handed to merge must not matter
        let merged = merge(&m, &[b, a]).unwrap();
        assert_eq!(merged.to_string(), single.to_string());
        assert_eq!(merged.get("format").as_str(), Some(AGGREGATE_FORMAT));
        assert_eq!(
            merged.get("manifest_hash").as_str(),
            Some(m.hash().as_str())
        );
    }

    #[test]
    fn aggregate_sections_match_plain_sweep_json_at_r1() {
        let m = tiny_manifest();
        let aggregate = run_manifest(&m, 2).unwrap();
        let cfgs = m.spec.expand().unwrap();
        let outcome = run_sweep(&cfgs, 2).unwrap();
        let summary = summarize(&outcome, None).unwrap();
        let plain = sweep_json(&outcome, &summary);
        assert_eq!(
            aggregate.get("points").to_string(),
            plain.get("points").to_string(),
            "R=1 aggregate points must be byte-identical to sweep_json"
        );
        assert_eq!(
            aggregate.get("summary").to_string(),
            plain.get("summary").to_string(),
            "R=1 aggregate summary must be byte-identical to sweep_json"
        );
        assert!(aggregate.get("replication").is_null(), "no R key at R=1");
    }

    #[test]
    fn merge_rejects_foreign_partial_and_duplicate_shards() {
        let m = tiny_manifest();
        let a = run_shard(&m, 0, 2, 1).unwrap();
        let b = run_shard(&m, 1, 2, 1).unwrap();
        // foreign manifest
        let mut other = tiny_manifest();
        other.spec.seed ^= 1;
        let e = merge(&other, &[a.clone(), b.clone()])
            .unwrap_err()
            .to_string();
        assert!(e.contains("different manifest"), "{e}");
        // missing shard
        let e = merge(&m, &[a.clone()]).unwrap_err().to_string();
        assert!(e.contains("missing shard") && e.contains("2/2"), "{e}");
        // duplicate shard
        let e = merge(&m, &[a.clone(), a.clone()]).unwrap_err().to_string();
        assert!(e.contains("claim shard"), "{e}");
        // mixed partitions
        let whole = run_shard(&m, 0, 1, 1).unwrap();
        let e = merge(&m, &[a, whole]).unwrap_err().to_string();
        assert!(e.contains("different partitions"), "{e}");
        assert!(merge(&m, &[]).is_err());
    }

    #[test]
    fn file_roundtrip_and_dir_discovery_preserve_bytes() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("target/test-sweep-shards/unit-merge");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let m = tiny_manifest();
        let single = run_manifest(&m, 2).unwrap();
        run_all_shards(&m, 2, 1, &dir, false).unwrap();
        let files = find_shard_files(&dir).unwrap();
        assert_eq!(files.len(), 2);
        let merged = merge_files(&m, &files).unwrap();
        assert_eq!(
            merged.to_string(),
            single.to_string(),
            "file round trip must not perturb a single byte"
        );
        // a truncated file is a load error carrying the path
        std::fs::write(&files[0], "{\"format\":\"shard-result-v1\",").unwrap();
        let e = merge_files(&m, &files).unwrap_err().to_string();
        assert!(e.contains("shard-0001"), "{e}");
    }

    #[test]
    fn table_renders_with_and_without_replication() {
        let m = tiny_manifest();
        let aggregate = run_manifest(&m, 2).unwrap();
        let plain = render_aggregate_table(&aggregate).render();
        assert!(plain.contains("S(D)|rate=6") && !plain.contains("±95%"));
        let mut rm = tiny_manifest();
        rm.replication = 2;
        let replicated = run_manifest(&rm, 4).unwrap();
        assert_eq!(replicated.get("replication").as_i64(), Some(2));
        let table = render_aggregate_table(&replicated).render();
        assert!(table.contains("±95%"), "{table}");
    }
}
