//! Shard execution for distributed sweeps (DESIGN.md §13).
//!
//! A shard is one independently runnable slice of a manifest's expanded
//! grid: shard `i` of `N` owns the round-robin indices
//! [`shard_point_indices`] assigns it. [`run_shard`] expands the grid,
//! runs the owned points (each replicated `R` times with
//! [`replicate_seed`]-derived seeds), and produces a [`ShardResult`]
//! whose `shard-result-v1` file embeds:
//!
//! * the **manifest hash** — proves which experiment produced it, and
//! * the **slice hash** — proves the file holds exactly the points this
//!   partition assigns, in order, untampered.
//!
//! Per-point records are serialized with the same [`point_json`] the
//! single-process sweep uses, so the merge step can reassemble the
//! single-process aggregate byte-for-byte. Replicate 0 of a point *is*
//! the representative record (its seed is the manifest seed), which is
//! what keeps `replication = 1` output byte-identical to a plain sweep.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Value};
use crate::util::stats::SampleSet;

use super::manifest::{
    replicate_seed, shard_point_indices, slice_hash, ExperimentManifest,
};
use super::{point_json, run_sweep, SweepPoint, METRICS};

/// Format tag required in a shard result's `"format"` key.
pub const SHARD_FORMAT: &str = "shard-result-v1";

/// Reservoir capacity for per-metric replication statistics. Replicate
/// counts are tiny today, but Monte Carlo manifests may push R into the
/// millions — percentile memory stays bounded here while mean/std/CI
/// remain exact (Welford).
const REPLICATION_RESERVOIR_CAP: usize = 4096;

/// One shard's completed slice of a manifest grid.
#[derive(Debug, Clone)]
pub struct ShardResult {
    /// [`ExperimentManifest::hash`] of the producing manifest.
    pub manifest_hash: String,
    /// 0-based shard index.
    pub shard: usize,
    /// Total shards in the partition this result was produced under.
    pub shards: usize,
    /// Replicates per grid point the producer ran.
    pub replication: usize,
    /// [`slice_hash`] over the owned point names.
    pub slice_hash: String,
    /// `(global grid index, per-point record)` in ascending index order.
    /// The record is exactly [`point_json`] output, plus a `replication`
    /// statistics key when `replication > 1`.
    pub points: Vec<(usize, Value)>,
}

/// Run one shard of the manifest on `threads` workers.
///
/// All `R` replicates of a grid point run inside the shard that owns the
/// point (replicates are never split across shards), so replication
/// statistics are computed exactly once, by one producer, from exact
/// Welford accumulators — nothing approximate needs merging later.
pub fn run_shard(
    m: &ExperimentManifest,
    shard: usize,
    shards: usize,
    threads: usize,
) -> anyhow::Result<ShardResult> {
    anyhow::ensure!(shards >= 1, "shard count must be >= 1");
    anyhow::ensure!(
        shard < shards,
        "shard index {shard} out of range for {shards} shards (0-based)"
    );
    let grid = m.spec.expand()?;
    let indices = shard_point_indices(grid.len(), shard, shards);
    let replication = m.replication.max(1);
    let manifest_hash = m.hash();
    let names: Vec<String> =
        indices.iter().map(|&i| grid[i].name.clone()).collect();
    let slice = slice_hash(&manifest_hash, shard, shards, &names);

    // More shards than grid points: the surplus shards legitimately own
    // nothing and emit an empty (but still hash-verified) result.
    if indices.is_empty() {
        return Ok(ShardResult {
            manifest_hash,
            shard,
            shards,
            replication,
            slice_hash: slice,
            points: vec![],
        });
    }

    let mut cfgs = Vec::with_capacity(indices.len() * replication);
    for &i in &indices {
        for rep in 0..replication {
            let mut cfg = grid[i].clone();
            let seed = replicate_seed(m.spec.seed, rep);
            cfg.seed = seed;
            cfg.workload.seed = seed;
            cfgs.push(cfg);
        }
    }
    let outcome = run_sweep(&cfgs, threads)?;

    let mut points = Vec::with_capacity(indices.len());
    for (k, &gi) in indices.iter().enumerate() {
        let group = &outcome.points[k * replication..(k + 1) * replication];
        // Replicate 0 ran on the manifest seed, so its record is the
        // same bytes a replication-free sweep would emit for this point.
        let mut point = point_json(&group[0]);
        if replication > 1 {
            if let Value::Obj(map) = &mut point {
                map.insert("replication".to_string(), replication_json(group));
            }
        }
        points.push((gi, point));
    }
    Ok(ShardResult {
        manifest_hash,
        shard,
        shards,
        replication,
        slice_hash: slice,
        points,
    })
}

/// Per-metric statistics over one point's replicates: exact mean/std/CI
/// from the Welford accumulator, min/max/median through the bounded
/// reservoir. `std` is the Bessel-corrected sample deviation; `ci95` is
/// the normal-approximation half-width on the mean.
fn replication_json(group: &[SweepPoint]) -> Value {
    let mut metrics = Vec::with_capacity(METRICS.len());
    for m in METRICS {
        let mut set = SampleSet::new(REPLICATION_RESERVOIR_CAP);
        for p in group {
            set.push((m.extract)(&p.report));
        }
        let s = set.summary();
        let o = set.online();
        metrics.push((
            m.key,
            Value::obj(vec![
                ("ci95", Value::float(o.ci95_half_width())),
                ("max", Value::float(s.max)),
                ("mean", Value::float(o.mean())),
                ("min", Value::float(s.min)),
                ("p50", Value::float(s.p50)),
                ("std", Value::float(o.std_sample())),
            ]),
        ));
    }
    Value::obj(vec![
        ("metrics", Value::obj(metrics)),
        ("r", Value::int(group.len() as i64)),
    ])
}

impl ShardResult {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("format", Value::str(SHARD_FORMAT)),
            ("manifest_hash", Value::str(self.manifest_hash.clone())),
            (
                "points",
                Value::arr(
                    self.points
                        .iter()
                        .map(|(i, p)| {
                            Value::obj(vec![
                                ("index", Value::int(*i as i64)),
                                ("point", p.clone()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("replication", Value::int(self.replication as i64)),
            ("shard", Value::int(self.shard as i64)),
            ("shards", Value::int(self.shards as i64)),
            ("slice_hash", Value::str(self.slice_hash.clone())),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<ShardResult> {
        let format = v.get("format").as_str().ok_or_else(|| {
            anyhow::anyhow!(
                "shard result is missing the required \"format\" key \
                 (expected \"{SHARD_FORMAT}\")"
            )
        })?;
        if format != SHARD_FORMAT {
            anyhow::bail!(
                "unsupported shard-result format '{format}' \
                 (this build reads '{SHARD_FORMAT}')"
            );
        }
        let points_v = v.get("points").as_arr().ok_or_else(|| {
            anyhow::anyhow!("shard result \"points\" must be an array")
        })?;
        let mut points = Vec::with_capacity(points_v.len());
        for item in points_v {
            let idx = item.get("index").as_u64().ok_or_else(|| {
                anyhow::anyhow!(
                    "shard result point entries need an integer \"index\""
                )
            })? as usize;
            let point = item.get("point");
            if point.get("name").as_str().is_none() {
                anyhow::bail!(
                    "shard result point at grid index {idx} has no \"name\""
                );
            }
            points.push((idx, point.clone()));
        }
        Ok(ShardResult {
            manifest_hash: req_str(v, "manifest_hash")?,
            shard: req_count(v, "shard")?,
            shards: req_count(v, "shards")?,
            replication: req_count(v, "replication")?,
            slice_hash: req_str(v, "slice_hash")?,
            points,
        })
    }

    /// Load a shard result file; parse and shape errors carry the path.
    pub fn load(path: &Path) -> anyhow::Result<ShardResult> {
        let v = json::load_file(path)?;
        ShardResult::from_json(&v)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Pretty-write (creates parent dirs).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        json::save_file(path, &self.to_json())
    }

    /// Prove this result belongs to the manifest hashing to
    /// `manifest_hash`, was run at the expected replication, and holds
    /// exactly the slice its partition coordinates assign — names, order,
    /// and slice hash all rechecked.
    pub fn validate_against(
        &self,
        manifest_hash: &str,
        replication: usize,
        grid_names: &[String],
    ) -> anyhow::Result<()> {
        let id = format!("shard {}/{}", self.shard + 1, self.shards);
        if self.manifest_hash != manifest_hash {
            anyhow::bail!(
                "{id} was produced by a different manifest (result has \
                 manifest hash {}, this manifest hashes to {manifest_hash}); \
                 re-run the shard from this manifest, or merge with the \
                 manifest that produced it",
                self.manifest_hash
            );
        }
        if self.replication != replication {
            anyhow::bail!(
                "{id} ran {} replicate(s) per point but the manifest asks \
                 for {replication}",
                self.replication
            );
        }
        if self.shard >= self.shards {
            anyhow::bail!(
                "{id} has an out-of-range shard index (expected 0..{})",
                self.shards
            );
        }
        let expected =
            shard_point_indices(grid_names.len(), self.shard, self.shards);
        let got: Vec<usize> = self.points.iter().map(|(i, _)| *i).collect();
        if got != expected {
            anyhow::bail!(
                "{id} covers grid indices {got:?} but this partition \
                 assigns {expected:?}"
            );
        }
        for (i, p) in &self.points {
            let name = p.get("name").as_str().unwrap_or("");
            if name != grid_names[*i] {
                anyhow::bail!(
                    "{id}: point at grid index {i} is '{name}' but the \
                     manifest grid expands to '{}' there",
                    grid_names[*i]
                );
            }
        }
        let names: Vec<String> =
            expected.iter().map(|&i| grid_names[i].clone()).collect();
        let want = slice_hash(manifest_hash, self.shard, self.shards, &names);
        if self.slice_hash != want {
            anyhow::bail!(
                "{id} slice hash mismatch (file records {}, recomputed \
                 {want}): the result file is corrupt or was edited",
                self.slice_hash
            );
        }
        Ok(())
    }
}

fn req_str(v: &Value, key: &str) -> anyhow::Result<String> {
    v.get(key).as_str().map(str::to_string).ok_or_else(|| {
        anyhow::anyhow!("shard result is missing the string key \"{key}\"")
    })
}

fn req_count(v: &Value, key: &str) -> anyhow::Result<usize> {
    v.get(key).as_u64().map(|u| u as usize).ok_or_else(|| {
        anyhow::anyhow!(
            "shard result is missing the non-negative integer key \"{key}\""
        )
    })
}

// ---------------------------------------------------------------------------
// Resumable file driver
// ---------------------------------------------------------------------------

/// Canonical file name for shard `shard` (0-based) of `shards` inside an
/// output directory.
pub fn shard_file_name(shard: usize, shards: usize) -> String {
    format!("shard-{:04}-of-{:04}.json", shard + 1, shards)
}

/// What the resumable driver did for one shard.
#[derive(Debug, Clone, PartialEq)]
pub enum ShardOutcome {
    /// Ran the shard and wrote its result file.
    Completed(PathBuf),
    /// A valid result for this exact manifest + partition already existed
    /// — skipped without running anything (the resume path).
    Skipped(PathBuf),
}

impl ShardOutcome {
    pub fn path(&self) -> &Path {
        match self {
            ShardOutcome::Completed(p) | ShardOutcome::Skipped(p) => p,
        }
    }
}

/// Run shard `shard`/`shards` and write its result under `dir`, unless a
/// reusable result file is already there.
///
/// "Reusable" is proven, not assumed: the existing file must parse, carry
/// this manifest's hash and these partition coordinates, and pass the
/// full slice validation. Anything else — corrupt JSON, a different
/// manifest, a different shard count — is reported on stderr and the
/// shard is re-run, overwriting the stale file. `force` re-runs
/// unconditionally.
pub fn run_shard_to_file(
    m: &ExperimentManifest,
    shard: usize,
    shards: usize,
    threads: usize,
    dir: &Path,
    force: bool,
) -> anyhow::Result<ShardOutcome> {
    let path = dir.join(shard_file_name(shard, shards));
    if !force && path.exists() {
        match reusable(m, shard, shards, &path) {
            Ok(()) => return Ok(ShardOutcome::Skipped(path)),
            Err(e) => eprintln!(
                "warning: re-running shard {}/{shards}: existing {} is not \
                 reusable: {e}",
                shard + 1,
                path.display()
            ),
        }
    }
    let result = run_shard(m, shard, shards, threads)?;
    result.save(&path)?;
    Ok(ShardOutcome::Completed(path))
}

fn reusable(
    m: &ExperimentManifest,
    shard: usize,
    shards: usize,
    path: &Path,
) -> anyhow::Result<()> {
    let existing = ShardResult::load(path)?;
    if existing.shard != shard || existing.shards != shards {
        anyhow::bail!(
            "file is shard {}/{} but this run needs shard {}/{shards}",
            existing.shard + 1,
            existing.shards,
            shard + 1
        );
    }
    let grid = m.spec.expand()?;
    let names: Vec<String> = grid.iter().map(|c| c.name.clone()).collect();
    existing.validate_against(&m.hash(), m.replication.max(1), &names)
}

/// Run (or resume) every shard of an `shards`-way partition into `dir`,
/// in index order. Returns one outcome per shard; count the
/// [`ShardOutcome::Skipped`] entries to see how much a resume saved.
pub fn run_all_shards(
    m: &ExperimentManifest,
    shards: usize,
    threads: usize,
    dir: &Path,
    force: bool,
) -> anyhow::Result<Vec<ShardOutcome>> {
    anyhow::ensure!(shards >= 1, "shard count must be >= 1");
    let mut outcomes = Vec::with_capacity(shards);
    for shard in 0..shards {
        outcomes.push(run_shard_to_file(m, shard, shards, threads, dir, force)?);
    }
    Ok(outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::SweepSpec;

    fn tiny_manifest() -> ExperimentManifest {
        let mut spec = SweepSpec {
            num_requests: 8,
            quick: true,
            ..SweepSpec::default()
        };
        spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
        ExperimentManifest::new(spec)
    }

    fn synthetic_result() -> ShardResult {
        let point = |name: &str| {
            Value::obj(vec![
                ("name", Value::str(name)),
                ("steps", Value::int(3)),
            ])
        };
        let names = vec!["S(D)".to_string()];
        ShardResult {
            manifest_hash: "aa".repeat(8),
            shard: 0,
            shards: 2,
            replication: 1,
            slice_hash: slice_hash(&"aa".repeat(8), 0, 2, &names),
            points: vec![(0, point("S(D)"))],
        }
    }

    #[test]
    fn shard_result_roundtrips_through_json() {
        let r = synthetic_result();
        let back = ShardResult::from_json(&r.to_json()).unwrap();
        assert_eq!(back.to_json().to_string(), r.to_json().to_string());
        assert_eq!(back.shard, 0);
        assert_eq!(back.shards, 2);
        assert_eq!(back.points.len(), 1);
    }

    #[test]
    fn from_json_rejects_malformed_results() {
        let cases = [
            (r#"{"shard":0}"#, "format"),
            (r#"{"format":"shard-result-v9"}"#, "shard-result-v1"),
            (
                r#"{"format":"shard-result-v1","points":3}"#,
                "array",
            ),
            (
                r#"{"format":"shard-result-v1","points":[{"index":0,"point":{}}]}"#,
                "name",
            ),
            (
                r#"{"format":"shard-result-v1","points":[]}"#,
                "manifest_hash",
            ),
        ];
        for (src, needle) in cases {
            let v = json::parse(src).unwrap();
            let e = ShardResult::from_json(&v).unwrap_err().to_string();
            assert!(e.contains(needle), "{src}: {e}");
        }
    }

    #[test]
    fn validation_catches_foreign_and_tampered_results() {
        let names = vec!["S(D)".to_string(), "M(D)".to_string()];
        let hash = "aa".repeat(8);
        let mut r = synthetic_result();
        r.slice_hash = slice_hash(&hash, 0, 2, &["S(D)".to_string()]);
        r.validate_against(&hash, 1, &names).unwrap();
        // wrong manifest
        let e = r.validate_against("bb", 1, &names).unwrap_err().to_string();
        assert!(e.contains("different manifest"), "{e}");
        // wrong replication
        let e = r.validate_against(&hash, 3, &names).unwrap_err().to_string();
        assert!(e.contains("replicate"), "{e}");
        // tampered point name
        let mut bad = r.clone();
        bad.points[0].1 = Value::obj(vec![("name", Value::str("M(D)"))]);
        let e = bad
            .validate_against(&hash, 1, &names)
            .unwrap_err()
            .to_string();
        assert!(e.contains("expands to"), "{e}");
        // tampered slice hash
        let mut bad = r.clone();
        bad.slice_hash = "0".repeat(16);
        let e = bad
            .validate_against(&hash, 1, &names)
            .unwrap_err()
            .to_string();
        assert!(e.contains("slice hash"), "{e}");
        // wrong index set
        let mut bad = r.clone();
        bad.points[0].0 = 1;
        let e = bad
            .validate_against(&hash, 1, &names)
            .unwrap_err()
            .to_string();
        assert!(e.contains("assigns"), "{e}");
    }

    #[test]
    fn run_shard_single_partition_matches_plain_sweep() {
        let m = tiny_manifest();
        let r = run_shard(&m, 0, 1, 2).unwrap();
        assert_eq!(r.points.len(), 2);
        let cfgs = m.spec.expand().unwrap();
        let plain = run_sweep(&cfgs, 1).unwrap();
        for ((gi, point), p) in r.points.iter().zip(&plain.points) {
            assert_eq!(
                point.to_string(),
                point_json(p).to_string(),
                "R=1 shard point {gi} must byte-match the plain sweep"
            );
        }
        // empty slice: more shards than points
        let empty = run_shard(&m, 2, 3, 1).unwrap();
        assert!(empty.points.is_empty());
        assert!(run_shard(&m, 3, 3, 1).is_err(), "index out of range");
    }

    #[test]
    fn replication_attaches_stats_and_keeps_representative() {
        let mut m = tiny_manifest();
        m.replication = 3;
        let r = run_shard(&m, 0, 1, 4).unwrap();
        let single = {
            let mut one = tiny_manifest();
            one.replication = 1;
            run_shard(&one, 0, 1, 1).unwrap()
        };
        for ((_, rep_pt), (_, single_pt)) in r.points.iter().zip(&single.points)
        {
            let stats = rep_pt.get("replication");
            assert_eq!(stats.get("r").as_i64(), Some(3));
            let tps = stats.get("metrics").get("throughput_tps");
            assert!(tps.get("mean").as_f64().is_some());
            assert!(tps.get("std").as_f64().unwrap() >= 0.0);
            assert!(tps.get("ci95").as_f64().unwrap() >= 0.0);
            assert!(
                tps.get("min").as_f64().unwrap()
                    <= tps.get("max").as_f64().unwrap()
            );
            // stripping the replication key leaves the R=1 bytes
            let mut stripped = rep_pt.clone();
            if let Value::Obj(map) = &mut stripped {
                map.remove("replication");
            }
            assert_eq!(
                stripped.to_string(),
                single_pt.to_string(),
                "replicate 0 must be the R=1 representative"
            );
        }
    }

    #[test]
    fn file_driver_resumes_and_rejects_stale_files() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("target/test-sweep-shards/unit-driver");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let m = tiny_manifest();
        let first = run_shard_to_file(&m, 0, 2, 1, &dir, false).unwrap();
        assert!(matches!(first, ShardOutcome::Completed(_)));
        let second = run_shard_to_file(&m, 0, 2, 1, &dir, false).unwrap();
        assert!(matches!(second, ShardOutcome::Skipped(_)), "{second:?}");
        // --force re-runs
        let forced = run_shard_to_file(&m, 0, 2, 1, &dir, true).unwrap();
        assert!(matches!(forced, ShardOutcome::Completed(_)));
        // a different manifest refuses to reuse the file and re-runs
        let mut other = tiny_manifest();
        other.spec.seed ^= 7;
        let rerun = run_shard_to_file(&other, 0, 2, 1, &dir, false).unwrap();
        assert!(matches!(rerun, ShardOutcome::Completed(_)));
        // corrupt file: warn + re-run rather than trust it
        std::fs::write(dir.join(shard_file_name(0, 2)), "{oops").unwrap();
        let healed = run_shard_to_file(&other, 0, 2, 1, &dir, false).unwrap();
        assert!(matches!(healed, ShardOutcome::Completed(_)));
        ShardResult::load(&dir.join(shard_file_name(0, 2))).unwrap();
    }
}
