//! Experiment manifests: the on-disk `experiment-manifest-v1` sweep
//! declaration (DESIGN.md §13).
//!
//! A manifest is a serializable [`SweepSpec`] plus two distribution knobs:
//! a **seed-replication** count (`replication`: run every grid point R
//! times with derived seeds and report mean/stddev/CI per metric) and a
//! default **shard** count (`shards`: partition the expanded grid into
//! independently runnable chunks). The same manifest file drives the
//! single-process run, every shard of a distributed run, and the merge
//! step — so equality of the manifest **content hash** is the guard that
//! shard results being folded together actually came from the same
//! experiment.
//!
//! Hashing is formatting-independent: the hash covers the *canonical*
//! compact serialization of the parsed manifest
//! ([`ExperimentManifest::to_json`] orders keys via the codec's BTreeMap
//! and always emits defaults), not the raw file bytes, so re-indenting a
//! manifest does not orphan its shard results.

use std::path::Path;

use crate::config::PerfBackend;
use crate::util::json::{self, Value};

use super::{SweepAxes, SweepSpec};

/// Format tag required in the manifest's `"format"` key.
pub const MANIFEST_FORMAT: &str = "experiment-manifest-v1";

/// Top-level manifest keys (sorted). Anything else is rejected so typos
/// (`"replicas"`, `"shard"`) fail loudly instead of silently defaulting.
const MANIFEST_KEYS: &[&str] = &[
    "axes",
    "baseline",
    "dense_model",
    "format",
    "moe_model",
    "num_requests",
    "quick",
    "replication",
    "seed",
    "shards",
];

/// Axis keys accepted under `"axes"` (sorted), mirroring [`SweepAxes`].
const AXIS_KEYS: &[&str] = &[
    "backends",
    "chaos",
    "controllers",
    "evictions",
    "hardware",
    "presets",
    "rates",
    "routers",
    "scheds",
    "workloads",
];

/// A parsed experiment manifest: the sweep declaration plus the
/// replication and default-shard-count knobs.
#[derive(Debug, Clone)]
pub struct ExperimentManifest {
    pub spec: SweepSpec,
    /// Seed replicates per grid point (>= 1). 1 means "exactly today's
    /// single-run sweep" — byte-identical output, no replication keys.
    pub replication: usize,
    /// Default shard count for distributed runs (>= 1). `--shard i/N`
    /// overrides N at run time without changing the manifest hash's
    /// meaning: the hash covers the declaration, the slice hash covers
    /// the partition actually used.
    pub shards: usize,
}

impl ExperimentManifest {
    /// Wrap a spec with the no-replication, single-shard defaults.
    pub fn new(spec: SweepSpec) -> ExperimentManifest {
        ExperimentManifest {
            spec,
            replication: 1,
            shards: 1,
        }
    }

    /// Canonical serialization. Every field is emitted (except the
    /// optional baseline), so two manifests with equal parsed content
    /// always serialize — and therefore hash — identically.
    pub fn to_json(&self) -> Value {
        let strs =
            |v: &[String]| Value::arr(v.iter().map(Value::str).collect());
        let a = &self.spec.axes;
        let axes = Value::obj(vec![
            (
                "backends",
                Value::arr(
                    a.backends.iter().map(|b| Value::str(b.cli_str())).collect(),
                ),
            ),
            ("chaos", strs(&a.chaos)),
            ("controllers", strs(&a.controllers)),
            ("evictions", strs(&a.evictions)),
            ("hardware", strs(&a.hardware)),
            ("presets", strs(&a.presets)),
            (
                "rates",
                Value::arr(a.rates.iter().map(|r| Value::float(*r)).collect()),
            ),
            ("routers", strs(&a.routers)),
            ("scheds", strs(&a.scheds)),
            ("workloads", strs(&a.workloads)),
        ]);
        let mut fields = vec![
            ("axes", axes),
            ("dense_model", Value::str(self.spec.dense_model.clone())),
            ("format", Value::str(MANIFEST_FORMAT)),
            ("moe_model", Value::str(self.spec.moe_model.clone())),
            ("num_requests", Value::int(self.spec.num_requests as i64)),
            ("quick", Value::Bool(self.spec.quick)),
            ("replication", Value::int(self.replication as i64)),
            // Bit-lossless: u64 seeds round-trip through i64 (and JSON's
            // exact-int path) via the `as` casts on both sides.
            ("seed", Value::int(self.spec.seed as i64)),
            ("shards", Value::int(self.shards as i64)),
        ];
        if let Some(b) = &self.spec.baseline {
            fields.push(("baseline", Value::str(b.clone())));
        }
        Value::obj(fields)
    }

    /// Strict parse: unknown keys and wrong types are candidate-style
    /// errors, missing optional keys fall back to [`SweepSpec::default`]
    /// scalars (axes default to *empty*, i.e. "inherit preset default" —
    /// the manifest must name at least one preset to expand).
    pub fn from_json(v: &Value) -> anyhow::Result<ExperimentManifest> {
        let obj = v
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("manifest must be a JSON object"))?;
        for k in obj.keys() {
            if !MANIFEST_KEYS.contains(&k.as_str()) {
                anyhow::bail!(
                    "unknown manifest key '{k}' (expected one of {MANIFEST_KEYS:?})"
                );
            }
        }
        let format = v.get("format").as_str().ok_or_else(|| {
            anyhow::anyhow!(
                "manifest is missing the required \"format\" key \
                 (expected \"{MANIFEST_FORMAT}\")"
            )
        })?;
        if format != MANIFEST_FORMAT {
            anyhow::bail!(
                "unsupported manifest format '{format}' \
                 (this build reads '{MANIFEST_FORMAT}')"
            );
        }
        let axes = parse_axes(v.get("axes"))?;
        let d = SweepSpec::default();
        let spec = SweepSpec {
            axes,
            dense_model: opt_str(v, "dense_model")?.unwrap_or(d.dense_model),
            moe_model: opt_str(v, "moe_model")?.unwrap_or(d.moe_model),
            num_requests: opt_count(v, "num_requests")?
                .unwrap_or(d.num_requests),
            seed: match v.get("seed") {
                Value::Null => d.seed,
                s => s.as_i64().map(|i| i as u64).ok_or_else(|| {
                    anyhow::anyhow!("manifest \"seed\" must be an integer")
                })?,
            },
            quick: opt_bool(v, "quick")?.unwrap_or(false),
            baseline: opt_str(v, "baseline")?,
        };
        let replication = opt_count(v, "replication")?.unwrap_or(1);
        let shards = opt_count(v, "shards")?.unwrap_or(1);
        if replication == 0 {
            anyhow::bail!("manifest \"replication\" must be >= 1");
        }
        if shards == 0 {
            anyhow::bail!("manifest \"shards\" must be >= 1");
        }
        Ok(ExperimentManifest {
            spec,
            replication,
            shards,
        })
    }

    /// Load + strictly parse a manifest file.
    pub fn load(path: &Path) -> anyhow::Result<ExperimentManifest> {
        let v = json::load_file(path)?;
        ExperimentManifest::from_json(&v)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Pretty-write the canonical form (creates parent dirs).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        json::save_file(path, &self.to_json())
    }

    /// Content hash of the canonical serialization — the identity that
    /// shard results must match to be mergeable.
    pub fn hash(&self) -> String {
        content_hash(&self.to_json().to_string())
    }
}

fn opt_str(v: &Value, key: &str) -> anyhow::Result<Option<String>> {
    match v.get(key) {
        Value::Null => Ok(None),
        Value::Str(s) => Ok(Some(s.clone())),
        _ => anyhow::bail!("manifest \"{key}\" must be a string"),
    }
}

fn opt_bool(v: &Value, key: &str) -> anyhow::Result<Option<bool>> {
    match v.get(key) {
        Value::Null => Ok(None),
        Value::Bool(b) => Ok(Some(*b)),
        _ => anyhow::bail!("manifest \"{key}\" must be true or false"),
    }
}

fn opt_count(v: &Value, key: &str) -> anyhow::Result<Option<usize>> {
    match v.get(key) {
        Value::Null => Ok(None),
        n => n
            .as_u64()
            .map(|u| Some(u as usize))
            .ok_or_else(|| {
                anyhow::anyhow!("manifest \"{key}\" must be a non-negative integer")
            }),
    }
}

fn parse_axes(v: &Value) -> anyhow::Result<SweepAxes> {
    let obj = match v {
        Value::Null => return Ok(SweepAxes::default()),
        Value::Obj(o) => o,
        _ => anyhow::bail!("manifest \"axes\" must be a JSON object"),
    };
    for k in obj.keys() {
        if !AXIS_KEYS.contains(&k.as_str()) {
            anyhow::bail!(
                "unknown manifest axis '{k}' (expected one of {AXIS_KEYS:?})"
            );
        }
    }
    let rates = match v.get("rates") {
        Value::Null => vec![],
        Value::Arr(items) => items
            .iter()
            .map(|it| {
                it.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("manifest axis 'rates' must hold numbers")
                })
            })
            .collect::<anyhow::Result<_>>()?,
        _ => anyhow::bail!("manifest axis 'rates' must be an array of numbers"),
    };
    let backends = match v.get("backends") {
        Value::Null => vec![],
        Value::Arr(items) => items
            .iter()
            .map(|it| {
                it.as_str()
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "manifest axis 'backends' must hold strings \
                             (analytical|cycle|cycle-replay|trace:PATH)"
                        )
                    })
                    .and_then(|s| s.parse::<PerfBackend>())
            })
            .collect::<anyhow::Result<_>>()?,
        _ => {
            anyhow::bail!("manifest axis 'backends' must be an array of strings")
        }
    };
    Ok(SweepAxes {
        presets: str_axis(v, "presets")?,
        hardware: str_axis(v, "hardware")?,
        rates,
        routers: str_axis(v, "routers")?,
        scheds: str_axis(v, "scheds")?,
        evictions: str_axis(v, "evictions")?,
        backends,
        workloads: str_axis(v, "workloads")?,
        controllers: str_axis(v, "controllers")?,
        chaos: str_axis(v, "chaos")?,
    })
}

fn str_axis(v: &Value, key: &str) -> anyhow::Result<Vec<String>> {
    match v.get(key) {
        Value::Null => Ok(vec![]),
        Value::Arr(items) => items
            .iter()
            .map(|it| {
                it.as_str().map(str::to_string).ok_or_else(|| {
                    anyhow::anyhow!("manifest axis '{key}' must hold strings")
                })
            })
            .collect(),
        _ => anyhow::bail!("manifest axis '{key}' must be an array of strings"),
    }
}

// ---------------------------------------------------------------------------
// Hashing, replication seeds, and the shard partition
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit over the text, rendered as 16 lowercase hex digits.
/// Dependency-free and stable across platforms/releases — the whole
/// shard-identity scheme rides on this staying fixed.
pub fn content_hash(text: &str) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

/// Derive the seed for replicate `rep` of a grid point.
///
/// Replicate 0 **is** the manifest seed — that identity is what makes an
/// R=1 manifest run byte-for-byte equal to the plain sweep. Later
/// replicates go through a SplitMix64 finalizer (same constants as
/// [`crate::util::rng`]'s seeding) so nearby replicate indices land on
/// statistically unrelated streams.
pub fn replicate_seed(base: u64, rep: usize) -> u64 {
    if rep == 0 {
        return base;
    }
    let mut z = base ^ (rep as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Global grid indices owned by `shard` (0-based) of `shards`:
/// round-robin `shard, shard+shards, shard+2*shards, ...`. Deterministic,
/// covers every index exactly once across shards, balanced to within one
/// point even when `shards` does not divide the grid.
pub fn shard_point_indices(grid: usize, shard: usize, shards: usize) -> Vec<usize> {
    if shards == 0 || shard >= shards {
        return vec![];
    }
    (shard..grid).step_by(shards).collect()
}

/// Hash of one shard's slice of the expanded grid: manifest identity,
/// partition coordinates, and the owned point names in order. A shard
/// result carries this so the merge can prove the slice it is folding is
/// exactly the slice this partition assigns.
pub fn slice_hash(
    manifest_hash: &str,
    shard: usize,
    shards: usize,
    point_names: &[String],
) -> String {
    let mut text = format!("{manifest_hash}|{shard}/{shards}");
    for name in point_names {
        text.push('\n');
        text.push_str(name);
    }
    content_hash(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample() -> ExperimentManifest {
        let mut spec = SweepSpec {
            num_requests: 12,
            quick: true,
            ..SweepSpec::default()
        };
        spec.axes.presets = vec!["S(D)".into(), "M(D)".into()];
        spec.axes.rates = vec![5.0, 20.0];
        spec.axes.routers = vec!["round-robin".into()];
        ExperimentManifest {
            spec,
            replication: 3,
            shards: 2,
        }
    }

    #[test]
    fn roundtrips_through_json() {
        let m = sample();
        let v = m.to_json();
        let back = ExperimentManifest::from_json(&v).unwrap();
        assert_eq!(back.to_json().to_string(), v.to_string());
        assert_eq!(back.replication, 3);
        assert_eq!(back.shards, 2);
        assert_eq!(back.spec.axes.presets, m.spec.axes.presets);
        assert_eq!(back.spec.axes.rates, m.spec.axes.rates);
        assert_eq!(back.spec.num_requests, 12);
        assert!(back.spec.quick);
        assert_eq!(back.spec.seed, m.spec.seed);
    }

    #[test]
    fn hash_is_formatting_independent() {
        let m = sample();
        // pretty vs compact on-disk forms parse to the same hash
        let pretty = ExperimentManifest::from_json(
            &json::parse(&m.to_json().to_pretty()).unwrap(),
        )
        .unwrap();
        let compact = ExperimentManifest::from_json(
            &json::parse(&m.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(m.hash(), pretty.hash());
        assert_eq!(m.hash(), compact.hash());
        // but any content change moves it
        let mut other = sample();
        other.spec.seed ^= 1;
        assert_ne!(m.hash(), other.hash());
        let mut other = sample();
        other.replication = 4;
        assert_ne!(m.hash(), other.hash());
    }

    #[test]
    fn defaults_fill_missing_scalars() {
        let v = json::parse(
            r#"{"format":"experiment-manifest-v1","axes":{"presets":["S(D)"]}}"#,
        )
        .unwrap();
        let m = ExperimentManifest::from_json(&v).unwrap();
        let d = SweepSpec::default();
        assert_eq!(m.spec.num_requests, d.num_requests);
        assert_eq!(m.spec.seed, d.seed);
        assert_eq!(m.spec.dense_model, d.dense_model);
        assert!(!m.spec.quick);
        assert_eq!(m.replication, 1);
        assert_eq!(m.shards, 1);
        assert!(m.spec.baseline.is_none());
    }

    #[test]
    fn rejects_bad_manifests_with_candidates() {
        let cases = [
            (r#"{"axes":{}}"#, "format"),
            (r#"{"format":"experiment-manifest-v2"}"#, "experiment-manifest-v1"),
            (
                r#"{"format":"experiment-manifest-v1","replicas":3}"#,
                "replication",
            ),
            (
                r#"{"format":"experiment-manifest-v1","axes":{"routes":[]}}"#,
                "routers",
            ),
            (
                r#"{"format":"experiment-manifest-v1","replication":0}"#,
                ">= 1",
            ),
            (
                r#"{"format":"experiment-manifest-v1","shards":0}"#,
                ">= 1",
            ),
            (
                r#"{"format":"experiment-manifest-v1","axes":{"rates":["x"]}}"#,
                "numbers",
            ),
            (
                r#"{"format":"experiment-manifest-v1","axes":{"backends":["warp"]}}"#,
                "analytical",
            ),
            (r#"[1,2]"#, "object"),
        ];
        for (src, needle) in cases {
            let v = json::parse(src).unwrap();
            let e = ExperimentManifest::from_json(&v).unwrap_err().to_string();
            assert!(e.contains(needle), "{src}: {e}");
        }
    }

    #[test]
    fn replicate_seed_zero_is_identity_and_reps_diverge() {
        for base in [0u64, 1, 0xC0FFEE, u64::MAX] {
            assert_eq!(replicate_seed(base, 0), base);
            let mut seen = std::collections::BTreeSet::new();
            for rep in 0..64 {
                assert!(
                    seen.insert(replicate_seed(base, rep)),
                    "replicate seeds collided (base={base}, rep={rep})"
                );
            }
        }
        // deterministic across calls
        assert_eq!(replicate_seed(42, 7), replicate_seed(42, 7));
    }

    #[test]
    fn shard_partition_is_disjoint_covering_and_balanced() {
        for grid in [1usize, 2, 7, 12, 13] {
            for shards in [1usize, 2, 3, 7, 20] {
                let mut all = vec![];
                let mut sizes = vec![];
                for s in 0..shards {
                    let idx = shard_point_indices(grid, s, shards);
                    assert!(idx.windows(2).all(|w| w[0] < w[1]), "ordered");
                    sizes.push(idx.len());
                    all.extend(idx);
                }
                all.sort_unstable();
                assert_eq!(all, (0..grid).collect::<Vec<_>>(),
                    "grid={grid} shards={shards} must cover exactly once");
                let (lo, hi) = (
                    *sizes.iter().min().unwrap(),
                    *sizes.iter().max().unwrap(),
                );
                assert!(hi - lo <= 1, "balanced to within one point");
            }
        }
        // 12 points over 7 shards: the uneven case the suite exercises
        let sizes: Vec<usize> = (0..7)
            .map(|s| shard_point_indices(12, s, 7).len())
            .collect();
        assert_eq!(sizes, vec![2, 2, 2, 2, 2, 1, 1]);
        assert!(shard_point_indices(5, 9, 7).is_empty());
        assert!(shard_point_indices(5, 0, 0).is_empty());
    }

    #[test]
    fn content_and_slice_hashes_are_stable() {
        // pinned values: these are part of the shard-file contract
        assert_eq!(content_hash(""), "cbf29ce484222325");
        assert_eq!(content_hash("a"), "af63dc4c8601ec8c");
        let names = vec!["S(D)".to_string(), "M(D)".to_string()];
        let h1 = slice_hash("abc", 0, 2, &names);
        let h2 = slice_hash("abc", 0, 2, &names);
        assert_eq!(h1, h2);
        assert_ne!(h1, slice_hash("abd", 0, 2, &names));
        assert_ne!(h1, slice_hash("abc", 1, 2, &names));
        let fewer = vec!["S(D)".to_string()];
        assert_ne!(h1, slice_hash("abc", 0, 2, &fewer));
    }

    #[test]
    fn save_and_load_roundtrip() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("target/test-manifest-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.json");
        let m = sample();
        m.save(&path).unwrap();
        let back = ExperimentManifest::load(&path).unwrap();
        assert_eq!(back.hash(), m.hash());
        // load errors carry the path
        std::fs::write(dir.join("bad.json"), "{\"format\":").unwrap();
        let e = ExperimentManifest::load(&dir.join("bad.json"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("bad.json"), "{e}");
    }
}
