//! The operator-level profiler (§II-A): sweeps the AOT operator grid on the
//! PJRT backend, measures per-shape latency, and emits the trace DB the
//! trace-driven performance model consumes.
//!
//! This is the paper's "analyze any model on their own hardware with a
//! single-line command": `llmservingsim profile --model tiny-dense
//! --hardware-tag cpu-pjrt`. Integrating a new backend = pointing the same
//! command at a different PJRT target (DESIGN.md §1 shows the TPU-persona
//! variant); no simulator changes.
//!
//! The profiler also self-validates (§II-A "through validation against real
//! execution"): a leave-one-out interpolation check over the measured grid
//! reports the error a simulator lookup would have had at each profiled
//! point had it not been measured.

use std::path::Path;

use crate::model::OpKind;
use crate::perf::hardware::HardwareBundle;
use crate::perf::trace::TraceDb;
use crate::perf::HardwareSpec;
use crate::util::stats;

use super::{Manifest, Runtime};

/// Profiling options.
#[derive(Debug, Clone)]
pub struct ProfileOptions {
    /// Warmup executions per op (excluded from measurement).
    pub warmup: usize,
    /// Measured repetitions per op; the median is recorded.
    pub reps: usize,
    /// Tag recorded as the trace's hardware name.
    pub hardware_tag: String,
}

impl Default for ProfileOptions {
    fn default() -> Self {
        ProfileOptions {
            warmup: 2,
            reps: 7,
            hardware_tag: "cpu-pjrt".into(),
        }
    }
}

/// Result of profiling one model's grid.
#[derive(Debug)]
pub struct ProfileOutcome {
    pub db: TraceDb,
    pub ops_profiled: usize,
    /// Total profiling wall-clock, ns.
    pub wall_ns: u64,
    /// Leave-one-out self-validation error (percent), per op kind.
    pub loo_error_pct: Vec<(OpKind, f64)>,
}

/// Profile every artifact of `model_name` in the manifest.
pub fn profile_model(
    manifest: &Manifest,
    runtime: &mut Runtime,
    model_name: &str,
    opts: &ProfileOptions,
) -> anyhow::Result<ProfileOutcome> {
    let mm = manifest
        .model(model_name)
        .ok_or_else(|| anyhow::anyhow!("model '{model_name}' not in manifest"))?;
    let mut db = TraceDb::new(&opts.hardware_tag, model_name);
    // simlint: allow(D02) — wall-clock budget for the profiling run itself (real
    // hardware measurement); never feeds simulated time
    let t0 = std::time::Instant::now();

    // Warmup pass: compile + first-execute every artifact (JIT cost must
    // never leak into samples).
    for art in &mm.ops {
        let loaded = runtime.load(art)?;
        for _ in 0..opts.warmup.max(1) {
            loaded.execute_timed()?;
        }
    }
    // Per-op measurement: `reps` warm executions; the 25th percentile is
    // recorded. On a shared machine the noise is one-sided (preemption
    // spikes), and p25-of-N matches the expectation of the min-of-2
    // estimator that real per-invocation measurements (ground truth, and
    // any real engine's step timing) experience.
    let mut samples: Vec<Vec<f64>> = vec![Vec::with_capacity(opts.reps); mm.ops.len()];
    for (i, art) in mm.ops.iter().enumerate() {
        let loaded = runtime.load(art)?;
        for _ in 0..opts.reps.max(1) {
            samples[i].push(loaded.execute_timed()? as f64);
        }
    }
    let mut ops = 0;
    for (art, s) in mm.ops.iter().zip(&samples) {
        let ns = stats::percentile(s, 25.0).round() as u64;
        if art.kind.is_decode_grid() {
            db.add_batch_ctx(art.kind, art.batch, art.ctx, ns);
        } else {
            db.add_tokens(art.kind, art.tokens, ns);
        }
        ops += 1;
        log::debug!("profiled {}: {} ns (median of {})", art.name, ns, opts.reps);
    }
    let loo = leave_one_out_error(&db);
    Ok(ProfileOutcome {
        db,
        ops_profiled: ops,
        wall_ns: t0.elapsed().as_nanos() as u64,
        loo_error_pct: loo,
    })
}

/// Profile and write the trace DB to `out`.
pub fn profile_to_file(
    artifacts_root: &Path,
    model_name: &str,
    out: &Path,
    opts: &ProfileOptions,
) -> anyhow::Result<ProfileOutcome> {
    let manifest = Manifest::load(artifacts_root)?;
    let mut runtime = Runtime::cpu(artifacts_root)?;
    let outcome = profile_model(&manifest, &mut runtime, model_name, opts)?;
    outcome.db.save(out)?;
    Ok(outcome)
}

/// Package a profiled trace DB into a hardware bundle at `out`: spec +
/// samples + derived per-op calibration factors, one file. This is the
/// second half of the one-command onboarding pipeline
/// (`profile --emit-bundle`, DESIGN.md §8); `import-hardware` /
/// `--hardware-dir` load the file back into the
/// [`hardware registry`](crate::perf::hardware) so the device resolves by
/// name in simulate, sweep, and heterogeneous-fleet configs.
pub fn emit_bundle(
    db: &TraceDb,
    spec: HardwareSpec,
    out: &Path,
) -> anyhow::Result<HardwareBundle> {
    let bundle = HardwareBundle::from_trace(spec, db.clone())?;
    bundle.save(out)?;
    Ok(bundle)
}

/// Leave-one-out interpolation error per op kind: re-predict each measured
/// grid point from the other points and compare.
pub fn leave_one_out_error(db: &TraceDb) -> Vec<(OpKind, f64)> {
    use crate::model::OpInvocation;
    let mut out = vec![];
    for kind in db.kinds().collect::<Vec<_>>() {
        // Rebuild per-kind sample list through the public API: query each
        // grid point against a DB with that point removed.
        let samples = db.samples(kind);
        if samples.len() < 3 {
            continue;
        }
        let mut errs = vec![];
        for (i, &(a, b, ns)) in samples.iter().enumerate() {
            let mut reduced = TraceDb::new(&db.hardware, &db.model);
            for (j, &(x, y, v)) in samples.iter().enumerate() {
                if i == j {
                    continue;
                }
                if kind.is_decode_grid() {
                    reduced.add_batch_ctx(kind, x, y, v);
                } else {
                    reduced.add_tokens(kind, x, v);
                }
            }
            let inv = if kind.is_decode_grid() {
                OpInvocation::decode(a, b)
            } else if kind == OpKind::AttnPrefill {
                OpInvocation::prefill(a)
            } else {
                OpInvocation::tokens(kind, a)
            };
            if let Some(pred) = reduced.lookup(inv) {
                errs.push(stats::ape(pred, ns as f64));
            }
        }
        if !errs.is_empty() {
            out.push((kind, errs.iter().sum::<f64>() / errs.len() as f64));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn emit_bundle_roundtrips_without_a_backend() {
        // The bundle-emission half of the pipeline needs no PJRT runtime:
        // package a synthetic trace, reload it, and check it registers.
        let mut db = TraceDb::new("profiler-test-npu", "tiny-dense");
        for t in [1u64, 8, 64] {
            db.add_tokens(OpKind::Ffn, t, 3_000 * t);
        }
        let spec = HardwareSpec {
            name: "profiler-test-npu".into(),
            ..HardwareSpec::cpu_pjrt()
        };
        let path = std::env::temp_dir().join("llmss_profiler_bundle_test.json");
        let bundle = emit_bundle(&db, spec, &path).unwrap();
        assert!(bundle.has_perf_data());
        let back = HardwareBundle::load(&path).unwrap();
        assert_eq!(back.spec.name, "profiler-test-npu");
        assert_eq!(back.calibration, bundle.calibration);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn profiles_tiny_dense_and_prices_lookups() {
        if !artifacts_root().join("manifest.json").exists()
            || !Runtime::backend_available()
        {
            eprintln!("skipping: needs `make artifacts` and a real PJRT backend");
            return;
        }
        let opts = ProfileOptions {
            warmup: 1,
            reps: 3,
            hardware_tag: "cpu-pjrt-test".into(),
        };
        let manifest = Manifest::load(&artifacts_root()).unwrap();
        let mut rt = Runtime::cpu(&artifacts_root()).unwrap();
        let outcome = profile_model(&manifest, &mut rt, "tiny-dense", &opts).unwrap();
        assert!(outcome.ops_profiled >= 50, "ops={}", outcome.ops_profiled);
        // the DB must price arbitrary shapes afterwards
        use crate::model::{OpInvocation, OpKind};
        use crate::perf::PerfModel;
        let l = outcome
            .db
            .op_latency(OpInvocation::tokens(OpKind::Ffn, 48));
        assert!(l > 0);
        let d = outcome.db.op_latency(OpInvocation::decode(3, 100));
        assert!(d > 0);
        // save/load roundtrip
        let path = std::env::temp_dir().join("llmss_trace_test.json");
        outcome.db.save(&path).unwrap();
        let back = TraceDb::load(&path).unwrap();
        assert_eq!(
            back.op_latency(OpInvocation::tokens(OpKind::Ffn, 48)),
            l
        );
        let _ = std::fs::remove_file(&path);
    }
}
