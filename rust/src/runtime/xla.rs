//! Build-hermetic stand-in for the `xla` crate (PJRT bindings).
//!
//! The real ground-truth engine executes compiled HLO artifacts through
//! PJRT via the `xla` crate (xla_extension C shim). That crate needs the
//! XLA C++ libraries at build time, which the CI / offline environment does
//! not guarantee, so the simulator compiles against this API-compatible
//! stub by default: every entry point that would touch PJRT returns a
//! "backend unavailable" error, and [`super::Runtime::cpu`] fails cleanly
//! before any other method can be reached.
//!
//! Everything artifact-gated (ground-truth validation, the profiler,
//! `validate`/`profile` CLI commands, Fig. 2 benches) degrades to a clear
//! error or a skip; the discrete-event simulator, all perf backends, and
//! the sweep engine are unaffected. To wire the real backend back in, add
//! the `xla` dependency to `Cargo.toml` and replace the `mod xla` / `use`
//! in `runtime/mod.rs` with `use xla;` — the call sites are unchanged.

use std::fmt;

/// Error raised by every stubbed PJRT entry point.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT backend unavailable (built against the in-repo xla \
             stub; see rust/src/runtime/xla.rs to enable real execution)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Host-side tensor literal. The stub keeps no data: literals are only ever
/// staged into device buffers, which cannot exist without a client.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (text form).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer. Unconstructible through the stub.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable. Unconstructible through the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(
        &self,
        _args: &[PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the single gate: it fails, so
/// no other stub method is reachable in practice.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer, Error> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_gate_fails_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT backend unavailable"));
    }

    #[test]
    fn literal_construction_is_usable_without_a_client() {
        let lit = Literal::vec1(&[0.0; 6]);
        assert!(lit.reshape(&[2, 3]).is_ok());
    }
}
