//! PJRT runtime: loads the HLO-text operator artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place Rust touches XLA. Interchange is HLO *text* (not
//! serialized `HloModuleProto`): jax >= 0.5 emits 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). Python never runs at simulation time — the
//! artifacts directory is the complete hand-off.
//!
//! By default this module compiles against the in-repo [`xla`] stub so the
//! crate builds without the XLA C++ toolchain; `Runtime::cpu` then returns
//! a clear "backend unavailable" error and everything artifact-gated skips
//! (see the stub's module docs for how to re-enable real execution).

pub mod profiler;
pub mod xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::model::OpKind;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// One lowered operator artifact (a `manifest.json` entry).
#[derive(Debug, Clone)]
pub struct OpArtifact {
    pub name: String,
    pub kind: OpKind,
    /// Path relative to the artifacts root.
    pub file: String,
    /// Parameter shapes (all f32).
    pub param_shapes: Vec<Vec<usize>>,
    /// 1-D grid coordinate (tokens) — 0 for decode-grid ops.
    pub tokens: u64,
    /// 2-D grid coordinates for decode attention.
    pub batch: u64,
    pub ctx: u64,
    pub flops: u64,
    pub bytes: u64,
}

/// Manifest for one model's operator grid.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub model: String,
    pub hidden: u64,
    pub layers: u64,
    pub ops: Vec<OpArtifact>,
}

/// The parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub models: Vec<ModelManifest>,
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: &Path) -> anyhow::Result<Manifest> {
        let v = json::load_file(&root.join("manifest.json"))?;
        Self::from_json(root, &v)
    }

    pub fn from_json(root: &Path, v: &Value) -> anyhow::Result<Manifest> {
        let mut models = vec![];
        for m in v.get("models").as_arr().unwrap_or(&[]) {
            let info = m.get("model");
            let name = info
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("manifest model missing name"))?
                .to_string();
            let mut ops = vec![];
            for op in m.get("ops").as_arr().unwrap_or(&[]) {
                let kind_str = op
                    .get("op")
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("op missing kind"))?;
                let kind = OpKind::from_str(kind_str)
                    .ok_or_else(|| anyhow::anyhow!("unknown op kind '{kind_str}'"))?;
                let param_shapes = op
                    .get("params")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|p| {
                        p.get("shape")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .map(|d| d.as_u64().unwrap_or(0) as usize)
                            .collect()
                    })
                    .collect();
                let grid = op.get("grid");
                ops.push(OpArtifact {
                    name: op
                        .get("name")
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("op missing name"))?
                        .to_string(),
                    kind,
                    file: op
                        .get("file")
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("op missing file"))?
                        .to_string(),
                    param_shapes,
                    tokens: grid.get("tokens").as_u64().unwrap_or(0),
                    batch: grid.get("batch").as_u64().unwrap_or(0),
                    ctx: grid.get("ctx").as_u64().unwrap_or(0),
                    flops: op.get("flops").as_u64().unwrap_or(0),
                    bytes: op.get("bytes").as_u64().unwrap_or(0),
                });
            }
            models.push(ModelManifest {
                model: name,
                hidden: info.get("hidden").as_u64().unwrap_or(0),
                layers: info.get("layers").as_u64().unwrap_or(0),
                ops,
            });
        }
        if models.is_empty() {
            anyhow::bail!("manifest has no models");
        }
        Ok(Manifest {
            root: root.to_path_buf(),
            models,
        })
    }

    pub fn model(&self, name: &str) -> Option<&ModelManifest> {
        self.models.iter().find(|m| m.model == name)
    }
}

/// A compiled operator ready to execute: executable + pre-built inputs.
pub struct LoadedOp {
    pub artifact: OpArtifact,
    exe: xla::PjRtLoadedExecutable,
    /// Inputs staged as DEVICE buffers once at load time and reused via
    /// `execute_b`: the literal-taking `execute` converts (and, in xla
    /// 0.1.6's C shim, leaks) a device buffer per argument per call.
    inputs: Vec<xla::PjRtBuffer>,
}

/// Measurement clock for the profiler and the ground-truth engine:
/// monotonic nanoseconds since the first call. Both measure with this same
/// function, so predictions and reference share one time base.
///
/// History: this used to bind `clock_gettime(CLOCK_PROCESS_CPUTIME_ID)`
/// directly against glibc for preemption-immune process-CPU time, but that
/// required an `unsafe extern` block and the crate is now
/// `#![forbid(unsafe_code)]`. `std::time::Instant` (CLOCK_MONOTONIC) is
/// the strictest clock reachable from safe std; on the single-tenant CI
/// and profiling boxes the difference to process-CPU time is scheduler
/// noise, and the profiler's min-of-N-repeats sampling absorbs it.
pub fn cpu_time_ns() -> u64 {
    use std::sync::OnceLock;
    static START: OnceLock<std::time::Instant> = OnceLock::new();
    // simlint: allow(D02) — wall-clock measurement of real kernel execution
    // (profiler / ground-truth); never feeds simulated time
    let start = *START.get_or_init(std::time::Instant::now);
    start.elapsed().as_nanos() as u64
}

impl LoadedOp {
    /// Execute once, synchronously; returns measured nanoseconds on the
    /// `cpu_time_ns` clock.
    pub fn execute_timed(&self) -> anyhow::Result<u64> {
        let t0 = cpu_time_ns();
        let result = self.exe.execute_b::<xla::PjRtBuffer>(&self.inputs)?;
        // Force completion by materializing the (tuple) output.
        let _lit = result[0][0].to_literal_sync()?;
        Ok(cpu_time_ns() - t0)
    }

    /// Execute and return the raw output literal (tests / numerics checks).
    pub fn execute(&self) -> anyhow::Result<xla::Literal> {
        let result = self.exe.execute_b::<xla::PjRtBuffer>(&self.inputs)?;
        Ok(result[0][0].to_literal_sync()?)
    }
}

/// PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, LoadedOp>,
    root: PathBuf,
    /// Seed for deterministic input generation.
    seed: u64,
}

impl Runtime {
    /// True when a real PJRT backend is compiled in and usable; false with
    /// the in-repo [`xla`] stub. Artifact-gated tests, benches, and
    /// examples check this alongside the artifacts directory so they skip
    /// cleanly instead of erroring when only the stub is present.
    pub fn backend_available() -> bool {
        xla::PjRtClient::cpu().is_ok()
    }

    /// Create a CPU PJRT runtime rooted at the artifacts directory.
    pub fn cpu(artifacts_root: &Path) -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime {
            client,
            cache: HashMap::new(),
            root: artifacts_root.to_path_buf(),
            seed: 0xA07,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile + build inputs for) an artifact; cached by name.
    pub fn load(&mut self, artifact: &OpArtifact) -> anyhow::Result<&LoadedOp> {
        if !self.cache.contains_key(&artifact.name) {
            let path = self.root.join(&artifact.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e}", artifact.name))?;
            let mut rng = Rng::new(self.seed ^ hash_name(&artifact.name));
            let inputs = artifact
                .param_shapes
                .iter()
                .map(|shape| {
                    let lit = make_literal(shape, &mut rng)?;
                    let buf = self
                        .client
                        .buffer_from_host_literal(None, &lit)
                        .map_err(|e| anyhow::anyhow!("staging input: {e}"))?;
                    // The host->device copy is asynchronous; force it to
                    // complete before `lit` drops (use-after-free otherwise).
                    buf.to_literal_sync()
                        .map_err(|e| anyhow::anyhow!("sync staging: {e}"))?;
                    Ok(buf)
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            self.cache.insert(
                artifact.name.clone(),
                LoadedOp {
                    artifact: artifact.clone(),
                    exe,
                    inputs,
                },
            );
        }
        Ok(&self.cache[&artifact.name])
    }

    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }
}

fn hash_name(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Build a random f32 literal of `shape` (small magnitude: activations and
/// weights in a realistic range so softmax paths stay finite).
fn make_literal(shape: &[usize], rng: &mut Rng) -> anyhow::Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    let data: Vec<f32> = (0..n)
        .map(|_| (rng.f64() as f32 - 0.5) * 0.2)
        .collect();
    let lit = xla::Literal::vec1(&data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    if dims.is_empty() {
        return Ok(lit);
    }
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape to {shape:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        // tests run from the crate root
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_root().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&artifacts_root()).unwrap();
        assert!(m.model("tiny-dense").is_some());
        let dense = m.model("tiny-dense").unwrap();
        assert_eq!(dense.hidden, 256);
        // all nine op kinds minus moe-specific ones
        let kinds: std::collections::HashSet<OpKind> =
            dense.ops.iter().map(|o| o.kind).collect();
        assert!(kinds.contains(&OpKind::AttnPrefill));
        assert!(kinds.contains(&OpKind::AttnDecode));
        assert!(kinds.contains(&OpKind::Ffn));
        // decode ops carry 2-D grid coords
        let d = dense
            .ops
            .iter()
            .find(|o| o.kind == OpKind::AttnDecode)
            .unwrap();
        assert!(d.batch > 0 && d.ctx > 0);
    }

    #[test]
    fn runtime_loads_and_executes_op() {
        if !have_artifacts() || !Runtime::backend_available() {
            eprintln!("skipping: needs `make artifacts` and a real PJRT backend");
            return;
        }
        let m = Manifest::load(&artifacts_root()).unwrap();
        let dense = m.model("tiny-dense").unwrap();
        let op = dense
            .ops
            .iter()
            .find(|o| o.kind == OpKind::Ffn && o.tokens == 8)
            .expect("ffn_t8 artifact");
        let mut rt = Runtime::cpu(&artifacts_root()).unwrap();
        let loaded = rt.load(op).unwrap();
        let ns = loaded.execute_timed().unwrap();
        assert!(ns > 0);
        // second load is cached
        let _ = rt.load(op).unwrap();
        assert_eq!(rt.loaded_count(), 1);
    }

    #[test]
    fn pallas_attention_artifact_executes() {
        if !have_artifacts() || !Runtime::backend_available() {
            eprintln!("skipping: needs `make artifacts` and a real PJRT backend");
            return;
        }
        let m = Manifest::load(&artifacts_root()).unwrap();
        let dense = m.model("tiny-dense").unwrap();
        let op = dense
            .ops
            .iter()
            .find(|o| o.kind == OpKind::AttnPrefill)
            .unwrap();
        let mut rt = Runtime::cpu(&artifacts_root()).unwrap();
        let loaded = rt.load(op).unwrap();
        // the interpret-mode Pallas kernel must run on the CPU client
        let out = loaded.execute().unwrap();
        let tuple = out.to_tuple1().unwrap();
        let values = tuple.to_vec::<f32>().unwrap();
        assert!(!values.is_empty());
        assert!(values.iter().all(|v| v.is_finite()));
    }
}
