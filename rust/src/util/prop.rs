//! Minimal property-based testing harness.
//!
//! The offline registry lacks `proptest`, so coordinator invariants are
//! checked with this lightweight substitute: deterministic seed-derived case
//! generation, a fixed case budget, and first-failure reporting including
//! the per-case seed so a failure replays with `replay(seed, ...)`.

use super::rng::Rng;

/// Number of cases per property unless overridden.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` over `cases` inputs produced by `gen`. Panics with the failing
/// seed + debug dump on the first violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = seed_for(name);
    for i in 0..cases {
        let case_seed = base.wrapping_add(i as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {i} (seed {case_seed:#x}):\n  \
                 {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<T: std::fmt::Debug>(
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    let input = gen(&mut rng);
    prop(&input)
}

/// Stable 64-bit hash of the property name (FNV-1a) so each property gets an
/// independent but reproducible case stream.
fn seed_for(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert-style helper for building property results.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            "addition-commutes",
            64,
            |rng| (rng.below(1000), rng.below(1000)),
            |&(a, b)| {
                count += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        assert_eq!(count, 64);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            16,
            |rng| rng.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn seeds_stable_across_runs() {
        let mut first: Vec<u64> = vec![];
        check("stable", 8, |rng| rng.next_u64(), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second: Vec<u64> = vec![];
        check("stable", 8, |rng| rng.next_u64(), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn replay_reproduces() {
        // find the value generated for a given seed, then replay it
        let seed = 0x1234;
        let mut seen = None;
        let _ = replay(seed, |rng| rng.below(100), |&x| {
            seen = Some(x);
            Ok(())
        });
        let mut again = None;
        let _ = replay(seed, |rng| rng.below(100), |&x| {
            again = Some(x);
            Ok(())
        });
        assert_eq!(seen, again);
    }
}
