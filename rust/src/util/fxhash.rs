//! Deterministic fast hashing for hot-path maps.
//!
//! `std::collections::HashMap` defaults to SipHash-1-3 with a per-process
//! random key. That is the right default against untrusted input, but in
//! the simulator's hot loops (radix-tree child lookups, in-flight KV
//! transfer tracking) the keys are small trusted integers and SipHash is
//! pure overhead. This module provides the well-known Fx multiply-rotate
//! hash (as used by rustc): a few cycles per word and — crucially for
//! reproducibility — no random state, so map behaviour is identical
//! across runs and platforms.
//!
//! Determinism caveat: code must still never depend on map *iteration*
//! order (byte-identical reports rely on explicit ordering everywhere);
//! using a fixed hasher merely removes per-process entropy, it does not
//! make iteration order part of the contract.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-rotate hasher with no per-process state.
#[derive(Debug, Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in chunks.by_ref() {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rest.len()].copy_from_slice(rest);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

/// `BuildHasher` for [`FxHasher`]; usable anywhere `RandomState` is.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// `HashMap` keyed with the deterministic Fx hash. Construct with
/// `FxHashMap::default()`.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// `HashSet` keyed with the deterministic Fx hash. Construct with
/// `FxHashSet::default()`.
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hashes_are_stable_across_hashers() {
        let a = {
            let mut h = FxHasher::default();
            h.write_u64(0xdead_beef);
            h.finish()
        };
        let b = {
            let mut h = FxHasher::default();
            h.write_u64(0xdead_beef);
            h.finish()
        };
        assert_eq!(a, b);
        assert_ne!(a, 0);
    }

    #[test]
    fn distinct_inputs_differ() {
        let mut seen = std::collections::HashSet::new();
        for i in 0u64..1000 {
            let mut h = FxHasher::default();
            h.write_u64(i);
            assert!(seen.insert(h.finish()), "collision at {i}");
        }
    }

    #[test]
    fn byte_writes_cover_tail() {
        let mut h1 = FxHasher::default();
        h1.write(b"abcdefghij"); // 8-byte chunk + 2-byte tail
        let mut h2 = FxHasher::default();
        h2.write(b"abcdefghik");
        assert_ne!(h1.finish(), h2.finish());
    }

    #[test]
    fn map_works_as_drop_in() {
        let mut m: FxHashMap<u32, usize> = FxHashMap::default();
        for i in 0..100u32 {
            m.insert(i, i as usize * 2);
        }
        assert_eq!(m.len(), 100);
        assert_eq!(m.get(&40), Some(&80));
        m.remove(&40);
        assert_eq!(m.get(&40), None);
    }
}
