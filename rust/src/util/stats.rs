//! Descriptive statistics used by the metrics pipeline, the profiler, and
//! the validation benches (error rates vs. ground truth).

/// Summary of a sample: moments + order statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns zeros for an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                count: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        Summary {
            count: n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Percentile of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Median of an unsorted slice.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    /// Unbiased (Bessel-corrected) sample variance, `m2 / (n - 1)`.
    /// Clamped at zero: catastrophic cancellation can leave `m2` a hair
    /// negative for near-constant samples, and a NaN std would poison
    /// every downstream aggregate.
    pub fn var_sample(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).max(0.0)
        }
    }
    pub fn std_sample(&self) -> f64 {
        self.var_sample().sqrt()
    }
    /// Standard error of the mean, `s / sqrt(n)`.
    pub fn std_err(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std_sample() / (self.n as f64).sqrt()
        }
    }
    /// Half-width of the normal-approximation 95% confidence interval on
    /// the mean (`1.96 * std_err`). Zero for n < 2 — with one replicate
    /// there is no spread estimate, not an infinitely tight one.
    pub fn ci95_half_width(&self) -> f64 {
        1.96 * self.std_err()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
    pub fn merge(&mut self, other: &Online) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Bounded-memory sample accumulator: exact count/mean/std/min/max
/// (Welford) plus a deterministic reservoir for percentiles.
///
/// Below the reservoir capacity every sample is retained, so summaries are
/// *exact* — identical to [`Summary::of`] over the same values. Past the
/// capacity, percentiles come from uniform reservoir sampling driven by a
/// private fixed-seed [`Rng`], so results stay byte-reproducible for a
/// given push sequence (worker threads never share a `SampleSet`). This is
/// what lets the metrics pipeline ingest hundreds of millions of
/// inter-token gaps from million-request streaming workloads in O(cap)
/// memory.
#[derive(Debug, Clone)]
pub struct SampleSet {
    online: Online,
    reservoir: Vec<f64>,
    cap: usize,
    rng: crate::util::rng::Rng,
}

/// Default reservoir capacity: exact percentiles for every workload the
/// test suite and the paper's figures run, bounded memory beyond.
pub const SAMPLE_RESERVOIR_CAP: usize = 65_536;

impl Default for SampleSet {
    fn default() -> Self {
        SampleSet::new(SAMPLE_RESERVOIR_CAP)
    }
}

impl SampleSet {
    pub fn new(cap: usize) -> SampleSet {
        assert!(cap > 0);
        SampleSet {
            online: Online::new(),
            reservoir: Vec::new(),
            cap,
            rng: crate::util::rng::Rng::new(0x5A4D_17E5),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.online.push(x);
        if self.reservoir.len() < self.cap {
            self.reservoir.push(x);
        } else {
            // classic Algorithm R: keep each of the n seen samples with
            // probability cap/n
            let j = self.rng.below(self.online.count());
            if (j as usize) < self.cap {
                self.reservoir[j as usize] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.online.count()
    }

    /// The exact Welford accumulator behind this set (mean/std/stderr are
    /// always exact regardless of reservoir drops).
    pub fn online(&self) -> &Online {
        &self.online
    }

    /// True iff percentiles are exact (no sample has been dropped).
    pub fn is_exact(&self) -> bool {
        self.online.count() as usize <= self.cap
    }

    pub fn summary(&self) -> Summary {
        if self.online.count() == 0 {
            return Summary::of(&[]);
        }
        let mut sorted = self.reservoir.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            count: self.online.count() as usize,
            mean: self.online.mean(),
            std: self.online.std(),
            min: self.online.min(),
            max: self.online.max(),
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Absolute percentage error: `|a - b| / |b| * 100` (b = reference).
pub fn ape(measured: f64, reference: f64) -> f64 {
    if reference == 0.0 {
        if measured == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        ((measured - reference) / reference).abs() * 100.0
    }
}

/// Mean absolute percentage error over paired samples.
pub fn mape(measured: &[f64], reference: &[f64]) -> f64 {
    assert_eq!(measured.len(), reference.len());
    assert!(!measured.is_empty());
    measured
        .iter()
        .zip(reference)
        .map(|(m, r)| ape(*m, *r))
        .sum::<f64>()
        / measured.len() as f64
}

/// Fixed-width histogram for latency distributions.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
        }
    }
    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let i = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[i.min(n - 1)] += 1;
        }
    }
    pub fn bins(&self) -> &[u64] {
        &self.bins
    }
    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut o = Online::new();
        for x in xs {
            o.push(x);
        }
        let s = Summary::of(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.std() - s.std).abs() < 1e-12);
        assert_eq!(o.min(), 2.0);
        assert_eq!(o.max(), 9.0);
    }

    #[test]
    fn online_merge() {
        let a_data = [1.0, 2.0, 3.0];
        let b_data = [10.0, 20.0];
        let mut a = Online::new();
        let mut b = Online::new();
        for x in a_data {
            a.push(x);
        }
        for x in b_data {
            b.push(x);
        }
        a.merge(&b);
        let all = [1.0, 2.0, 3.0, 10.0, 20.0];
        let s = Summary::of(&all);
        assert!((a.mean() - s.mean).abs() < 1e-9);
        assert!((a.std() - s.std).abs() < 1e-9);
    }

    #[test]
    fn sample_set_exact_below_cap() {
        let xs: Vec<f64> = (0..100).map(|i| (i * 7 % 100) as f64).collect();
        let mut s = SampleSet::new(256);
        for &x in &xs {
            s.push(x);
        }
        assert!(s.is_exact());
        let got = s.summary();
        let want = Summary::of(&xs);
        assert_eq!(got.count, want.count);
        assert!((got.mean - want.mean).abs() < 1e-9);
        assert!((got.std - want.std).abs() < 1e-9);
        assert_eq!(got.p50, want.p50);
        assert_eq!(got.p90, want.p90);
        assert_eq!(got.p99, want.p99);
        assert_eq!((got.min, got.max), (want.min, want.max));
    }

    #[test]
    fn sample_set_bounded_and_deterministic_past_cap() {
        let mk = || {
            let mut s = SampleSet::new(64);
            for i in 0..10_000u64 {
                s.push((i % 1000) as f64);
            }
            s
        };
        let a = mk();
        let b = mk();
        assert!(!a.is_exact());
        assert_eq!(a.count(), 10_000);
        assert_eq!(a.summary(), b.summary(), "reservoir must be deterministic");
        // mean/min/max stay exact; percentiles approximate the uniform
        let s = a.summary();
        assert!((s.mean - 499.55).abs() < 1.0, "mean={}", s.mean);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 999.0);
        assert!((s.p50 - 500.0).abs() < 150.0, "p50={}", s.p50);
    }

    #[test]
    fn sample_statistics_and_ci() {
        let mut o = Online::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            o.push(x);
        }
        // population var = 4, sample var = 32/7
        assert!((o.var() - 4.0).abs() < 1e-12);
        assert!((o.var_sample() - 32.0 / 7.0).abs() < 1e-12);
        assert!((o.std_err() - (32.0 / 7.0f64).sqrt() / 8.0f64.sqrt()).abs() < 1e-12);
        assert!((o.ci95_half_width() - 1.96 * o.std_err()).abs() < 1e-15);
        // degenerate cases: no spread estimate, not NaN
        let mut one = Online::new();
        one.push(3.0);
        assert_eq!(one.var_sample(), 0.0);
        assert_eq!(one.ci95_half_width(), 0.0);
        assert_eq!(Online::new().std_err(), 0.0);
        // constant samples never go negative-variance
        let mut c = Online::new();
        for _ in 0..1000 {
            c.push(0.1 + 0.2); // classic fp non-exact value
        }
        assert!(c.var_sample() >= 0.0);
        assert!(!c.std_sample().is_nan());
        // the SampleSet exposes its exact accumulator
        let mut s = SampleSet::new(4);
        for x in [1.0, 2.0, 3.0] {
            s.push(x);
        }
        assert_eq!(s.online().count(), 3);
        assert!((s.online().mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ape_and_mape() {
        assert!((ape(110.0, 100.0) - 10.0).abs() < 1e-12);
        assert!((ape(90.0, 100.0) - 10.0).abs() < 1e-12);
        assert_eq!(ape(0.0, 0.0), 0.0);
        let m = mape(&[110.0, 95.0], &[100.0, 100.0]);
        assert!((m - 7.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [-1.0, 0.0, 0.5, 5.0, 9.99, 10.0, 42.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 7);
        assert_eq!(h.bins()[0], 2); // 0.0, 0.5
        assert_eq!(h.bins()[5], 1); // 5.0
        assert_eq!(h.bins()[9], 1); // 9.99
    }
}
