//! Self-contained substrates: JSON codec, PRNG, statistics, property-test
//! harness, benchmark harness, and logging. These replace the crates the
//! offline registry does not carry (serde/rand/proptest/criterion); see
//! DESIGN.md §1.

pub mod bench;
pub mod fxhash;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
