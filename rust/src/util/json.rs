//! Minimal, dependency-free JSON codec.
//!
//! The offline crate registry for this environment does not carry
//! `serde`/`serde_json`, so the simulator ships its own codec. It is used for
//! every on-disk interchange format in the project: cluster configs, the AOT
//! `manifest.json`, profiled latency traces, and benchmark/metric reports.
//!
//! Supports the full JSON grammar (RFC 8259): nested values, all escapes,
//! `\uXXXX` (incl. surrogate pairs), scientific notation. Integers that fit
//! `i64` are kept exact via [`Number::Int`].

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON number; integers are kept exact.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Number {
    Int(i64),
    Float(f64),
}

impl Number {
    pub fn as_f64(self) -> f64 {
        match self {
            Number::Int(i) => i as f64,
            Number::Float(f) => f,
        }
    }
    pub fn as_i64(self) -> Option<i64> {
        match self {
            Number::Int(i) => Some(i),
            Number::Float(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(f as i64),
            _ => None,
        }
    }
}

/// A JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(Number),
    Str(String),
    Arr(Vec<Value>),
    /// Object keys are sorted (BTreeMap) so serialization is deterministic.
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(n.as_f64()),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) => n.as_i64(),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; returns `Value::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array index lookup; returns `Value::Null` out of range.
    pub fn idx(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- constructors ---------------------------------------------------
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
    pub fn int(i: i64) -> Value {
        Value::Num(Number::Int(i))
    }
    pub fn float(f: f64) -> Value {
        Value::Num(Number::Float(f))
    }
    pub fn obj(fields: Vec<(&str, Value)>) -> Value {
        Value::Obj(
            fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
    pub fn arr(items: Vec<Value>) -> Value {
        Value::Arr(items)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }
    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(Number::Int(i)) => out.push_str(&i.to_string()),
            Value::Num(Number::Float(f)) => {
                if f.is_finite() {
                    out.push_str(&format!("{f}"));
                } else {
                    // JSON has no inf/nan; null is the conventional fallback.
                    out.push_str("null");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        out.extend(std::iter::repeat(' ').take(n * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset and line number.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
    pub line: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage is
/// an error.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        b: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        let line = 1 + self.b[..self.pos.min(self.b.len())]
            .iter()
            .filter(|&&c| c == b'\n')
            .count();
        ParseError {
            msg: msg.into(),
            offset: self.pos,
            line,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: require \uXXXX low surrogate
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is &str, so it's valid).
                    let start = self.pos;
                    let mut end = start + 1;
                    while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end]).unwrap());
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Value::Num(Number::Int(i)));
            }
        }
        text.parse::<f64>()
            .map(|f| Value::Num(Number::Float(f)))
            .map_err(|_| self.err("invalid number"))
    }
}

/// Convenience: load + parse a JSON file.
pub fn load_file(path: &std::path::Path) -> anyhow::Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// Convenience: pretty-write a JSON file (creates parent dirs).
pub fn save_file(path: &std::path::Path, v: &Value) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, v.to_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::int(42));
        assert_eq!(parse("-7").unwrap(), Value::int(-7));
        assert_eq!(parse("3.5").unwrap(), Value::float(3.5));
        assert_eq!(parse("1e3").unwrap(), Value::float(1000.0));
        assert_eq!(parse("2.5e-2").unwrap(), Value::float(0.025));
        assert_eq!(parse("\"hi\"").unwrap(), Value::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": null}, "x"], "c": {"d": true}}"#).unwrap();
        assert_eq!(v.get("a").idx(0).as_i64(), Some(1));
        assert!(v.get("a").idx(1).get("b").is_null());
        assert_eq!(v.get("a").idx(2).as_str(), Some("x"));
        assert_eq!(v.get("c").get("d").as_bool(), Some(true));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#""\ud800x""#).is_err());
    }

    #[test]
    fn error_reports_line() {
        let err = parse("{\n\"a\": 1,\n bad\n}").unwrap_err();
        assert_eq!(err.line, 3);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,null],"name":"x \"q\"","nested":{"t":true}}"#;
        let v = parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = v.to_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn integers_kept_exact() {
        let big = 9_007_199_254_740_993i64; // 2^53 + 1, not representable in f64
        let v = parse(&big.to_string()).unwrap();
        assert_eq!(v.as_i64(), Some(big));
        assert_eq!(parse(&v.to_string()).unwrap().as_i64(), Some(big));
    }

    #[test]
    fn nonfinite_floats_serialize_null() {
        assert_eq!(Value::float(f64::NAN).to_string(), "null");
        assert_eq!(Value::float(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn builders() {
        let v = Value::obj(vec![
            ("n", Value::int(3)),
            ("l", Value::arr(vec![Value::str("a")])),
        ]);
        assert_eq!(v.get("n").as_i64(), Some(3));
        assert_eq!(v.get("l").idx(0).as_str(), Some("a"));
        assert_eq!(v.get("l").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn u64_accessor() {
        assert_eq!(parse("5").unwrap().as_u64(), Some(5));
        assert_eq!(parse("-5").unwrap().as_u64(), None);
    }
}
