//! Deterministic PRNG + distributions.
//!
//! The offline registry lacks the `rand` facade, so the simulator ships its
//! own generator: xoshiro256** seeded via SplitMix64. Every stochastic piece
//! of the simulator (arrival processes, workload sampling, gate mimicry,
//! routing tie-breaks) draws from an explicitly-seeded [`Rng`], making every
//! simulation bit-reproducible from its config seed.

/// xoshiro256** (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Derive an independent child generator (for per-instance streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiased results.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -(1.0 - u).ln() / lambda
    }

    /// Standard normal (Box–Muller; one draw per call, pair cached not kept
    /// for simplicity/determinism under forking).
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 <= 0.0 {
            u1 = f64::MIN_POSITIVE;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given `mu`/`sigma` of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson-distributed count with mean `lambda` (Knuth for small lambda,
    /// normal approximation above 64 — adequate for arrival batching).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf-distributed index in `[0, n)` with exponent `s` (s=0 → uniform).
    /// Uses inverse-CDF over precomputed weights; for hot paths build a
    /// [`ZipfTable`] once instead.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        ZipfTable::new(n, s).sample(self)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Uniformly choose an element.
    pub fn choice<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len() as u64) as usize]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed Zipf CDF for repeated sampling (expert gate mimicry).
#[derive(Debug, Clone)]
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fork_independent() {
        let mut root = Rng::new(7);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut rng = Rng::new(4);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn exp_mean() {
        let mut rng = Rng::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(6);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut rng = Rng::new(7);
        for lambda in [0.5, 4.0, 100.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| rng.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn lognormal_positive() {
        let mut rng = Rng::new(8);
        for _ in 0..1000 {
            assert!(rng.lognormal(3.0, 1.0) > 0.0);
        }
    }

    #[test]
    fn zipf_skew() {
        let mut rng = Rng::new(9);
        let table = ZipfTable::new(8, 1.2);
        let mut counts = [0u32; 8];
        for _ in 0..20_000 {
            counts[table.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[3] && counts[3] > counts[7], "{counts:?}");
    }

    #[test]
    fn zipf_zero_exponent_uniformish() {
        let mut rng = Rng::new(10);
        let table = ZipfTable::new(4, 0.0);
        let mut counts = [0u32; 4];
        for _ in 0..40_000 {
            counts[table.sample(&mut rng)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 700.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Rng::new(11);
        let mut v: Vec<u32> = (0..20).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(v, (0..20).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Rng::new(12);
        let w = [0.1, 0.8, 0.1];
        let mut counts = [0u32; 3];
        for _ in 0..10_000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert!(counts[1] > 7_000, "{counts:?}");
    }

    #[test]
    fn range_bounds() {
        let mut rng = Rng::new(13);
        for _ in 0..1000 {
            let x = rng.range_u64(3, 7);
            assert!((3..=7).contains(&x));
            let f = rng.range_f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }
}
