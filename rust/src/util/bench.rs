//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated measurement with median/std reporting and an
//! aligned table printer. All `benches/*.rs` targets use `harness = false`
//! and drive this module directly, so `cargo bench` regenerates each paper
//! table/figure as a printed table.

use std::time::{Duration, Instant};

use super::stats;

/// Result of one benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Cap total measurement wall-clock; long-running sims get fewer iters.
    pub max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 2,
            measure_iters: 10,
            max_total: Duration::from_secs(60),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            measure_iters: 3,
            max_total: Duration::from_secs(120),
        }
    }

    /// Measure `f`, returning per-iteration timing statistics.
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let started = Instant::now();
        let mut samples = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            if started.elapsed() > self.max_total && samples.len() >= 3 {
                break;
            }
        }
        let s = stats::Summary::of(&samples);
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            median: Duration::from_secs_f64(s.p50),
            mean: Duration::from_secs_f64(s.mean),
            std: Duration::from_secs_f64(s.std),
            min: Duration::from_secs_f64(s.min),
        }
    }
}

/// Human-readable duration (ns/µs/ms/s auto-scaling).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

/// Aligned ASCII table printer for bench/report output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line
        };
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher {
            warmup_iters: 1,
            measure_iters: 5,
            max_total: Duration::from_secs(5),
        };
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(r.iters, 5);
        assert!(r.median > Duration::ZERO);
        assert!(r.min <= r.median);
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_duration(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_duration(Duration::from_millis(2500)).contains(" s"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["config", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2".into()]);
        let out = t.render();
        assert!(out.contains("| config    |"));
        assert!(out.contains("| long-name |"));
        let widths: Vec<usize> = out.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
