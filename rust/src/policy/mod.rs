//! Unified policy-plugin layer (§II-B: "flexible interfaces for request
//! routing, cache management, and scheduling policies").
//!
//! Every serving decision point is a named, registered trait object:
//!
//! | Decision point        | Trait              | Built-in names |
//! |-----------------------|--------------------|----------------|
//! | global request routing| [`RoutePolicy`]    | `round-robin`, `least-outstanding`, `least-kv`, `prefix-aware`, `session-affinity` |
//! | wait-queue ordering   | [`SchedulePolicy`] | `fcfs`, `sjf`, `priority`, `slo` |
//! | prefix-cache eviction | [`EvictionPolicy`] | `lru`, `lfu`, `largest` |
//! | traffic generation    | [`TrafficSource`]  | `burst`, `diurnal`, `mmpp`, `poisson`, `sessions`, `uniform` |
//! | cluster dynamics      | [`ClusterController`] | `static`, `queue-threshold`, `failure-replay`, `chaos` |
//!
//! [`SimConfig`](crate::config::SimConfig) stores policy *names* (plain
//! strings, so JSON round-trip and presets keep working); a
//! [`PolicyRegistry`] maps names to factory closures, and resolution
//! happens exactly once, when a
//! [`Simulation`](crate::coordinator::Simulation) is built. Downstream
//! code adds a policy in one file with zero core edits:
//!
//! 1. implement the trait (all three are object-safe and `Send`);
//! 2. either register a factory under a name
//!    ([`register_sched_policy`] & friends make it reachable from configs
//!    and [sweep](crate::sweep) axes), or inject an instance directly via
//!    [`Simulation::builder`](crate::coordinator::Simulation::builder).
//!
//! The registry is deterministic: names are stored in a `BTreeMap`, so
//! enumeration order is stable and sweep grids built from
//! [`PolicyRegistry::route_names`] etc. are reproducible.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};

use crate::config::ClusterConfig;
use crate::sim::Nanos;

pub use crate::cluster::{
    ClusterAction, ClusterController, ClusterView, InstanceSnapshot,
};
pub use crate::memory::radix::CacheLeaf;
pub use crate::router::{InstanceView, RoutePolicy};
pub use crate::workload::{Traffic, TrafficSource, WorkloadSpec};

// ---------------------------------------------------------------------------
// Traits
// ---------------------------------------------------------------------------

/// Wait-queue ordering policy for the continuous-batching scheduler.
///
/// `order` reorders `wait` in admission order (index 0 is admitted first).
/// Implementations must be deterministic — break ties on request id — or
/// simulations stop being reproducible. The built-ins always sort
/// preempted sequences first (vLLM recompute semantics); custom policies
/// are free to choose otherwise.
pub trait SchedulePolicy: Send {
    /// Registry/report name of this policy.
    fn name(&self) -> &str;

    /// Reorder `wait` (sequence ids) in admission order.
    fn order(
        &mut self,
        wait: &mut [u64],
        seqs: &crate::instance::SeqMap,
        now: Nanos,
    );
}

/// Victim-selection policy for the tiered prefix cache.
/// Candidates arrive as [`CacheLeaf`] snapshots (id, tokens, last access,
/// access count), collected from the radix tree by the cache manager.
///
/// `pick` returns the id of the leaf to evict, or `None` to refuse (the
/// cache then stops evicting). Must be deterministic: break ties on
/// `leaf.id`.
pub trait EvictionPolicy: Send {
    /// Registry/report name of this policy.
    fn name(&self) -> &str;

    /// Choose a victim among `leaves` (possibly empty).
    fn pick(&mut self, leaves: &[CacheLeaf]) -> Option<usize>;
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Factory for route policies. `Arc` so a registry snapshot is cheap.
pub type RouteFactory = Arc<dyn Fn() -> Box<dyn RoutePolicy> + Send + Sync>;
/// Factory for schedule policies.
pub type SchedFactory = Arc<dyn Fn() -> Box<dyn SchedulePolicy> + Send + Sync>;
/// Factory for eviction policies.
pub type EvictFactory = Arc<dyn Fn() -> Box<dyn EvictionPolicy> + Send + Sync>;
/// Factory for traffic sources. Unlike the other decision points, a
/// traffic source is parameterized by the workload it generates, so the
/// factory receives the full [`WorkloadSpec`].
pub type TrafficFactory =
    Arc<dyn Fn(&WorkloadSpec) -> anyhow::Result<Box<dyn TrafficSource>> + Send + Sync>;
/// Factory for cluster controllers. Like traffic sources, controllers are
/// parameterized by config — the factory receives the full
/// [`ClusterConfig`] (thresholds, fleet bounds, failure script).
pub type ControllerFactory = Arc<
    dyn Fn(&ClusterConfig) -> anyhow::Result<Box<dyn ClusterController>>
        + Send
        + Sync,
>;

/// Maps policy names to factory closures for all three decision points.
///
/// Factories (not instances) are stored because policies are stateful and
/// every simulation needs a fresh instance — sharing one across sweep
/// workers would break determinism. Registration replaces any previous
/// entry under the same name (last wins), so re-registering is idempotent.
#[derive(Clone)]
pub struct PolicyRegistry {
    route: BTreeMap<String, RouteFactory>,
    sched: BTreeMap<String, SchedFactory>,
    evict: BTreeMap<String, EvictFactory>,
    traffic: BTreeMap<String, TrafficFactory>,
    controller: BTreeMap<String, ControllerFactory>,
}

impl Default for PolicyRegistry {
    /// The built-in registry ([`PolicyRegistry::builtins`]).
    fn default() -> Self {
        Self::builtins()
    }
}

impl std::fmt::Debug for PolicyRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PolicyRegistry")
            .field("route", &self.route_names())
            .field("sched", &self.sched_names())
            .field("evict", &self.evict_names())
            .field("traffic", &self.traffic_names())
            .field("controller", &self.controller_names())
            .finish()
    }
}

fn unknown(kind: &str, name: &str, known: &[String]) -> anyhow::Error {
    anyhow::anyhow!(
        "unknown {kind} policy '{name}' (registered: {})",
        known.join("|")
    )
}

impl PolicyRegistry {
    /// A registry with no entries (useful for fully-custom setups).
    pub fn empty() -> Self {
        PolicyRegistry {
            route: BTreeMap::new(),
            sched: BTreeMap::new(),
            evict: BTreeMap::new(),
            traffic: BTreeMap::new(),
            controller: BTreeMap::new(),
        }
    }

    /// A registry pre-seeded with every built-in policy.
    pub fn builtins() -> Self {
        use crate::router::{
            LeastKvLoad, LeastOutstanding, PrefixAware, RoundRobin,
            SessionAffinity,
        };

        let mut r = Self::empty();
        r.register_route("round-robin", || Box::new(RoundRobin::default()));
        r.register_route("least-outstanding", || Box::new(LeastOutstanding));
        r.register_route("least-kv", || Box::new(LeastKvLoad));
        r.register_route("prefix-aware", || Box::new(PrefixAware));
        // Session affinity is a wrapper: sticky sessions over a fallback
        // policy that places each session's first request. The instance's
        // `name()` reports both ("session-affinity(least-outstanding)") so
        // reports never misattribute the placement decisions.
        r.register_route("session-affinity", || {
            Box::new(SessionAffinity::wrapping(Box::new(LeastOutstanding)))
        });
        // The sched/evict sides derive from the typed enums, so name,
        // enum, and registry can never drift apart.
        for s in crate::config::SchedPolicy::all() {
            let s = *s;
            r.register_sched(s.as_str(), move || s.to_policy());
        }
        for e in crate::memory::EvictPolicy::all() {
            let e = *e;
            r.register_evict(e.as_str(), move || e.to_policy());
        }
        // Built-in traffic sources are the parameter-free-sweepable kinds;
        // replay stays structural (it needs a trace path) and resolves
        // directly in `make_traffic`.
        for name in Traffic::builtin_names() {
            let n = *name;
            r.register_traffic(n, move |spec: &WorkloadSpec| {
                crate::workload::source::build_builtin(n, spec)
            });
        }
        // The fourth axis: cluster controllers (DESIGN.md §9). `static`
        // schedules no ticks, so it reproduces the pre-driver event stream
        // byte for byte.
        r.register_controller("static", |_cfg: &ClusterConfig| {
            Ok(Box::new(crate::cluster::StaticController)
                as Box<dyn ClusterController>)
        });
        r.register_controller("queue-threshold", |cfg: &ClusterConfig| {
            Ok(Box::new(crate::cluster::QueueThreshold::from_config(cfg))
                as Box<dyn ClusterController>)
        });
        r.register_controller("failure-replay", |cfg: &ClusterConfig| {
            Ok(Box::new(crate::cluster::FailureReplay::from_config(cfg))
                as Box<dyn ClusterController>)
        });
        r.register_controller("chaos", |cfg: &ClusterConfig| {
            Ok(Box::new(crate::cluster::ChaosController::from_config(cfg))
                as Box<dyn ClusterController>)
        });
        r
    }

    // ---- registration -----------------------------------------------------

    /// Register (or replace) a route-policy factory under `name`.
    pub fn register_route(
        &mut self,
        name: impl Into<String>,
        factory: impl Fn() -> Box<dyn RoutePolicy> + Send + Sync + 'static,
    ) {
        self.route.insert(name.into(), Arc::new(factory));
    }

    /// Register (or replace) a schedule-policy factory under `name`.
    pub fn register_sched(
        &mut self,
        name: impl Into<String>,
        factory: impl Fn() -> Box<dyn SchedulePolicy> + Send + Sync + 'static,
    ) {
        self.sched.insert(name.into(), Arc::new(factory));
    }

    /// Register (or replace) an eviction-policy factory under `name`.
    pub fn register_evict(
        &mut self,
        name: impl Into<String>,
        factory: impl Fn() -> Box<dyn EvictionPolicy> + Send + Sync + 'static,
    ) {
        self.evict.insert(name.into(), Arc::new(factory));
    }

    /// Register (or replace) a traffic-source factory under `name`.
    pub fn register_traffic(
        &mut self,
        name: impl Into<String>,
        factory: impl Fn(&WorkloadSpec) -> anyhow::Result<Box<dyn TrafficSource>>
            + Send
            + Sync
            + 'static,
    ) {
        self.traffic.insert(name.into(), Arc::new(factory));
    }

    /// Register (or replace) a cluster-controller factory under `name`.
    pub fn register_controller(
        &mut self,
        name: impl Into<String>,
        factory: impl Fn(&ClusterConfig) -> anyhow::Result<Box<dyn ClusterController>>
            + Send
            + Sync
            + 'static,
    ) {
        self.controller.insert(name.into(), Arc::new(factory));
    }

    // ---- resolution -------------------------------------------------------

    /// Instantiate the route policy registered as `name`.
    pub fn make_route(&self, name: &str) -> anyhow::Result<Box<dyn RoutePolicy>> {
        match self.route.get(name) {
            Some(f) => Ok(f()),
            None => Err(unknown("router", name, &self.route_names())),
        }
    }

    /// Instantiate the schedule policy registered as `name`.
    pub fn make_sched(&self, name: &str) -> anyhow::Result<Box<dyn SchedulePolicy>> {
        match self.sched.get(name) {
            Some(f) => Ok(f()),
            None => Err(unknown("sched", name, &self.sched_names())),
        }
    }

    /// Instantiate the eviction policy registered as `name`.
    pub fn make_evict(&self, name: &str) -> anyhow::Result<Box<dyn EvictionPolicy>> {
        match self.evict.get(name) {
            Some(f) => Ok(f()),
            None => Err(unknown("evict", name, &self.evict_names())),
        }
    }

    /// Build the traffic source for `spec`: replay resolves structurally
    /// (it carries its own path), every other kind — built-in or custom —
    /// resolves by name.
    pub fn make_traffic(
        &self,
        spec: &WorkloadSpec,
    ) -> anyhow::Result<Box<dyn TrafficSource>> {
        if matches!(spec.traffic, Traffic::Replay { .. }) {
            return crate::workload::source::build(&spec.traffic, spec);
        }
        let name = spec.traffic.kind_name();
        match self.traffic.get(name) {
            Some(f) => f(spec),
            None => Err(unknown("traffic", name, &self.traffic_names())),
        }
    }

    /// Build the cluster controller named by `cluster.controller`, handing
    /// the factory the full cluster config (thresholds, failure script).
    pub fn make_controller(
        &self,
        cluster: &ClusterConfig,
    ) -> anyhow::Result<Box<dyn ClusterController>> {
        match self.controller.get(&cluster.controller) {
            Some(f) => f(cluster),
            None => Err(unknown(
                "controller",
                &cluster.controller,
                &self.controller_names(),
            )),
        }
    }

    pub fn has_route(&self, name: &str) -> bool {
        self.route.contains_key(name)
    }
    pub fn has_sched(&self, name: &str) -> bool {
        self.sched.contains_key(name)
    }
    pub fn has_evict(&self, name: &str) -> bool {
        self.evict.contains_key(name)
    }
    pub fn has_traffic(&self, name: &str) -> bool {
        self.traffic.contains_key(name)
    }
    pub fn has_controller(&self, name: &str) -> bool {
        self.controller.contains_key(name)
    }

    // ---- validation without instantiation ---------------------------------
    // (factories may be stateful/expensive; name checks must not run them)

    /// Error (with the candidate list) unless `name` is a registered route
    /// policy.
    pub fn check_route(&self, name: &str) -> anyhow::Result<()> {
        if self.has_route(name) {
            Ok(())
        } else {
            Err(unknown("router", name, &self.route_names()))
        }
    }

    /// Error (with the candidate list) unless `name` is a registered
    /// schedule policy.
    pub fn check_sched(&self, name: &str) -> anyhow::Result<()> {
        if self.has_sched(name) {
            Ok(())
        } else {
            Err(unknown("sched", name, &self.sched_names()))
        }
    }

    /// Error (with the candidate list) unless `name` is a registered
    /// eviction policy.
    pub fn check_evict(&self, name: &str) -> anyhow::Result<()> {
        if self.has_evict(name) {
            Ok(())
        } else {
            Err(unknown("evict", name, &self.evict_names()))
        }
    }

    /// Error (with the candidate list) unless `name` is a registered
    /// traffic source. `replay` is rejected with a pointer to its
    /// structural spelling — it needs a trace path, so it cannot be
    /// selected by bare name.
    pub fn check_traffic(&self, name: &str) -> anyhow::Result<()> {
        if name == "replay" {
            anyhow::bail!(
                "traffic 'replay' needs a trace path; set the workload's \
                 traffic to {{\"kind\": \"replay\", \"path\": ...}} in a \
                 config file instead of selecting it by name"
            );
        }
        if self.has_traffic(name) {
            Ok(())
        } else {
            Err(unknown("traffic", name, &self.traffic_names()))
        }
    }

    /// Error (with the candidate list) unless `name` is a registered
    /// cluster controller.
    pub fn check_controller(&self, name: &str) -> anyhow::Result<()> {
        if self.has_controller(name) {
            Ok(())
        } else {
            Err(unknown("controller", name, &self.controller_names()))
        }
    }

    // ---- enumeration (sorted, deterministic) ------------------------------

    /// All registered route-policy names, sorted.
    pub fn route_names(&self) -> Vec<String> {
        self.route.keys().cloned().collect()
    }

    /// All registered schedule-policy names, sorted.
    pub fn sched_names(&self) -> Vec<String> {
        self.sched.keys().cloned().collect()
    }

    /// All registered eviction-policy names, sorted.
    pub fn evict_names(&self) -> Vec<String> {
        self.evict.keys().cloned().collect()
    }

    /// All registered traffic-source names, sorted.
    pub fn traffic_names(&self) -> Vec<String> {
        self.traffic.keys().cloned().collect()
    }

    /// All registered cluster-controller names, sorted.
    pub fn controller_names(&self) -> Vec<String> {
        self.controller.keys().cloned().collect()
    }
}

// ---------------------------------------------------------------------------
// Global registry
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<RwLock<PolicyRegistry>> = OnceLock::new();

/// The process-wide registry, pre-seeded with all built-ins. Configs and
/// sweep axes referring to policies by name resolve against a snapshot of
/// this unless a custom registry is supplied via
/// [`Simulation::builder`](crate::coordinator::Simulation::builder).
pub fn global() -> &'static RwLock<PolicyRegistry> {
    GLOBAL.get_or_init(|| RwLock::new(PolicyRegistry::builtins()))
}

/// A point-in-time copy of the global registry (cheap: factories are
/// `Arc`-shared). Simulations resolve against snapshots, so a concurrent
/// registration never changes a running simulation.
pub fn snapshot() -> PolicyRegistry {
    global()
        .read()
        // simlint: allow(S01) — poisoned global registry is unrecoverable; abort loudly
        .expect("policy registry lock poisoned")
        .clone()
}

/// Register a route policy in the global registry (last wins).
pub fn register_route_policy(
    name: impl Into<String>,
    factory: impl Fn() -> Box<dyn RoutePolicy> + Send + Sync + 'static,
) {
    global()
        .write()
        // simlint: allow(S01) — poisoned global registry is unrecoverable; abort loudly
        .expect("policy registry lock poisoned")
        .register_route(name, factory);
}

/// Register a schedule policy in the global registry (last wins).
pub fn register_sched_policy(
    name: impl Into<String>,
    factory: impl Fn() -> Box<dyn SchedulePolicy> + Send + Sync + 'static,
) {
    global()
        .write()
        // simlint: allow(S01) — poisoned global registry is unrecoverable; abort loudly
        .expect("policy registry lock poisoned")
        .register_sched(name, factory);
}

/// Register an eviction policy in the global registry (last wins).
pub fn register_evict_policy(
    name: impl Into<String>,
    factory: impl Fn() -> Box<dyn EvictionPolicy> + Send + Sync + 'static,
) {
    global()
        .write()
        // simlint: allow(S01) — poisoned global registry is unrecoverable; abort loudly
        .expect("policy registry lock poisoned")
        .register_evict(name, factory);
}

/// Register a traffic source in the global registry (last wins). Configs
/// select it with [`Traffic::Custom`] and sweep `--workloads` axes
/// enumerate it alongside the built-ins.
pub fn register_traffic_source(
    name: impl Into<String>,
    factory: impl Fn(&WorkloadSpec) -> anyhow::Result<Box<dyn TrafficSource>>
        + Send
        + Sync
        + 'static,
) {
    global()
        .write()
        // simlint: allow(S01) — poisoned global registry is unrecoverable; abort loudly
        .expect("policy registry lock poisoned")
        .register_traffic(name, factory);
}

/// Register a cluster controller in the global registry (last wins).
/// Configs select it with `cluster.controller` and sweep `--controllers`
/// axes enumerate it alongside the built-ins.
pub fn register_cluster_controller(
    name: impl Into<String>,
    factory: impl Fn(&ClusterConfig) -> anyhow::Result<Box<dyn ClusterController>>
        + Send
        + Sync
        + 'static,
) {
    global()
        .write()
        // simlint: allow(S01) — poisoned global registry is unrecoverable; abort loudly
        .expect("policy registry lock poisoned")
        .register_controller(name, factory);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_by_name() {
        let reg = PolicyRegistry::builtins();
        for name in reg.route_names() {
            let p = reg.make_route(&name).unwrap();
            // session-affinity reports its fallback inside the name
            assert!(
                p.name().starts_with(name.as_str()),
                "route '{}' reports '{}'",
                name,
                p.name()
            );
        }
        for name in reg.sched_names() {
            assert_eq!(reg.make_sched(&name).unwrap().name(), name);
        }
        for name in reg.evict_names() {
            assert_eq!(reg.make_evict(&name).unwrap().name(), name);
        }
    }

    #[test]
    fn unknown_names_error_with_candidates() {
        let reg = PolicyRegistry::builtins();
        let e = reg.make_route("coin-flip").unwrap_err().to_string();
        assert!(e.contains("coin-flip") && e.contains("round-robin"), "{e}");
        let e = reg.make_sched("lifo").unwrap_err().to_string();
        assert!(e.contains("lifo") && e.contains("fcfs"), "{e}");
        let e = reg.make_evict("random").unwrap_err().to_string();
        assert!(e.contains("random") && e.contains("lru"), "{e}");
    }

    #[test]
    fn enumeration_is_sorted_and_stable() {
        let reg = PolicyRegistry::builtins();
        let names = reg.route_names();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
        assert_eq!(reg.sched_names(), vec!["fcfs", "priority", "sjf", "slo"]);
        assert_eq!(reg.evict_names(), vec!["largest", "lfu", "lru"]);
        assert_eq!(
            reg.controller_names(),
            vec!["chaos", "failure-replay", "queue-threshold", "static"]
        );
        assert_eq!(
            reg.traffic_names(),
            Traffic::builtin_names()
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn builtin_traffic_resolves_and_matches_spec() {
        let reg = PolicyRegistry::builtins();
        let mut spec = crate::workload::WorkloadSpec::sharegpt_100(10.0);
        spec.num_requests = 5;
        for name in reg.traffic_names() {
            spec.traffic = Traffic::Custom { name: name.clone() };
            let mut src = reg.make_traffic(&spec).unwrap();
            assert_eq!(src.name(), name);
            assert!(src.next_request().is_some(), "{name} yields nothing");
        }
        // unknown names error with candidates; replay-by-name errors with a
        // pointer to its structural spelling (it resolves via the Traffic
        // enum, not the registry)
        spec.traffic = Traffic::Custom { name: "surge".into() };
        let e = reg.make_traffic(&spec).unwrap_err().to_string();
        assert!(e.contains("surge") && e.contains("poisson"), "{e}");
        let e = reg.check_traffic("replay").unwrap_err().to_string();
        assert!(e.contains("path"), "{e}");
        assert!(reg.check_traffic("surge").is_err());
    }

    #[test]
    fn builtin_controllers_resolve_and_unknowns_list_candidates() {
        let reg = PolicyRegistry::builtins();
        let mut cluster = crate::config::ClusterConfig::default();
        for name in reg.controller_names() {
            cluster.controller = name.clone();
            let c = reg.make_controller(&cluster).unwrap();
            assert_eq!(c.name(), name);
        }
        cluster.controller = "chaos-monkey".into();
        let e = reg.make_controller(&cluster).unwrap_err().to_string();
        assert!(
            e.contains("chaos-monkey") && e.contains("queue-threshold"),
            "{e}"
        );
        let e = reg.check_controller("chaos-monkey").unwrap_err().to_string();
        assert!(e.contains("static"), "{e}");
        assert!(reg.check_controller("failure-replay").is_ok());
    }

    #[test]
    fn custom_controller_registers_globally() {
        struct NoopController;
        impl ClusterController for NoopController {
            fn name(&self) -> &str {
                "test-noop-controller"
            }
            fn on_tick(
                &mut self,
                _now: Nanos,
                _view: &ClusterView,
            ) -> Vec<ClusterAction> {
                vec![]
            }
        }
        register_cluster_controller("test-noop-controller", |_cfg| {
            Ok(Box::new(NoopController) as Box<dyn ClusterController>)
        });
        let snap = snapshot();
        assert!(snap.has_controller("test-noop-controller"));
        let cluster = crate::config::ClusterConfig {
            controller: "test-noop-controller".into(),
            ..Default::default()
        };
        let mut c = snap.make_controller(&cluster).unwrap();
        assert!(c.wants_ticks(), "trait default: custom controllers tick");
        let view = ClusterView {
            now: 0,
            instances: vec![],
            in_flight: 0,
            finished: 0,
            arrivals: 0,
            slo_attainment: 1.0,
        };
        assert!(c.on_tick(0, &view).is_empty());
    }

    #[test]
    fn custom_traffic_registers_globally() {
        use crate::workload::{ReplaySource, Request};
        register_traffic_source("test-two-requests", |_spec| {
            Ok(Box::new(ReplaySource::from_requests(vec![
                Request {
                    id: 0,
                    prompt_tokens: 8,
                    output_tokens: 2,
                    ..Request::default()
                },
                Request {
                    id: 1,
                    arrival: 10,
                    prompt_tokens: 8,
                    output_tokens: 2,
                    ..Request::default()
                },
            ])))
        });
        let mut spec = crate::workload::WorkloadSpec::sharegpt_100(10.0);
        spec.traffic = Traffic::Custom {
            name: "test-two-requests".into(),
        };
        let reqs = spec.generate().unwrap();
        assert_eq!(reqs.len(), 2);
        assert!(snapshot().traffic_names().contains(&"test-two-requests".to_string()));
    }

    #[test]
    fn registration_replaces_and_snapshot_isolates() {
        let mut reg = PolicyRegistry::builtins();
        struct Always0;
        impl RoutePolicy for Always0 {
            fn choose(
                &mut self,
                _req: &crate::workload::Request,
                candidates: &[InstanceView],
            ) -> usize {
                candidates[0].id
            }
            fn name(&self) -> &str {
                "always-0"
            }
        }
        reg.register_route("always-0", || Box::new(Always0));
        let snap = reg.clone();
        reg.register_route("always-0", || Box::new(Always0));
        assert!(snap.has_route("always-0"));
        assert_eq!(snap.make_route("always-0").unwrap().name(), "always-0");
        // snapshot does not gain entries registered later
        reg.register_route("later", || Box::new(Always0));
        assert!(!snap.has_route("later"));
        assert!(reg.has_route("later"));
    }

    #[test]
    fn global_registration_is_visible_in_snapshots() {
        struct Noop;
        impl EvictionPolicy for Noop {
            fn name(&self) -> &str {
                "test-noop-evict"
            }
            fn pick(&mut self, _leaves: &[CacheLeaf]) -> Option<usize> {
                None
            }
        }
        register_evict_policy("test-noop-evict", || Box::new(Noop));
        let snap = snapshot();
        assert!(snap.has_evict("test-noop-evict"));
        assert!(snap
            .evict_names()
            .contains(&"test-noop-evict".to_string()));
        assert!(snap.make_evict("test-noop-evict").unwrap().pick(&[]).is_none());
    }
}
