//! The simulation coordinator: builds a full deployment from a
//! [`SimConfig`] and drives the discrete-event run loop — the Layer-3
//! composition of router, instances, prefix caches, inter-instance fabric,
//! and metrics.
//!
//! Event flow:
//! * `RequestArrival` → global router picks a prefill-capable instance →
//!   enqueue → kick the instance if idle.
//! * an idle instance with work runs `begin_step` (state advances
//!   immediately; observable effects are timestamped at step completion)
//!   and schedules `StepComplete`.
//! * `StepComplete` → record emitted tokens / finishes / prefix-cache
//!   inserts; P/D hand-offs price a KV transfer on the inter-instance
//!   fabric and schedule `KvTransferDone`; then try to start the next step.
//! * `KvTransferDone` → decode instance receives the sequence, kicks.
//!
//! The loop is fully deterministic given the config seed.

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::{CacheScope, KvTransferPolicy, PerfBackend, SimConfig};
use crate::instance::{ServingInstance, StepOutcome};
use crate::memory::PrefixCache;
use crate::metrics::{MetricsCollector, Report};
use crate::model::ModelSpec;
use crate::network::{Fabric, Topology};
use crate::perf::analytical::{Calibrated, Roofline};
use crate::perf::cycle::{CycleSim, SystolicSpec};
use crate::perf::replay::Replay;
use crate::perf::trace::TraceDb;
use crate::perf::PerfModel;
use crate::policy::{EvictionPolicy, PolicyRegistry, RoutePolicy, SchedulePolicy};
use crate::router::{GlobalRouter, InstanceView};
use crate::sim::{Event, EventQueue, Nanos};
use crate::workload::{Request, TrafficSource};

/// Build the per-instance performance model for `backend`.
///
/// For the trace backend: if the trace DB was profiled for this exact model,
/// it prices ops directly; otherwise the roofline is calibrated with the
/// DB's measured efficiency factors (tiny-model traces extended to
/// paper-scale configs — DESIGN.md §1).
///
/// For the default (analytical) backend, the instance's hardware name is
/// looked up in the global [`hardware registry`](crate::perf::hardware):
/// a registered bundle carrying profiled data prices ops through it —
/// trace interpolation where samples exist, calibrated roofline elsewhere
/// (DESIGN.md §8). Built-in presets carry no profiled data, so their
/// pricing is the pure roofline, exactly as before.
pub fn build_perf(
    backend: &PerfBackend,
    model: &ModelSpec,
    hw: &crate::perf::HardwareSpec,
) -> anyhow::Result<Arc<dyn PerfModel>> {
    Ok(match backend {
        PerfBackend::Analytical => {
            match crate::perf::hardware::bundle_for(&hw.name) {
                Some(bundle) if bundle.has_perf_data() => bundle.perf_on(hw, model),
                _ => Arc::new(Roofline::new(hw.clone(), model.clone())),
            }
        }
        PerfBackend::Cycle => {
            Arc::new(CycleSim::new(SystolicSpec::default(), model.clone()))
        }
        PerfBackend::CycleReplay => Arc::new(Replay::new(CycleSim::new(
            SystolicSpec::default(),
            model.clone(),
        ))),
        PerfBackend::Trace { path } => {
            let db = TraceDb::load(std::path::Path::new(path))?;
            if db.model == model.name {
                Arc::new(db)
            } else {
                let roof = Roofline::new(hw.clone(), model.clone());
                let cal_src = Roofline::new(
                    hw.clone(),
                    ModelSpec::preset(&db.model).ok_or_else(|| {
                        anyhow::anyhow!("trace profiled unknown model '{}'", db.model)
                    })?,
                );
                let factors = db.calibration(&cal_src);
                Arc::new(Calibrated::new(roof, factors))
            }
        }
    })
}

/// One fully-built simulation.
///
/// `Simulation` is `Send`: the whole object graph (instances with their
/// shared `Arc<dyn PerfModel>`, caches, router, event queue, metrics) can
/// move to another thread, which is what the parallel sweep engine
/// ([`crate::sweep`]) relies on. Each simulation still runs sequentially —
/// determinism comes from the event queue's total order, parallelism from
/// running many independent simulations at once.
pub struct Simulation {
    pub cfg: SimConfig,
    instances: Vec<ServingInstance>,
    /// Prefix caches; `cache_of[i]` maps instance i to its cache index.
    caches: Vec<PrefixCache>,
    cache_of: Vec<Option<usize>>,
    router: GlobalRouter,
    inter_fabric: Fabric,
    queue: EventQueue,
    metrics: MetricsCollector,
    /// Streaming request source: the run loop pulls the next request only
    /// after scheduling the previous one, so workloads of any size run in
    /// memory bounded by in-flight state (no upfront `Vec<Request>`).
    source: Box<dyn TrafficSource>,
    /// The pulled-but-not-yet-arrived head of the stream.
    next_arrival: Option<Request>,
    busy: Vec<bool>,
    pending: Vec<Option<StepOutcome>>,
    /// In-flight P/D hand-offs: req id -> (request, destination instance).
    kv_in_flight: HashMap<u64, (Request, usize)>,
    pub steps_total: u64,
}

/// Boxed perf-model factory (see [`SimulationBuilder::with_perf_factory`]).
pub type PerfFactoryFn = Box<
    dyn Fn(
        &PerfBackend,
        &ModelSpec,
        &crate::perf::HardwareSpec,
    ) -> anyhow::Result<Arc<dyn PerfModel>>,
>;

/// Staged construction of a [`Simulation`] with injectable policies.
///
/// By default every policy *name* in the config (router, per-instance
/// scheduling, prefix-cache eviction) resolves against a snapshot of the
/// [global policy registry](crate::policy::global), and perf models come
/// from [`build_perf`]. Each `with_*` method overrides one decision point
/// for this simulation only — no registration, no config enum, no core
/// edit:
///
/// ```ignore
/// let sim = Simulation::builder(cfg)
///     .with_route_policy(Box::new(MyRouter::default()))
///     .with_sched_policy(|| Box::new(MySched))
///     .with_evict_policy(|| Box::new(MyEvict))
///     .build()?;
/// ```
///
/// Scheduling/eviction overrides are factories because every instance
/// (resp. cache) needs its own policy instance — policies are stateful and
/// sharing one would couple decision points. Overrides apply uniformly to
/// all instances; per-instance heterogeneity stays name-driven via
/// [`with_registry`](SimulationBuilder::with_registry).
pub struct SimulationBuilder {
    cfg: SimConfig,
    registry: Option<PolicyRegistry>,
    route: Option<Box<dyn RoutePolicy>>,
    sched: Option<Box<dyn Fn() -> Box<dyn SchedulePolicy>>>,
    evict: Option<Box<dyn Fn() -> Box<dyn EvictionPolicy>>>,
    perf: Option<PerfFactoryFn>,
    traffic: Option<Box<dyn TrafficSource>>,
}

impl SimulationBuilder {
    /// Resolve policy names against `registry` instead of a snapshot of
    /// the global one.
    pub fn with_registry(mut self, registry: PolicyRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Use `policy` for global routing, ignoring the config's router name.
    pub fn with_route_policy(mut self, policy: Box<dyn RoutePolicy>) -> Self {
        self.route = Some(policy);
        self
    }

    /// Use `factory()` for every instance's wait-queue ordering, ignoring
    /// the config's sched names.
    pub fn with_sched_policy(
        mut self,
        factory: impl Fn() -> Box<dyn SchedulePolicy> + 'static,
    ) -> Self {
        self.sched = Some(Box::new(factory));
        self
    }

    /// Use `factory()` for every prefix cache's eviction, ignoring the
    /// config's evict names.
    pub fn with_evict_policy(
        mut self,
        factory: impl Fn() -> Box<dyn EvictionPolicy> + 'static,
    ) -> Self {
        self.evict = Some(Box::new(factory));
        self
    }

    /// Use `source` as the request stream, ignoring the config's workload
    /// traffic (the trait-object analogue of registering a custom traffic
    /// source — see [`crate::policy::register_traffic_source`]).
    pub fn with_traffic_source(mut self, source: Box<dyn TrafficSource>) -> Self {
        self.traffic = Some(source);
        self
    }

    /// Use a custom perf-model factory instead of [`build_perf`] (the
    /// ground-truth engine and ablations that pin models per instance).
    pub fn with_perf_factory(
        mut self,
        factory: impl Fn(
                &PerfBackend,
                &ModelSpec,
                &crate::perf::HardwareSpec,
            ) -> anyhow::Result<Arc<dyn PerfModel>>
            + 'static,
    ) -> Self {
        self.perf = Some(Box::new(factory));
        self
    }

    /// Validate the config, resolve every policy name exactly once, and
    /// assemble the simulation.
    pub fn build(self) -> anyhow::Result<Simulation> {
        let SimulationBuilder {
            cfg,
            registry,
            route,
            sched,
            evict,
            perf,
            traffic,
        } = self;
        cfg.validate()?;
        let registry = registry.unwrap_or_else(crate::policy::snapshot);
        let perf_factory: PerfFactoryFn =
            perf.unwrap_or_else(|| Box::new(build_perf));
        // Resolve the traffic source up front: bad replay paths and unknown
        // custom names fail here, with candidates, not mid-run.
        let source = match traffic {
            Some(s) => s,
            None => registry.make_traffic(&cfg.workload)?,
        };

        let mut instances = vec![];
        let mut caches: Vec<PrefixCache> = vec![];
        let mut cache_of = vec![];
        let mut global_cache: Option<usize> = None;

        for (i, icfg) in cfg.instances.iter().enumerate() {
            let model = icfg.model_spec()?;
            let hw = icfg.hardware_spec()?;
            let perf = perf_factory(&cfg.perf, &model, &hw)?;
            let sched_policy = match &sched {
                Some(f) => f(),
                None => registry.make_sched(&icfg.sched)?,
            };
            let inst = ServingInstance::new(
                i,
                icfg.clone(),
                perf,
                cfg.block_size,
                cfg.seed,
                sched_policy,
            )?;
            // prefix cache wiring
            let slot = match &icfg.prefix_cache {
                None => None,
                Some(pc) => {
                    let kv_capacity_tokens =
                        (inst.blocks.total_blocks() as u64) * cfg.block_size;
                    let device_tokens =
                        ((kv_capacity_tokens as f64) * pc.device_fraction).round()
                            as u64;
                    let needs_new = match pc.scope {
                        CacheScope::PerInstance => true,
                        CacheScope::Global => global_cache.is_none(),
                    };
                    if needs_new {
                        let evict_policy = match &evict {
                            Some(f) => f(),
                            None => registry.make_evict(&pc.policy)?,
                        };
                        caches.push(PrefixCache::with_policy(
                            device_tokens.max(64),
                            pc.host_tokens,
                            evict_policy,
                        ));
                        if pc.scope == CacheScope::Global {
                            global_cache = Some(caches.len() - 1);
                        }
                        Some(caches.len() - 1)
                    } else {
                        // Shared global cache already built by an earlier
                        // instance: that instance's policy wins, but this
                        // name must still resolve so typos fail the build
                        // with the candidate list rather than pass silently.
                        if evict.is_none() {
                            registry.check_evict(&pc.policy)?;
                        }
                        global_cache
                    }
                }
            };
            cache_of.push(slot);
            instances.push(inst);
        }

        let route_policy = match route {
            Some(p) => p,
            None => registry.make_route(&cfg.router)?,
        };

        let n = instances.len();
        let inter_topo =
            Topology::switched(n, cfg.inter_instance_bw, cfg.inter_instance_latency_ns);
        Ok(Simulation {
            router: GlobalRouter::new(route_policy),
            inter_fabric: Fabric::new(inter_topo),
            queue: EventQueue::new(),
            metrics: MetricsCollector::new(),
            source,
            next_arrival: None,
            busy: vec![false; n],
            pending: (0..n).map(|_| None).collect(),
            kv_in_flight: HashMap::new(),
            steps_total: 0,
            cfg,
            instances,
            caches,
            cache_of,
        })
    }
}

impl Simulation {
    /// Build a simulation from config, resolving every policy name
    /// against the global registry.
    pub fn new(cfg: SimConfig) -> anyhow::Result<Self> {
        Self::builder(cfg).build()
    }

    /// Staged construction with policy/perf injection — the single entry
    /// point for custom policies that skip the registry.
    pub fn builder(cfg: SimConfig) -> SimulationBuilder {
        SimulationBuilder {
            cfg,
            registry: None,
            route: None,
            sched: None,
            evict: None,
            perf: None,
            traffic: None,
        }
    }

    /// Router-visible views, computing the prefix match for `req` if given.
    fn views(&self, req: Option<&Request>) -> Vec<InstanceView> {
        let toks = req.map(|r| r.token_ids());
        self.instances
            .iter()
            .enumerate()
            .map(|(i, inst)| {
                let prefix_match = match (&toks, self.cache_of[i]) {
                    (Some(t), Some(c)) => self.caches[c].peek(t),
                    _ => 0,
                };
                InstanceView {
                    id: i,
                    role: inst.cfg.role,
                    outstanding: inst.outstanding(),
                    kv_utilization: inst.kv_utilization(),
                    prefix_match,
                    compatible: true,
                }
            })
            .collect()
    }

    /// Start a step on instance `i` if it is idle and has work.
    fn kick(&mut self, i: usize, now: Nanos) {
        if self.busy[i] || !self.instances[i].has_work() {
            return;
        }
        let out = match self.cache_of[i] {
            Some(c) => self.instances[i].begin_step(now, Some(&mut self.caches[c])),
            None => self.instances[i].begin_step(now, None),
        };
        if !out.work {
            return;
        }
        self.steps_total += 1;
        self.busy[i] = true;
        self.queue
            .schedule_in(out.duration, Event::StepComplete { instance: i });
        self.pending[i] = Some(out);
    }

    /// Apply a completed step's observable effects at time `now`.
    fn complete_step(&mut self, i: usize, now: Nanos) {
        let out = self.pending[i]
            .take()
            .expect("step completion without outcome");
        self.busy[i] = false;
        self.metrics.on_busy(i, out.duration);

        for (id, cached) in &out.cache_hits {
            self.metrics.on_cached(*id, *cached);
        }
        for id in &out.emitted {
            self.metrics.on_token(*id, now);
        }
        for id in &out.finished {
            self.metrics.on_finish(*id, now);
        }
        // prefix-cache inserts for finished prefills
        if let Some(c) = self.cache_of[i] {
            for req in &out.prefill_done {
                self.caches[c].insert(&req.token_ids(), now);
            }
        }
        // P/D hand-offs
        for h in &out.handoff {
            let views = self.views(None);
            let Some(dst) = self.router.pick_decode(&views) else {
                log::warn!("no decode instance for request {}", h.req.id);
                continue;
            };
            let bytes = match self.instances[i].cfg.kv_transfer {
                KvTransferPolicy::Blocking => h.kv_bytes,
                // layered transfer overlapped with prefill; only the last
                // layer's slice is exposed at completion
                KvTransferPolicy::Layered => {
                    h.kv_bytes / self.instances[i].model.layers.max(1)
                }
            };
            let done = self.inter_fabric.transfer(i, dst, bytes, now);
            self.kv_in_flight.insert(h.req.id, (h.req.clone(), dst));
            self.queue.schedule_at(
                done,
                Event::KvTransferDone {
                    request_id: h.req.id,
                    dst_instance: dst,
                },
            );
        }
        self.kick(i, now);
    }

    /// Pull the next request off the traffic source and schedule its
    /// arrival event. One request is in the "pulled, not arrived" state at
    /// a time — the streaming contract that bounds memory.
    fn prime_next_arrival(&mut self) {
        debug_assert!(self.next_arrival.is_none());
        if let Some(r) = self.source.next_request() {
            self.queue
                .schedule_at(r.arrival, Event::RequestArrival { request_id: r.id });
            self.next_arrival = Some(r);
        }
    }

    /// Run to completion and produce the report.
    pub fn run(&mut self) -> Report {
        self.prime_next_arrival();

        while let Some((now, event)) = self.queue.pop() {
            match event {
                Event::RequestArrival { request_id } => {
                    let req = self
                        .next_arrival
                        .take()
                        .expect("arrival event without a pulled request");
                    debug_assert_eq!(req.id, request_id);
                    self.metrics.on_arrival(&req, now);
                    let views = self.views(Some(&req));
                    match self.router.dispatch(&req, &views) {
                        Some(i) => {
                            self.metrics.on_dispatch(request_id, now, i);
                            self.instances[i].enqueue(req, now);
                            self.kick(i, now);
                        }
                        None => {
                            log::error!("no instance can serve request {request_id}")
                        }
                    }
                    self.prime_next_arrival();
                }
                Event::StepComplete { instance } => {
                    self.complete_step(instance, now);
                }
                Event::Wake { instance } => {
                    self.kick(instance, now);
                }
                Event::KvTransferDone {
                    request_id,
                    dst_instance,
                } => {
                    let (req, dst) = self
                        .kv_in_flight
                        .remove(&request_id)
                        .expect("unknown KV transfer");
                    debug_assert_eq!(dst, dst_instance);
                    self.instances[dst].enqueue_decoded(req, now);
                    self.kick(dst, now);
                }
                Event::ExpertFetchDone { .. } | Event::MetricsTick => {}
            }
        }

        let makespan = self.queue.now();
        let unfinished = self.metrics.num_in_flight();
        if unfinished > 0 {
            log::warn!(
                "simulation drained with {unfinished} unfinished requests \
                 (KV pool too small for the workload?)"
            );
        }
        self.metrics
            .report(makespan, &self.cfg.workload.tenant_names())
    }

    // ---- introspection ---------------------------------------------------

    pub fn num_instances(&self) -> usize {
        self.instances.len()
    }

    /// Name reported by the resolved router policy (e.g.
    /// `session-affinity(least-outstanding)` — wrappers spell out their
    /// fallback, so reports never misattribute placement).
    pub fn router_policy_name(&self) -> &str {
        self.router.policy_name()
    }

    pub fn instance(&self, i: usize) -> &ServingInstance {
        &self.instances[i]
    }

    pub fn cache_stats(&self) -> Vec<crate::memory::CacheStats> {
        self.caches.iter().map(|c| c.stats).collect()
    }

    pub fn events_processed(&self) -> u64 {
        self.queue.processed()
    }

    pub fn inter_instance_bytes(&self) -> u64 {
        self.inter_fabric.bytes_moved
    }
}

/// Convenience: build + run + report.
pub fn run_config(cfg: SimConfig) -> anyhow::Result<(Report, SimSummary)> {
    let mut sim = Simulation::new(cfg)?;
    let report = sim.run();
    let summary = SimSummary {
        steps: sim.steps_total,
        events: sim.events_processed(),
        cache_stats: sim.cache_stats(),
        inter_instance_bytes: sim.inter_instance_bytes(),
    };
    Ok((report, summary))
}

/// Simulator-internal counters (Fig. 3 cost accounting).
#[derive(Debug, Clone)]
pub struct SimSummary {
    pub steps: u64,
    pub events: u64,
    pub cache_stats: Vec<crate::memory::CacheStats>,
    pub inter_instance_bytes: u64,
}

// Compile-time guarantee that the simulation core stays thread-movable;
// losing `Send` here would silently break the sweep engine.
#[allow(dead_code)]
fn assert_core_is_send() {
    fn assert_send<T: Send>() {}
    assert_send::<Simulation>();
    assert_send::<crate::metrics::Report>();
    assert_send::<SimSummary>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn small(mut cfg: SimConfig) -> SimConfig {
        cfg.workload.num_requests = 20;
        cfg.workload.lengths = crate::workload::LengthDist::short();
        cfg
    }

    #[test]
    fn single_instance_dense_completes() {
        let cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        let (report, summary) = run_config(cfg).unwrap();
        assert_eq!(report.num_finished, 20);
        assert!(report.throughput_tps > 0.0);
        assert!(report.ttft_ns.mean > 0.0);
        assert!(summary.steps > 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        let (a, sa) = run_config(cfg.clone()).unwrap();
        let (b, sb) = run_config(cfg).unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert_eq!(sa.steps, sb.steps);
        assert!((a.tpot_ns.mean - b.tpot_ns.mean).abs() < 1e-9);
    }

    #[test]
    fn moe_single_instance_completes() {
        let cfg = small(presets::single_moe("tiny-moe", "rtx3090"));
        let (report, _) = run_config(cfg).unwrap();
        assert_eq!(report.num_finished, 20);
    }

    #[test]
    fn multi_instance_spreads_load() {
        let mut cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        // burst arrivals force queueing so least-outstanding actually spreads
        cfg.workload.traffic = crate::workload::Traffic::burst();
        let mut sim = Simulation::new(cfg).unwrap();
        let report = sim.run();
        assert_eq!(report.num_finished, 20);
        // both instances must have done work under least-outstanding routing
        assert!(report.utilization.get(&0).copied().unwrap_or(0.0) > 0.0);
        assert!(report.utilization.get(&1).copied().unwrap_or(0.0) > 0.0);
    }

    #[test]
    fn pd_disaggregation_completes_with_transfers() {
        let cfg = small(presets::pd_dense("tiny-dense", "rtx3090"));
        let mut sim = Simulation::new(cfg).unwrap();
        let report = sim.run();
        assert_eq!(report.num_finished, 20);
        assert!(
            sim.inter_instance_bytes() > 0,
            "P/D must move KV across instances"
        );
    }

    #[test]
    fn pd_layered_transfer_moves_fewer_exposed_bytes() {
        let mk = |policy| {
            let mut cfg = small(presets::pd_dense("tiny-dense", "rtx3090"));
            for i in &mut cfg.instances {
                i.kv_transfer = policy;
            }
            let mut sim = Simulation::new(cfg).unwrap();
            let r = sim.run();
            (r, sim.inter_instance_bytes())
        };
        let (_, blocking_bytes) = mk(KvTransferPolicy::Blocking);
        let (_, layered_bytes) = mk(KvTransferPolicy::Layered);
        assert!(
            layered_bytes < blocking_bytes,
            "layered {layered_bytes} !< blocking {blocking_bytes}"
        );
    }

    #[test]
    fn prefix_cache_improves_ttft() {
        let base = small(presets::single_dense("tiny-dense", "rtx3090"));
        let mut with_pc = presets::with_prefix_cache(
            base.clone(),
            crate::config::CacheScope::PerInstance,
        );
        // identical workload apart from prefix sharing
        let mut base_shared = base.clone();
        base_shared.workload.sessions = 10;
        base_shared.workload.shared_prefix = 64;
        with_pc.workload = base_shared.workload.clone();

        let (cold, _) = run_config(base_shared).unwrap();
        let (warm, summary) = run_config(with_pc).unwrap();
        assert_eq!(cold.num_finished, warm.num_finished);
        assert!(summary.cache_stats[0].hit_rate() > 0.0);
        assert!(
            warm.ttft_ns.mean < cold.ttft_ns.mean,
            "PC TTFT {} !< no-PC TTFT {}",
            warm.ttft_ns.mean,
            cold.ttft_ns.mean
        );
    }

    #[test]
    fn global_cache_shared_across_instances() {
        let cfg = small(presets::with_prefix_cache(
            presets::multi_dense("tiny-dense", "rtx3090"),
            crate::config::CacheScope::Global,
        ));
        let mut sim = Simulation::new(cfg).unwrap();
        let report = sim.run();
        assert!(report.num_finished > 0);
        assert_eq!(sim.cache_stats().len(), 1, "global scope = one cache");
    }

    #[test]
    fn all_fig3_configs_run() {
        for cfg in presets::fig3_configs("tiny-dense", "tiny-moe", "rtx3090") {
            let name = cfg.name.clone();
            let (report, _) = run_config(small(cfg)).unwrap();
            assert_eq!(report.num_finished, 20, "config {name}");
        }
    }

    #[test]
    fn simulation_moves_across_threads() {
        // The tentpole property behind the sweep engine: a fully-built
        // simulation is Send and produces the same report on a foreign
        // thread as on the building thread.
        let cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        let (home, _) = run_config(cfg.clone()).unwrap();
        let mut sim = Simulation::new(cfg).unwrap();
        let away = std::thread::spawn(move || sim.run()).join().unwrap();
        assert_eq!(home.makespan, away.makespan);
        assert_eq!(home.generated_tokens, away.generated_tokens);
        assert_eq!(
            home.to_json().to_string(),
            away.to_json().to_string(),
            "thread migration must not perturb the report"
        );
    }

    #[test]
    fn multi_tenant_bursty_reports_breakdowns() {
        use crate::workload::{SloClass, TenantSpec, Traffic};
        let mut cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        cfg.workload.num_requests = 40;
        cfg.workload.traffic = Traffic::mmpp(80.0, 0.0, 1.0, 3.0);
        cfg.workload.tenants = TenantSpec::mix(3);
        for i in &mut cfg.instances {
            i.sched = "slo".to_string();
        }
        let (report, _) = run_config(cfg).unwrap();
        assert_eq!(report.num_finished, 40);
        assert!(!report.per_tenant.is_empty());
        assert!(!report.per_class.is_empty());
        let finished: usize = report.per_tenant.iter().map(|t| t.num_finished).sum();
        assert_eq!(finished, 40, "tenant partition must cover all requests");
        let by_class: usize = report.per_class.iter().map(|c| c.num_finished).sum();
        assert_eq!(by_class, 40);
        assert!(report.goodput_tps <= report.throughput_tps + 1e-9);
        assert!(report
            .per_class
            .iter()
            .any(|c| c.class == SloClass::Batch));
    }

    #[test]
    fn custom_traffic_source_injects_via_builder() {
        use crate::workload::{ReplaySource, Traffic};
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        // the config names an unregistered source, but the builder override
        // wins, mirroring the policy-override contract
        cfg.workload.traffic = Traffic::Custom {
            name: "not-registered".into(),
        };
        let reqs = {
            let mut spec = cfg.workload.clone();
            spec.traffic = Traffic::burst();
            spec.num_requests = 8;
            spec.generate().unwrap()
        };
        let mut sim = Simulation::builder(cfg)
            .with_traffic_source(Box::new(ReplaySource::from_requests(reqs)))
            .build()
            .unwrap();
        let report = sim.run();
        assert_eq!(report.num_finished, 8);
        // and without the override, the unknown name fails with candidates
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.workload.traffic = Traffic::Custom {
            name: "not-registered".into(),
        };
        let e = Simulation::new(cfg).unwrap_err().to_string();
        assert!(e.contains("not-registered") && e.contains("poisson"), "{e}");
    }

    #[test]
    fn cycle_backend_runs() {
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.workload.num_requests = 5;
        cfg.perf = PerfBackend::Cycle;
        let (report, _) = run_config(cfg).unwrap();
        assert_eq!(report.num_finished, 5);
    }

    #[test]
    fn unknown_policy_names_fail_at_build_with_candidates() {
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.router = "coin-flip".to_string();
        let e = Simulation::new(cfg).unwrap_err().to_string();
        assert!(e.contains("coin-flip") && e.contains("round-robin"), "{e}");

        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.instances[0].sched = "lifo".to_string();
        let e = Simulation::new(cfg).unwrap_err().to_string();
        assert!(e.contains("lifo") && e.contains("fcfs"), "{e}");

        let mut cfg = small(presets::with_prefix_cache(
            presets::single_dense("tiny-dense", "rtx3090"),
            crate::config::CacheScope::PerInstance,
        ));
        cfg.instances[0].prefix_cache.as_mut().unwrap().policy =
            "random".to_string();
        let e = Simulation::new(cfg).unwrap_err().to_string();
        assert!(e.contains("random") && e.contains("lru"), "{e}");

        // Global scope: instances after the cache-creating one share the
        // first instance's cache, but their evict names must still resolve.
        let mut cfg = small(presets::with_prefix_cache(
            presets::multi_dense("tiny-dense", "rtx3090"),
            crate::config::CacheScope::Global,
        ));
        cfg.instances[1].prefix_cache.as_mut().unwrap().policy =
            "bogus".to_string();
        let e = Simulation::new(cfg).unwrap_err().to_string();
        assert!(e.contains("bogus") && e.contains("lru"), "{e}");
    }

    #[test]
    fn builder_overrides_skip_name_resolution() {
        // Policies injected through the builder win over config names, so
        // unregistered names are fine when every slot is overridden.
        use crate::policy::{CacheLeaf, EvictionPolicy, SchedulePolicy};
        use crate::router::{InstanceView, RoutePolicy};

        struct FirstFit;
        impl RoutePolicy for FirstFit {
            fn choose(
                &mut self,
                _req: &crate::workload::Request,
                candidates: &[InstanceView],
            ) -> usize {
                candidates[0].id
            }
            fn name(&self) -> &str {
                "first-fit"
            }
        }
        struct ReverseId;
        impl SchedulePolicy for ReverseId {
            fn name(&self) -> &str {
                "reverse-id"
            }
            fn order(
                &mut self,
                wait: &mut [u64],
                _seqs: &std::collections::HashMap<u64, crate::instance::SeqState>,
                _now: Nanos,
            ) {
                wait.sort_by_key(|id| std::cmp::Reverse(*id));
            }
        }
        struct EvictAll;
        impl EvictionPolicy for EvictAll {
            fn name(&self) -> &str {
                "evict-first"
            }
            fn pick(&mut self, leaves: &[CacheLeaf]) -> Option<usize> {
                leaves.first().map(|l| l.id)
            }
        }

        let mut cfg = small(presets::with_prefix_cache(
            presets::multi_dense("tiny-dense", "rtx3090"),
            crate::config::CacheScope::PerInstance,
        ));
        cfg.router = "not-registered".to_string();
        for i in &mut cfg.instances {
            i.sched = "not-registered".to_string();
            i.prefix_cache.as_mut().unwrap().policy = "not-registered".to_string();
        }
        let mut sim = Simulation::builder(cfg)
            .with_route_policy(Box::new(FirstFit))
            .with_sched_policy(|| Box::new(ReverseId))
            .with_evict_policy(|| Box::new(EvictAll))
            .build()
            .unwrap();
        assert_eq!(sim.router_policy_name(), "first-fit");
        assert_eq!(sim.instance(0).sched_name(), "reverse-id");
        let report = sim.run();
        assert_eq!(report.num_finished, 20);
    }

    #[test]
    fn session_affinity_reports_wrapped_name() {
        let mut cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        cfg.router = "session-affinity".to_string();
        cfg.workload.sessions = 5;
        let mut sim = Simulation::new(cfg).unwrap();
        assert_eq!(
            sim.router_policy_name(),
            "session-affinity(least-outstanding)"
        );
        let report = sim.run();
        assert_eq!(report.num_finished, 20);
    }
}
