//! The simulation coordinator: builds a full deployment from a
//! [`SimConfig`] and drives the discrete-event run loop — the Layer-3
//! composition of router, instances, prefix caches, inter-instance fabric,
//! and metrics.
//!
//! Event flow:
//! * `RequestArrival` → global router picks a prefill-capable instance →
//!   enqueue → kick the instance if idle.
//! * an idle instance with work runs `begin_step` (state advances
//!   immediately; observable effects are timestamped at step completion)
//!   and schedules `StepComplete`.
//! * `StepComplete` → record emitted tokens / finishes / prefix-cache
//!   inserts; P/D hand-offs price a KV transfer on the inter-instance
//!   fabric and schedule `KvTransferDone`; then try to start the next step.
//! * `KvTransferDone` → decode instance receives the sequence, kicks.
//!
//! The loop is fully deterministic given the config seed.

use std::sync::Arc;

use crate::cluster::{
    ClusterAction, ClusterController, ClusterView, InstanceSnapshot, Lifecycle,
    TimelineEntry,
};
use crate::config::{
    AdmissionConfig, CacheScope, InstanceConfig, KvTransferPolicy, PerfBackend,
    Role, SimConfig,
};
use crate::instance::{KvHandoff, ServingInstance, StepOutcome};
use crate::memory::PrefixCache;
use crate::metrics::{MetricsCollector, Report};
use crate::model::ModelSpec;
use crate::network::{Fabric, Topology};
use crate::perf::analytical::{Calibrated, Roofline};
use crate::perf::cycle::{CycleSim, SystolicSpec};
use crate::perf::replay::Replay;
use crate::perf::trace::TraceDb;
use crate::perf::PerfModel;
use crate::policy::{EvictionPolicy, PolicyRegistry, RoutePolicy, SchedulePolicy};
use crate::router::{GlobalRouter, InstanceView};
use crate::sim::{Event, EventQueue, Nanos, MILLI};
use crate::util::fxhash::FxHashMap;
use crate::workload::{Request, TrafficSource};

/// Build the per-instance performance model for `backend`.
///
/// For the trace backend: if the trace DB was profiled for this exact model,
/// it prices ops directly; otherwise the roofline is calibrated with the
/// DB's measured efficiency factors (tiny-model traces extended to
/// paper-scale configs — DESIGN.md §1).
///
/// For the default (analytical) backend, the instance's hardware name is
/// looked up in the global [`hardware registry`](crate::perf::hardware):
/// a registered bundle carrying profiled data prices ops through it —
/// trace interpolation where samples exist, calibrated roofline elsewhere
/// (DESIGN.md §8). Built-in presets carry no profiled data, so their
/// pricing is the pure roofline, exactly as before.
pub fn build_perf(
    backend: &PerfBackend,
    model: &ModelSpec,
    hw: &crate::perf::HardwareSpec,
) -> anyhow::Result<Arc<dyn PerfModel>> {
    Ok(match backend {
        PerfBackend::Analytical => {
            match crate::perf::hardware::bundle_for(&hw.name) {
                Some(bundle) if bundle.has_perf_data() => bundle.perf_on(hw, model),
                _ => Arc::new(Roofline::new(hw.clone(), model.clone())),
            }
        }
        PerfBackend::Cycle => {
            Arc::new(CycleSim::new(SystolicSpec::default(), model.clone()))
        }
        PerfBackend::CycleReplay => Arc::new(Replay::new(CycleSim::new(
            SystolicSpec::default(),
            model.clone(),
        ))),
        PerfBackend::Trace { path } => {
            let db = TraceDb::load(std::path::Path::new(path))?;
            if db.model == model.name {
                Arc::new(db)
            } else {
                let roof = Roofline::new(hw.clone(), model.clone());
                let cal_src = Roofline::new(
                    hw.clone(),
                    ModelSpec::preset(&db.model).ok_or_else(|| {
                        anyhow::anyhow!("trace profiled unknown model '{}'", db.model)
                    })?,
                );
                let factors = db.calibration(&cal_src);
                Arc::new(Calibrated::new(roof, factors))
            }
        }
    })
}

/// One fully-built simulation.
///
/// `Simulation` is `Send`: the whole object graph (instances with their
/// shared `Arc<dyn PerfModel>`, caches, router, event queue, metrics) can
/// move to another thread, which is what the parallel sweep engine
/// ([`crate::sweep`]) relies on. Each simulation still runs sequentially —
/// determinism comes from the event queue's total order, parallelism from
/// running many independent simulations at once.
pub struct Simulation {
    pub cfg: SimConfig,
    instances: Vec<ServingInstance>,
    /// Prefix caches; `cache_of[i]` maps instance i to its cache index.
    caches: Vec<PrefixCache>,
    cache_of: Vec<Option<usize>>,
    /// Index of the shared global-scope cache, if one was built.
    global_cache: Option<usize>,
    router: GlobalRouter,
    inter_fabric: Fabric,
    queue: EventQueue,
    metrics: MetricsCollector,
    /// Streaming request source: the run loop pulls the next request only
    /// after scheduling the previous one, so workloads of any size run in
    /// memory bounded by in-flight state (no upfront `Vec<Request>`).
    source: Box<dyn TrafficSource>,
    /// The pulled-but-not-yet-arrived head of the stream.
    next_arrival: Option<Request>,
    busy: Vec<bool>,
    /// In-flight step per instance: (completion time, outcome). The time
    /// lets a `StepComplete` from *before* a failure be told apart from
    /// the completion of a step started after recovery.
    pending: Vec<Option<(Nanos, StepOutcome)>>,
    /// In-flight P/D hand-offs: req id -> (request, destination instance).
    /// The request is *moved* here from the prefill instance's handoff (it
    /// lives nowhere else until `KvTransferDone` delivers it), and the map
    /// uses the deterministic Fx hasher — keys are trusted request ids.
    kv_in_flight: FxHashMap<u64, (Request, usize)>,
    /// Requests displaced by a drain/failure with no dispatchable target
    /// yet; retried (in id order) whenever an instance turns `Active`.
    parked: Vec<Request>,
    /// P/D hand-offs whose every decode target is partitioned away
    /// (`src`, hand-off); retried when the fabric heals or an instance
    /// turns `Active`, in blocked order.
    blocked_handoffs: Vec<(usize, KvHandoff)>,
    /// Reused buffer for router-visible instance views (refilled by
    /// `fill_views` on every dispatch instead of allocating a `Vec`).
    views_scratch: Vec<InstanceView>,
    /// Reused token-id buffer for prefix-match routing and cache inserts.
    tok_scratch: Vec<u32>,
    pub steps_total: u64,
    // ---- cluster-dynamics plumbing (DESIGN.md §9) ----
    /// Registry snapshot kept for resolving policies of scaled-up
    /// instances exactly like the initial fleet's.
    registry: PolicyRegistry,
    perf_factory: PerfFactoryFn,
    sched_override: Option<SchedFactoryFn>,
    evict_override: Option<EvictFactoryFn>,
    controller: Box<dyn ClusterController>,
    /// Controller tick period (ns); ticks are only scheduled when the
    /// controller `wants_ticks()`.
    tick: Nanos,
    /// Warmup before a scaled-up/recovered instance turns `Active` (ns).
    warmup: Nanos,
    timeline: Vec<TimelineEntry>,
    /// Fleet-size sample entries recorded so far (bounded).
    samples: u64,
    peak_active: usize,
    /// Count of instances added by `ScaleUp` (for deterministic naming).
    scaled: usize,
    /// Token-bucket + circuit-breaker arrival admission (`None` = admit
    /// everything, today's behavior).
    admission: Option<AdmissionState>,
    /// Replay log of chaos fabric mutations, reapplied when `scale_up`
    /// rebuilds the inter-instance fabric — a mid-incident scale-up must
    /// not silently heal degradations or partitions.
    fabric_mods: Vec<FabricMod>,
    /// Per-instance open fault window start (`None` = healthy). Opened by
    /// `fail_instance`, closed when the recovered instance turns `Active`.
    down_since: Vec<Option<Nanos>>,
    /// Per-instance accumulated downtime from closed fault windows.
    downtime: Vec<Nanos>,
    started: bool,
}

/// Runtime token-bucket + circuit-breaker state for arrival admission
/// (`cluster.admission` — DESIGN.md §12). The bucket refills lazily at
/// arrival time; the breaker opens on fleet-wide queue depth and stays
/// open for a cooldown.
struct AdmissionState {
    cfg: AdmissionConfig,
    tokens: f64,
    last_refill: Nanos,
    /// Breaker-open horizon; arrivals before this instant are rejected.
    open_until: Nanos,
}

/// One chaos fabric mutation, replayed onto fabrics rebuilt by `scale_up`.
#[derive(Debug, Clone)]
enum FabricMod {
    Degrade { device: usize, scale: f64 },
    Isolate { device: usize },
}

/// Cap on `"sample"` timeline entries so hour-long simulations cannot grow
/// the report without bound; action and transition entries are never
/// dropped.
const SAMPLE_CAP: u64 = 8192;

/// Boxed perf-model factory (see [`SimulationBuilder::with_perf_factory`]).
/// `Send` because the simulation keeps it for pricing scaled-up instances
/// and must stay thread-movable for the sweep engine.
pub type PerfFactoryFn = Box<
    dyn Fn(
            &PerfBackend,
            &ModelSpec,
            &crate::perf::HardwareSpec,
        ) -> anyhow::Result<Arc<dyn PerfModel>>
        + Send,
>;
/// Boxed schedule-policy factory kept for scaled-up instances.
pub type SchedFactoryFn = Box<dyn Fn() -> Box<dyn SchedulePolicy> + Send>;
/// Boxed eviction-policy factory kept for scaled-up instances.
pub type EvictFactoryFn = Box<dyn Fn() -> Box<dyn EvictionPolicy> + Send>;

/// Staged construction of a [`Simulation`] with injectable policies.
///
/// By default every policy *name* in the config (router, per-instance
/// scheduling, prefix-cache eviction) resolves against a snapshot of the
/// [global policy registry](crate::policy::global), and perf models come
/// from [`build_perf`]. Each `with_*` method overrides one decision point
/// for this simulation only — no registration, no config enum, no core
/// edit:
///
/// ```ignore
/// let sim = Simulation::builder(cfg)
///     .with_route_policy(Box::new(MyRouter::default()))
///     .with_sched_policy(|| Box::new(MySched))
///     .with_evict_policy(|| Box::new(MyEvict))
///     .build()?;
/// ```
///
/// Scheduling/eviction overrides are factories because every instance
/// (resp. cache) needs its own policy instance — policies are stateful and
/// sharing one would couple decision points. Overrides apply uniformly to
/// all instances; per-instance heterogeneity stays name-driven via
/// [`with_registry`](SimulationBuilder::with_registry).
pub struct SimulationBuilder {
    cfg: SimConfig,
    registry: Option<PolicyRegistry>,
    route: Option<Box<dyn RoutePolicy>>,
    sched: Option<SchedFactoryFn>,
    evict: Option<EvictFactoryFn>,
    perf: Option<PerfFactoryFn>,
    traffic: Option<Box<dyn TrafficSource>>,
    controller: Option<Box<dyn ClusterController>>,
}

impl SimulationBuilder {
    /// Resolve policy names against `registry` instead of a snapshot of
    /// the global one.
    pub fn with_registry(mut self, registry: PolicyRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Use `policy` for global routing, ignoring the config's router name.
    pub fn with_route_policy(mut self, policy: Box<dyn RoutePolicy>) -> Self {
        self.route = Some(policy);
        self
    }

    /// Use `factory()` for every instance's wait-queue ordering, ignoring
    /// the config's sched names. `Send` because the factory is kept for
    /// instances a cluster controller scales up mid-run.
    pub fn with_sched_policy(
        mut self,
        factory: impl Fn() -> Box<dyn SchedulePolicy> + Send + 'static,
    ) -> Self {
        self.sched = Some(Box::new(factory));
        self
    }

    /// Use `factory()` for every prefix cache's eviction, ignoring the
    /// config's evict names.
    pub fn with_evict_policy(
        mut self,
        factory: impl Fn() -> Box<dyn EvictionPolicy> + Send + 'static,
    ) -> Self {
        self.evict = Some(Box::new(factory));
        self
    }

    /// Use `controller` for cluster dynamics, ignoring the config's
    /// `cluster.controller` name (the trait-object analogue of
    /// [`crate::policy::register_cluster_controller`]).
    pub fn with_controller(mut self, controller: Box<dyn ClusterController>) -> Self {
        self.controller = Some(controller);
        self
    }

    /// Use `source` as the request stream, ignoring the config's workload
    /// traffic (the trait-object analogue of registering a custom traffic
    /// source — see [`crate::policy::register_traffic_source`]).
    pub fn with_traffic_source(mut self, source: Box<dyn TrafficSource>) -> Self {
        self.traffic = Some(source);
        self
    }

    /// Use a custom perf-model factory instead of [`build_perf`] (the
    /// ground-truth engine and ablations that pin models per instance).
    pub fn with_perf_factory(
        mut self,
        factory: impl Fn(
                &PerfBackend,
                &ModelSpec,
                &crate::perf::HardwareSpec,
            ) -> anyhow::Result<Arc<dyn PerfModel>>
            + Send
            + 'static,
    ) -> Self {
        self.perf = Some(Box::new(factory));
        self
    }

    /// Validate the config, resolve every policy name exactly once, and
    /// assemble the simulation.
    pub fn build(self) -> anyhow::Result<Simulation> {
        let SimulationBuilder {
            cfg,
            registry,
            route,
            sched,
            evict,
            perf,
            traffic,
            controller,
        } = self;
        cfg.validate()?;
        let registry = registry.unwrap_or_else(crate::policy::snapshot);
        let perf_factory: PerfFactoryFn =
            perf.unwrap_or_else(|| Box::new(build_perf));
        // Resolve the traffic source up front: bad replay paths and unknown
        // custom names fail here, with candidates, not mid-run.
        let source = match traffic {
            Some(s) => s,
            None => registry.make_traffic(&cfg.workload)?,
        };
        // Same for the cluster controller (the fourth axis): an unknown
        // `cluster.controller` name fails the build with the candidates.
        let controller = match controller {
            Some(c) => c,
            None => registry.make_controller(&cfg.cluster)?,
        };

        let mut instances = vec![];
        let mut caches: Vec<PrefixCache> = vec![];
        let mut cache_of = vec![];
        let mut global_cache: Option<usize> = None;

        for (i, icfg) in cfg.instances.iter().enumerate() {
            let (inst, slot) = build_instance(
                icfg,
                i,
                &cfg.perf,
                cfg.block_size,
                cfg.seed,
                &registry,
                &perf_factory,
                sched.as_ref(),
                evict.as_ref(),
                &mut caches,
                &mut global_cache,
            )?;
            cache_of.push(slot);
            instances.push(inst);
        }

        let route_policy = match route {
            Some(p) => p,
            None => registry.make_route(&cfg.router)?,
        };

        let n = instances.len();
        let inter_topo =
            Topology::switched(n, cfg.inter_instance_bw, cfg.inter_instance_latency_ns);
        let tick = cfg.cluster.tick_ms * MILLI;
        let warmup = cfg.cluster.warmup_ms * MILLI;
        Ok(Simulation {
            router: GlobalRouter::new(route_policy),
            inter_fabric: Fabric::new(inter_topo),
            queue: EventQueue::new(),
            metrics: MetricsCollector::new(),
            source,
            next_arrival: None,
            busy: vec![false; n],
            pending: (0..n).map(|_| None).collect(),
            kv_in_flight: FxHashMap::default(),
            parked: vec![],
            blocked_handoffs: vec![],
            views_scratch: vec![],
            tok_scratch: vec![],
            steps_total: 0,
            registry,
            perf_factory,
            sched_override: sched,
            evict_override: evict,
            controller,
            tick,
            warmup,
            timeline: vec![],
            samples: 0,
            peak_active: n,
            scaled: 0,
            admission: cfg.cluster.admission.clone().map(|a| AdmissionState {
                tokens: a.burst,
                last_refill: 0,
                open_until: 0,
                cfg: a,
            }),
            fabric_mods: vec![],
            down_since: vec![None; n],
            downtime: vec![0; n],
            started: false,
            cfg,
            instances,
            caches,
            cache_of,
            global_cache,
        })
    }
}

/// Build one serving instance and wire its prefix cache, resolving the
/// scheduling/eviction policies exactly like the initial-fleet path.
/// Shared by [`SimulationBuilder::build`] and `ScaleUp` (so scaled-up
/// instances behave byte-for-byte like configured ones).
#[allow(clippy::too_many_arguments)]
fn build_instance(
    icfg: &InstanceConfig,
    id: usize,
    perf_backend: &PerfBackend,
    block_size: u64,
    seed: u64,
    registry: &PolicyRegistry,
    perf_factory: &PerfFactoryFn,
    sched_override: Option<&SchedFactoryFn>,
    evict_override: Option<&EvictFactoryFn>,
    caches: &mut Vec<PrefixCache>,
    global_cache: &mut Option<usize>,
) -> anyhow::Result<(ServingInstance, Option<usize>)> {
    let model = icfg.model_spec()?;
    let hw = icfg.hardware_spec()?;
    let perf = perf_factory(perf_backend, &model, &hw)?;
    let sched_policy = match sched_override {
        Some(f) => f(),
        None => registry.make_sched(&icfg.sched)?,
    };
    let inst =
        ServingInstance::new(id, icfg.clone(), perf, block_size, seed, sched_policy)?;
    // prefix cache wiring
    let slot = match &icfg.prefix_cache {
        None => None,
        Some(pc) => {
            let kv_capacity_tokens = inst.blocks.total_blocks() as u64 * block_size;
            let device_tokens =
                ((kv_capacity_tokens as f64) * pc.device_fraction).round() as u64;
            let needs_new = match pc.scope {
                CacheScope::PerInstance => true,
                CacheScope::Global => global_cache.is_none(),
            };
            if needs_new {
                let evict_policy = match evict_override {
                    Some(f) => f(),
                    None => registry.make_evict(&pc.policy)?,
                };
                caches.push(PrefixCache::with_policy(
                    device_tokens.max(64),
                    pc.host_tokens,
                    evict_policy,
                ));
                if pc.scope == CacheScope::Global {
                    *global_cache = Some(caches.len() - 1);
                }
                Some(caches.len() - 1)
            } else {
                // Shared global cache already built by an earlier
                // instance: that instance's policy wins, but this
                // name must still resolve so typos fail the build
                // with the candidate list rather than pass silently.
                if evict_override.is_none() {
                    registry.check_evict(&pc.policy)?;
                }
                *global_cache
            }
        }
    };
    Ok((inst, slot))
}

impl Simulation {
    /// Build a simulation from config, resolving every policy name
    /// against the global registry.
    pub fn new(cfg: SimConfig) -> anyhow::Result<Self> {
        Self::builder(cfg).build()
    }

    /// Staged construction with policy/perf injection — the single entry
    /// point for custom policies that skip the registry.
    pub fn builder(cfg: SimConfig) -> SimulationBuilder {
        SimulationBuilder {
            cfg,
            registry: None,
            route: None,
            sched: None,
            evict: None,
            perf: None,
            traffic: None,
            controller: None,
        }
    }

    /// Refill `views_scratch` with router-visible views, computing the
    /// prefix match for `req` if given. Only `Active` instances are marked
    /// compatible — `Starting`, `Draining`, and `Stopped` instances never
    /// receive new requests.
    fn fill_views(&mut self, req: Option<&Request>) {
        // Token ids only matter when some instance has a prefix cache
        // (`cache_of` is all-None otherwise and every prefix_match is 0);
        // skipping the fill avoids materializing ids on every arrival of
        // cache-less presets.
        let mut use_toks = false;
        if let Some(r) = req {
            if !self.caches.is_empty() {
                r.fill_token_ids(&mut self.tok_scratch);
                use_toks = true;
            }
        }
        self.views_scratch.clear();
        for (i, inst) in self.instances.iter().enumerate() {
            let prefix_match = match self.cache_of[i] {
                Some(c) if use_toks => self.caches[c].peek(&self.tok_scratch),
                _ => 0,
            };
            self.views_scratch.push(InstanceView {
                id: i,
                role: inst.cfg.role,
                outstanding: inst.outstanding(),
                kv_utilization: inst.kv_utilization(),
                prefix_match,
                compatible: inst.lifecycle().is_active(),
            });
        }
    }

    /// Start a step on instance `i` if it is idle and has work. `Draining`
    /// instances keep stepping (they must finish their running batch);
    /// `Starting`/`Stopped` instances never step.
    fn kick(&mut self, i: usize, now: Nanos) {
        if !self.instances[i].lifecycle().can_run() {
            return;
        }
        if self.busy[i] || !self.instances[i].has_work() {
            return;
        }
        let out = match self.cache_of[i] {
            Some(c) => self.instances[i].begin_step(now, Some(&mut self.caches[c])),
            None => self.instances[i].begin_step(now, None),
        };
        if !out.work {
            return;
        }
        self.steps_total += 1;
        self.busy[i] = true;
        let due = now.saturating_add(out.duration);
        self.queue
            .schedule_in(out.duration, Event::StepComplete { instance: i });
        self.pending[i] = Some((due, out));
    }

    /// Apply a completed step's observable effects at time `now`.
    fn complete_step(&mut self, i: usize, now: Nanos) {
        let (_, mut out) = self.pending[i]
            .take()
            // simlint: allow(S01) — complete_step only fires for instances with a pending outcome
            .expect("step completion without outcome");
        self.busy[i] = false;
        self.metrics.on_busy(i, out.duration);

        for (id, cached) in &out.cache_hits {
            self.metrics.on_cached(*id, *cached);
        }
        for id in &out.emitted {
            self.metrics.on_token(*id, now);
        }
        for id in &out.finished {
            self.metrics.on_finish(*id, now);
        }
        // prefix-cache inserts for finished prefills
        if let Some(c) = self.cache_of[i] {
            for req in &out.prefill_done {
                req.fill_token_ids(&mut self.tok_scratch);
                self.caches[c].insert(&self.tok_scratch, now);
            }
        }
        // P/D hand-offs: each request moves out of the outcome and into
        // the in-flight map — the prefill instance already dropped it, so
        // no clone is needed anywhere on this path.
        for h in out.handoff.drain(..) {
            self.route_handoff(i, h, now);
        }
        // Hand the spent outcome back so the next step reuses its buffers.
        self.instances[i].recycle_outcome(out);
        self.kick(i, now);
        self.maybe_finish_drain(i, now);
    }

    /// Pull the next request off the traffic source and schedule its
    /// arrival event. One request is in the "pulled, not arrived" state at
    /// a time — the streaming contract that bounds memory.
    fn prime_next_arrival(&mut self) {
        debug_assert!(self.next_arrival.is_none());
        if let Some(r) = self.source.next_request() {
            self.queue
                .schedule_at(r.arrival, Event::RequestArrival { request_id: r.id });
            self.next_arrival = Some(r);
        }
    }

    /// Run to completion and produce the report — a thin wrapper over the
    /// stepped [`SimDriver`] (`driver().finish()`).
    pub fn run(&mut self) -> Report {
        self.driver().finish()
    }

    /// Open the stepped execution API over this simulation. `step()`,
    /// `run_until(t)`, and `finish()` process the same event stream `run`
    /// would, so stepped and one-shot execution are byte-identical.
    pub fn driver(&mut self) -> SimDriver<'_> {
        SimDriver { sim: self }
    }

    /// One-time start: prime the request stream and, for controllers that
    /// want them, schedule the first tick.
    fn ensure_started(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        self.peak_active = self.num_active_instances();
        self.prime_next_arrival();
        if self.controller.wants_ticks() && self.tick > 0 {
            // First tick at t=0, then every `tick` ns: a controller that
            // schedules future work from its first invocation (e.g.
            // failure-replay emitting `Fail { at }`) can hit any `at > 0`
            // nanosecond-exact, even one earlier than the tick period.
            self.queue.schedule_at(0, Event::ControllerTick);
        }
    }

    /// Dispatch one popped event. The only mutation entry point of the run
    /// loop — `run`, `step`, and `run_until` all funnel through here.
    fn handle_event(&mut self, now: Nanos, event: Event) {
        match event {
            Event::RequestArrival { request_id } => {
                let req = self
                    .next_arrival
                    .take()
                    // simlint: allow(S01) — prime_next_arrival stages exactly one request per arrival event
                    .expect("arrival event without a pulled request");
                debug_assert_eq!(req.id, request_id);
                self.metrics.on_arrival(&req, now);
                if self.admits(now) {
                    self.dispatch_request(req, now);
                } else {
                    self.metrics.on_rejected(req.id);
                }
                self.prime_next_arrival();
            }
            Event::StepComplete { instance } => {
                // The completion time doubles as a step identity: a
                // `StepComplete` whose time does not match the pending
                // step is stale — its step was wiped by a failure.
                if let Some((due, _)) = &self.pending[instance] {
                    if *due == now {
                        self.complete_step(instance, now);
                    }
                }
            }
            Event::Wake { instance } => {
                self.kick(instance, now);
            }
            Event::KvTransferDone {
                request_id,
                dst_instance,
            } => {
                let (req, dst) = self
                    .kv_in_flight
                    .remove(&request_id)
                    // simlint: allow(S01) — every KvTransferDone was scheduled with a kv_in_flight entry
                    .expect("unknown KV transfer");
                debug_assert_eq!(dst, dst_instance);
                if self.instances[dst].lifecycle().is_active() {
                    self.instances[dst].enqueue_decoded(req, now);
                    self.kick(dst, now);
                } else {
                    // The decode target left the fleet while KV was in
                    // flight: recompute elsewhere (the prefill-side first
                    // token folds into the prompt, like a preemption).
                    let mut r = req;
                    r.prompt_tokens += 1;
                    r.output_tokens = r.output_tokens.saturating_sub(1).max(1);
                    self.dispatch_request(r, now);
                }
            }
            Event::ControllerTick => self.on_controller_tick(now),
            Event::InstanceReady { instance } => {
                self.on_instance_ready(instance, now)
            }
            Event::InstanceFail { instance } => self.fail_instance(instance, now),
            Event::ExpertFetchDone { .. } | Event::MetricsTick => {}
        }
    }

    /// Route `req` to an `Active` prefill-capable instance, or park it when
    /// capacity is on the way (an instance is warming up or the controller
    /// has pending intent). Used for fresh arrivals and for requests
    /// displaced by drains/failures alike.
    fn dispatch_request(&mut self, req: Request, now: Nanos) {
        self.fill_views(Some(&req));
        match self.router.dispatch(&req, &self.views_scratch) {
            Some(i) => {
                self.metrics.on_dispatch(req.id, now, i);
                self.instances[i].enqueue(req, now);
                self.kick(i, now);
            }
            None => {
                let capacity_coming = self.instances.iter().any(|x| {
                    matches!(x.lifecycle(), Lifecycle::Starting { .. })
                }) || self.controller.has_pending(now);
                if capacity_coming {
                    self.parked.push(req);
                } else {
                    log::error!("no instance can serve request {}", req.id);
                }
            }
        }
    }

    /// Re-dispatch parked requests (ascending id) after capacity changes.
    fn unpark(&mut self, now: Nanos) {
        if self.parked.is_empty() {
            return;
        }
        let mut parked = std::mem::take(&mut self.parked);
        parked.sort_by_key(|r| r.id);
        for req in parked {
            self.dispatch_request(req, now); // may re-park
        }
    }

    /// Token-bucket + circuit-breaker admission check for one arrival
    /// (`true` = admit). No admission config admits everything. The bucket
    /// refills lazily from the elapsed time since the last arrival; the
    /// breaker trips when fleet-wide waiting depth exceeds the threshold
    /// and rejects every arrival until its cooldown expires.
    fn admits(&mut self, now: Nanos) -> bool {
        let waiting: usize = self.instances.iter().map(|x| x.waiting()).sum();
        let Some(adm) = self.admission.as_mut() else {
            return true;
        };
        let dt = now.saturating_sub(adm.last_refill);
        adm.last_refill = now;
        adm.tokens =
            (adm.tokens + dt as f64 * adm.cfg.rate / 1e9).min(adm.cfg.burst);
        if adm.cfg.breaker_queue > 0
            && now >= adm.open_until
            && waiting > adm.cfg.breaker_queue
        {
            adm.open_until =
                now.saturating_add(adm.cfg.breaker_cooldown_ms * MILLI);
        }
        if now < adm.open_until || adm.tokens < 1.0 {
            return false;
        }
        adm.tokens -= 1.0;
        true
    }

    /// Price and launch one P/D KV hand-off from `src`. When the router's
    /// pick is partitioned away, falls back to the first reachable `Active`
    /// decode instance in id order (deterministic); when *no* decode target
    /// is reachable, the hand-off parks until the fabric heals or an
    /// instance turns `Active`.
    fn route_handoff(&mut self, src: usize, h: KvHandoff, now: Nanos) {
        self.fill_views(None);
        let Some(picked) = self.router.pick_decode(&self.views_scratch) else {
            log::warn!("no decode instance for request {}", h.req.id);
            return;
        };
        let dst = if self.inter_fabric.reachable(src, picked) {
            Some(picked)
        } else {
            self.views_scratch
                .iter()
                .filter(|v| {
                    v.compatible
                        && v.role == Role::Decode
                        && self.inter_fabric.reachable(src, v.id)
                })
                .map(|v| v.id)
                .next()
        };
        let Some(dst) = dst else {
            self.blocked_handoffs.push((src, h));
            return;
        };
        let bytes = match self.instances[src].cfg.kv_transfer {
            KvTransferPolicy::Blocking => h.kv_bytes,
            // layered transfer overlapped with prefill; only the last
            // layer's slice is exposed at completion
            KvTransferPolicy::Layered => {
                h.kv_bytes / self.instances[src].model.layers.max(1)
            }
        };
        let done = self.inter_fabric.transfer(src, dst, bytes, now);
        debug_assert_ne!(done, crate::network::UNREACHABLE);
        let request_id = h.req.id;
        self.kv_in_flight.insert(request_id, (h.req, dst));
        self.queue.schedule_at(
            done,
            Event::KvTransferDone {
                request_id,
                dst_instance: dst,
            },
        );
    }

    /// Retry parked P/D hand-offs after the fabric healed or capacity
    /// returned, in blocked order (may re-park).
    fn retry_blocked_handoffs(&mut self, now: Nanos) {
        if self.blocked_handoffs.is_empty() {
            return;
        }
        let blocked = std::mem::take(&mut self.blocked_handoffs);
        for (src, h) in blocked {
            self.route_handoff(src, h, now);
        }
    }

    // ---- cluster-controller machinery (DESIGN.md §9) ---------------------

    /// Build the read-only snapshot controllers (and driver callers) see.
    pub fn cluster_view(&self, now: Nanos) -> ClusterView {
        ClusterView {
            now,
            instances: self
                .instances
                .iter()
                .enumerate()
                .map(|(i, inst)| InstanceSnapshot {
                    id: i,
                    name: inst.cfg.name.clone(),
                    hardware: inst.cfg.hardware.clone(),
                    role: inst.cfg.role,
                    zone: inst.cfg.zone.clone(),
                    lifecycle: inst.lifecycle(),
                    perf_scale: inst.perf_scale(),
                    waiting: inst.waiting(),
                    running: inst.running_count(),
                    busy: self.busy[i],
                    kv_utilization: inst.kv_utilization(),
                    max_batch_seqs: inst.cfg.max_batch_seqs,
                    cache: self.cache_of[i].map(|c| self.caches[c].stats),
                })
                .collect(),
            in_flight: self.metrics.num_in_flight(),
            finished: self.metrics.num_finished(),
            arrivals: self.metrics.num_arrivals(),
            slo_attainment: self.metrics.slo_attainment_so_far(),
        }
    }

    // simlint: cold — control-plane path: runs once per controller tick
    // (milliseconds apart), not per event, and allocates by design (cluster
    // snapshots, instance construction on scale-up). The H01 allocation-free
    // contract covers the per-event/per-step data plane only.
    fn on_controller_tick(&mut self, now: Nanos) {
        let view = self.cluster_view(now);
        let waiting = view.total_waiting();
        let actions = self.controller.on_tick(now, &view);
        for action in actions {
            self.apply_action(action, now);
        }
        // Sample *after* the actions: each entry records the fleet the
        // next tick interval actually runs with.
        if self.samples < SAMPLE_CAP {
            self.samples += 1;
            let active = self.num_active_instances();
            self.timeline.push(TimelineEntry {
                at: now,
                kind: "sample".to_string(),
                instance: None,
                active,
                detail: format!("waiting={waiting}"),
            });
        }
        // Keep ticking only while something can still happen; otherwise
        // the tick train would keep an otherwise-finished simulation alive
        // forever. Idle-but-unstarted work always has a scheduled event
        // (arrival, step completion, KV transfer, instance warmup), so
        // dropping the tick never strands progress.
        if self.tick_pending(now) {
            self.queue.schedule_in(self.tick, Event::ControllerTick);
        }
    }

    /// Whether another controller tick can still observe or cause change.
    fn tick_pending(&self, now: Nanos) -> bool {
        self.next_arrival.is_some()
            || self.busy.iter().any(|b| *b)
            || !self.kv_in_flight.is_empty()
            || self.controller.has_pending(now)
            || self
                .instances
                .iter()
                .any(|x| matches!(x.lifecycle(), Lifecycle::Starting { .. }))
    }

    /// Apply one controller action. Actions referring to unknown or
    /// wrong-state instances are logged and skipped — a controller bug
    /// must not crash the simulation.
    fn apply_action(&mut self, action: ClusterAction, now: Nanos) {
        match action {
            ClusterAction::ScaleUp { hardware, role } => {
                self.scale_up(hardware, role, now)
            }
            ClusterAction::ScaleDown { instance } => {
                self.drain_instance(instance, now, "scale-down")
            }
            ClusterAction::Drain { instance } => {
                self.drain_instance(instance, now, "drain")
            }
            ClusterAction::Fail { instance, at } => {
                if instance >= self.instances.len() {
                    log::warn!("fail ignored: no instance {instance}");
                } else if at <= now {
                    self.fail_instance(instance, now);
                } else {
                    self.queue
                        .schedule_at(at, Event::InstanceFail { instance });
                }
            }
            ClusterAction::Recover { instance } => self.recover_instance(instance, now),
            ClusterAction::SetBatchCap { instance, max_seqs } => {
                if instance >= self.instances.len() {
                    log::warn!("set-batch-cap ignored: no instance {instance}");
                    return;
                }
                let cap = max_seqs.max(1);
                self.instances[instance].cfg.max_batch_seqs = cap;
                self.note_timeline(
                    now,
                    "set-batch-cap",
                    Some(instance),
                    format!("max_seqs={cap}"),
                );
                self.kick(instance, now);
            }
            ClusterAction::FailDomain { zone, at } => {
                let members = self.zone_members(&zone);
                if members.is_empty() {
                    log::warn!("fail-domain ignored: no instances in zone '{zone}'");
                    return;
                }
                self.note_timeline(
                    now,
                    "fail-domain",
                    None,
                    format!("zone={zone} members={}", members.len()),
                );
                for i in members {
                    if at <= now {
                        self.fail_instance(i, now);
                    } else {
                        self.queue
                            .schedule_at(at, Event::InstanceFail { instance: i });
                    }
                }
            }
            ClusterAction::DegradeLink { instance, scale } => {
                if instance >= self.instances.len() {
                    log::warn!("degrade-link ignored: no instance {instance}");
                    return;
                }
                let scale = if scale.is_finite() {
                    scale.clamp(1e-6, 1.0)
                } else {
                    1.0
                };
                let touched = self.inter_fabric.degrade_device(instance, scale);
                // Absolute, not compounding: one mod per device in the log.
                self.fabric_mods.retain(|m| {
                    !matches!(m, FabricMod::Degrade { device, .. }
                        if *device == instance)
                });
                if scale < 1.0 {
                    self.fabric_mods.push(FabricMod::Degrade {
                        device: instance,
                        scale,
                    });
                }
                self.note_timeline(
                    now,
                    "degrade-link",
                    Some(instance),
                    format!("scale={scale} links={touched}"),
                );
            }
            ClusterAction::PartitionDomain { zone } => {
                let members = self.zone_members(&zone);
                if members.is_empty() {
                    log::warn!("partition ignored: no instances in zone '{zone}'");
                    return;
                }
                let mut cut = 0;
                for &i in &members {
                    cut += self.inter_fabric.isolate_device(i);
                    self.fabric_mods.push(FabricMod::Isolate { device: i });
                }
                self.note_timeline(
                    now,
                    "partition",
                    None,
                    format!("zone={zone} members={} links_cut={cut}", members.len()),
                );
            }
            ClusterAction::RestoreFabric => {
                self.inter_fabric.restore_all();
                self.fabric_mods.clear();
                self.note_timeline(now, "restore-fabric", None, String::new());
                self.retry_blocked_handoffs(now);
            }
            ClusterAction::SetPerfScale { instance, scale } => {
                if instance >= self.instances.len() {
                    log::warn!("perf-scale ignored: no instance {instance}");
                    return;
                }
                self.instances[instance].set_perf_scale(scale);
                let applied = self.instances[instance].perf_scale();
                self.note_timeline(
                    now,
                    "perf-scale",
                    Some(instance),
                    format!("scale={applied}"),
                );
            }
        }
    }

    /// Ids of every instance (any lifecycle state) labelled with `zone`,
    /// ascending.
    fn zone_members(&self, zone: &str) -> Vec<usize> {
        self.instances
            .iter()
            .enumerate()
            .filter(|(_, x)| x.cfg.zone == zone)
            .map(|(i, _)| i)
            .collect()
    }

    /// Add an instance cloned from the first existing instance with the
    /// requested role (hardware overridable); it warms up for
    /// `cluster.warmup_ms`, then turns `Active` and drains the parking lot.
    fn scale_up(&mut self, hardware: Option<String>, role: Role, now: Nanos) {
        // Same capacity definition as ClusterConfig::max_instances and
        // ClusterView::live — Active + Starting. Draining instances are
        // leaving and must not block replacement capacity mid-burst.
        let live = self
            .instances
            .iter()
            .filter(|x| {
                matches!(
                    x.lifecycle(),
                    Lifecycle::Active | Lifecycle::Starting { .. }
                )
            })
            .count();
        if live >= self.cfg.cluster.max_instances {
            log::warn!(
                "scale-up ignored: fleet already at max_instances ({})",
                self.cfg.cluster.max_instances
            );
            return;
        }
        let mut icfg = self
            .instances
            .iter()
            .find(|x| x.cfg.role == role)
            .map(|x| x.cfg.clone())
            .unwrap_or_else(|| {
                let mut c = self.instances[0].cfg.clone();
                c.role = role;
                c
            });
        self.scaled += 1;
        icfg.name = format!("scaled{}", self.scaled);
        if let Some(h) = hardware {
            icfg.hardware = h;
        }
        let idx = self.instances.len();
        let built = build_instance(
            &icfg,
            idx,
            &self.cfg.perf,
            self.cfg.block_size,
            self.cfg.seed,
            &self.registry,
            &self.perf_factory,
            self.sched_override.as_ref(),
            self.evict_override.as_ref(),
            &mut self.caches,
            &mut self.global_cache,
        );
        let (mut inst, slot) = match built {
            Ok(x) => x,
            Err(e) => {
                log::error!("scale-up of '{}' failed: {e:#}", icfg.name);
                return;
            }
        };
        let until = now.saturating_add(self.warmup);
        inst.set_lifecycle(Lifecycle::Starting { until });
        let detail = format!("hw={} role={}", icfg.hardware, icfg.role.as_str());
        self.instances.push(inst);
        self.cache_of.push(slot);
        self.busy.push(false);
        self.pending.push(None);
        self.down_since.push(None);
        self.downtime.push(0);
        // The inter-instance fabric is sized to the fleet; regrow it,
        // carrying the byte counter over (per-link congestion state resets
        // — scale-ups are rare, seconds-apart events).
        let bytes = self.inter_fabric.bytes_moved;
        self.inter_fabric = Fabric::new(Topology::switched(
            self.instances.len(),
            self.cfg.inter_instance_bw,
            self.cfg.inter_instance_latency_ns,
        ));
        self.inter_fabric.bytes_moved = bytes;
        // Chaos fabric state survives the rebuild: replay the mutation log
        // so a mid-incident scale-up doesn't silently heal the fabric.
        for m in &self.fabric_mods {
            match *m {
                FabricMod::Degrade { device, scale } => {
                    self.inter_fabric.degrade_device(device, scale);
                }
                FabricMod::Isolate { device } => {
                    self.inter_fabric.isolate_device(device);
                }
            }
        }
        self.queue
            .schedule_at(until, Event::InstanceReady { instance: idx });
        self.note_timeline(now, "scale-up", Some(idx), detail);
    }

    /// Graceful removal: re-route waiting requests now, let the running
    /// batch finish, stop when empty.
    fn drain_instance(&mut self, i: usize, now: Nanos, kind: &str) {
        if i >= self.instances.len() {
            log::warn!("{kind} ignored: no instance {i}");
            return;
        }
        if !self.instances[i].lifecycle().is_active() {
            log::warn!(
                "{kind} ignored: instance {i} is {}",
                self.instances[i].lifecycle().as_str()
            );
            return;
        }
        let displaced = self.instances[i].drain_waiting();
        // Draining *before* re-dispatch so the router cannot pick i again.
        self.instances[i].set_lifecycle(Lifecycle::Draining);
        self.note_timeline(now, kind, Some(i), format!("rerouted={}", displaced.len()));
        for req in displaced {
            self.dispatch_request(req, now);
        }
        self.maybe_finish_drain(i, now);
    }

    /// Complete a drain once the running batch has fully finished.
    fn maybe_finish_drain(&mut self, i: usize, now: Nanos) {
        if self.instances[i].lifecycle() == Lifecycle::Draining
            && !self.busy[i]
            && !self.instances[i].has_work()
        {
            self.instances[i].set_lifecycle(Lifecycle::Stopped);
            self.note_timeline(now, "drained", Some(i), String::new());
        }
    }

    /// Hard failure: the in-flight step is wiped, every resident request
    /// is lost and re-routed recompute-style, the instance stops.
    fn fail_instance(&mut self, i: usize, now: Nanos) {
        if self.instances[i].lifecycle().is_stopped() {
            return; // double fail / fail after drain completed
        }
        if self.down_since[i].is_none() {
            self.down_since[i] = Some(now);
            self.metrics.on_fault_begin(now);
        }
        self.busy[i] = false;
        self.pending[i] = None; // any queued StepComplete is now stale
        let displaced = self.instances[i].evacuate();
        self.instances[i].set_lifecycle(Lifecycle::Stopped);
        // simlint: allow(H01) — failure path: runs once per injected fault,
        // not per event, and the timeline note needs an owned string
        self.note_timeline(now, "fail", Some(i), format!("rerouted={}", displaced.len()));
        for req in displaced {
            self.dispatch_request(req, now);
        }
    }

    /// Bring a `Stopped` instance back through warmup.
    fn recover_instance(&mut self, i: usize, now: Nanos) {
        if i >= self.instances.len() {
            log::warn!("recover ignored: no instance {i}");
            return;
        }
        if !self.instances[i].lifecycle().is_stopped() {
            log::warn!(
                "recover ignored: instance {i} is {}",
                self.instances[i].lifecycle().as_str()
            );
            return;
        }
        let until = now.saturating_add(self.warmup);
        self.instances[i].set_lifecycle(Lifecycle::Starting { until });
        self.queue
            .schedule_at(until, Event::InstanceReady { instance: i });
        self.note_timeline(now, "recover", Some(i), String::new());
    }

    /// A `Starting` instance finished warmup: activate, retry parked
    /// requests, and kick (drained work may already be waiting).
    fn on_instance_ready(&mut self, i: usize, now: Nanos) {
        // `until <= now` also filters stale ready events: a fail+recover
        // during warmup leaves the old event pointing at a later Starting.
        if let Lifecycle::Starting { until } = self.instances[i].lifecycle() {
            if until > now {
                return;
            }
            self.instances[i].set_lifecycle(Lifecycle::Active);
            if let Some(start) = self.down_since[i].take() {
                self.downtime[i] =
                    self.downtime[i].saturating_add(now.saturating_sub(start));
                self.metrics.on_fault_end(now);
            }
            self.note_timeline(now, "ready", Some(i), String::new());
            self.peak_active = self.peak_active.max(self.num_active_instances());
            self.unpark(now);
            self.retry_blocked_handoffs(now);
            self.kick(i, now);
        }
    }

    fn note_timeline(
        &mut self,
        at: Nanos,
        kind: &str,
        instance: Option<usize>,
        detail: String,
    ) {
        let active = self.num_active_instances();
        self.timeline.push(TimelineEntry {
            at,
            kind: kind.to_string(),
            instance,
            active,
            detail,
        });
    }

    /// Final accounting shared by `run()` and `SimDriver::finish()`.
    fn final_report(&mut self) -> Report {
        let makespan = self.queue.now();
        let unfinished = self.metrics.num_in_flight();
        if unfinished > 0 {
            log::warn!(
                "simulation drained with {unfinished} unfinished requests \
                 (KV pool too small for the workload?)"
            );
        }
        if !self.parked.is_empty() {
            log::error!(
                "{} displaced requests never found a new instance",
                self.parked.len()
            );
        }
        if !self.blocked_handoffs.is_empty() {
            log::error!(
                "{} KV hand-offs stayed blocked behind a partition",
                self.blocked_handoffs.len()
            );
        }
        let mut report = self
            .metrics
            .report(makespan, &self.cfg.workload.tenant_names());
        if let Some(res) = report.resilience.as_mut() {
            res.domains = self.domain_reports(makespan);
        }
        // Inert controllers (static, or a zero-fault chaos profile that
        // never scheduled a tick) leave no trace: the report stays
        // byte-identical to a run without any controller.
        if self.controller.wants_ticks() || !self.timeline.is_empty() {
            report.controller = self.controller.name().to_string();
            report.timeline = self.timeline.clone();
        }
        report
    }

    /// Per-zone availability over the run: 1 minus the fraction of
    /// instance-time the zone's members spent inside a fault window
    /// (fail → re-`Active`). Open windows are closed at `makespan`.
    /// Zones in deterministic name order.
    fn domain_reports(&self, makespan: Nanos) -> Vec<crate::metrics::DomainReport> {
        let mut zones: std::collections::BTreeMap<&str, (usize, Nanos)> =
            std::collections::BTreeMap::new();
        for (i, inst) in self.instances.iter().enumerate() {
            let mut down = self.downtime[i];
            if let Some(start) = self.down_since[i] {
                down = down.saturating_add(makespan.saturating_sub(start));
            }
            let e = zones.entry(inst.cfg.zone.as_str()).or_insert((0, 0));
            e.0 += 1;
            e.1 = e.1.saturating_add(down);
        }
        zones
            .into_iter()
            .map(|(zone, (instances, downtime_ns))| {
                let span = (instances as u64).saturating_mul(makespan.max(1));
                crate::metrics::DomainReport {
                    zone: zone.to_string(),
                    instances,
                    downtime_ns,
                    availability: 1.0 - downtime_ns as f64 / span as f64,
                }
            })
            .collect()
    }

    // ---- introspection ---------------------------------------------------

    /// Instances currently part of the fleet (not `Stopped`). Under the
    /// `static` controller this equals the configured instance count; with
    /// dynamics it tracks lifecycle state — see
    /// [`Simulation::fleet_size`] for the historical total.
    pub fn num_instances(&self) -> usize {
        self.instances
            .iter()
            .filter(|x| !x.lifecycle().is_stopped())
            .count()
    }

    /// Every instance ever created, including `Stopped` ones (stable ids).
    pub fn fleet_size(&self) -> usize {
        self.instances.len()
    }

    /// Instances currently `Active` (router targets).
    pub fn num_active_instances(&self) -> usize {
        self.instances
            .iter()
            .filter(|x| x.lifecycle().is_active())
            .count()
    }

    /// Highest concurrently-`Active` instance count seen so far.
    pub fn peak_instances(&self) -> usize {
        self.peak_active
    }

    /// Name of the resolved cluster controller.
    pub fn controller_name(&self) -> &str {
        self.controller.name()
    }

    /// Controller name as reports attribute it: a controller that never
    /// ticked and left no timeline is indistinguishable from `static`,
    /// and is reported as such (the zero-fault chaos byte-compat rule).
    pub fn reported_controller(&self) -> &str {
        if self.controller.wants_ticks() || !self.timeline.is_empty() {
            self.controller.name()
        } else {
            "static"
        }
    }

    /// Controller actions, lifecycle transitions, and fleet samples so far.
    pub fn timeline(&self) -> &[TimelineEntry] {
        &self.timeline
    }

    /// Name reported by the resolved router policy (e.g.
    /// `session-affinity(least-outstanding)` — wrappers spell out their
    /// fallback, so reports never misattribute placement).
    pub fn router_policy_name(&self) -> &str {
        self.router.policy_name()
    }

    pub fn instance(&self, i: usize) -> &ServingInstance {
        &self.instances[i]
    }

    /// Stats of every cache still attached to a live (non-`Stopped`)
    /// instance, in cache-construction order. A cache whose instances all
    /// left the fleet reports nothing — introspection tracks the fleet,
    /// not history.
    pub fn cache_stats(&self) -> Vec<crate::memory::CacheStats> {
        let mut live = vec![false; self.caches.len()];
        for (i, slot) in self.cache_of.iter().enumerate() {
            if let Some(c) = slot {
                if !self.instances[i].lifecycle().is_stopped() {
                    live[*c] = true;
                }
            }
        }
        self.caches
            .iter()
            .enumerate()
            .filter(|(i, _)| live[*i])
            .map(|(_, c)| c.stats)
            .collect()
    }

    pub fn events_processed(&self) -> u64 {
        self.queue.processed()
    }

    pub fn inter_instance_bytes(&self) -> u64 {
        self.inter_fabric.bytes_moved
    }
}

/// The stepped execution API over a built [`Simulation`] (DESIGN.md §9).
///
/// `run()` is now a thin wrapper over this driver, so stepped and one-shot
/// execution process the identical event stream:
///
/// ```ignore
/// let mut sim = Simulation::new(cfg)?;
/// let mut driver = sim.driver();
/// let mut t = 0;
/// while !driver.is_done() {
///     t += sim::SECOND;                    // advance in wall slices —
///     driver.run_until(t);                 // now() only moves with events
///     let view = driver.view();            // inspect between steps
///     println!("active = {}", view.active());
/// }
/// let report = driver.finish();
/// ```
///
/// The driver borrows the simulation mutably: drop it to regain access to
/// the `Simulation`'s introspection methods, or call them through
/// [`SimDriver::sim`].
pub struct SimDriver<'a> {
    sim: &'a mut Simulation,
}

impl SimDriver<'_> {
    /// Current simulated time (the timestamp of the last processed event).
    pub fn now(&self) -> Nanos {
        self.sim.queue.now()
    }

    /// Events waiting in the queue (0 once drained).
    pub fn pending_events(&self) -> usize {
        self.sim.queue.len()
    }

    /// Process exactly one event; returns its timestamp, or `None` when
    /// the simulation is complete. The first call starts the simulation
    /// (primes the request stream, schedules the first controller tick).
    pub fn step(&mut self) -> Option<Nanos> {
        self.sim.ensure_started();
        let (now, event) = self.sim.queue.pop()?;
        self.sim.handle_event(now, event);
        Some(now)
    }

    /// Process every event with timestamp `<= t`; returns how many ran.
    /// The clock ends on the last processed event (not advanced to `t` —
    /// simulated time only moves when events do).
    pub fn run_until(&mut self, t: Nanos) -> u64 {
        self.sim.ensure_started();
        let mut n = 0;
        while let Some(next) = self.sim.queue.peek_time() {
            if next > t {
                break;
            }
            // simlint: allow(S01) — peek_time returned Some, so the queue is non-empty
            let (now, event) = self.sim.queue.pop().expect("peeked event vanished");
            self.sim.handle_event(now, event);
            n += 1;
        }
        n
    }

    /// Read-only cluster snapshot at the current time — the same view a
    /// controller sees on its tick.
    pub fn view(&self) -> ClusterView {
        self.sim.cluster_view(self.sim.queue.now())
    }

    /// Whether every event has been processed (only meaningful after the
    /// first `step`/`run_until` call started the simulation).
    pub fn is_done(&self) -> bool {
        self.sim.started && self.sim.queue.is_empty()
    }

    /// Drain the remaining events and produce the final report.
    pub fn finish(&mut self) -> Report {
        while self.step().is_some() {}
        self.sim.final_report()
    }

    /// The underlying simulation (read-only introspection mid-run).
    pub fn sim(&self) -> &Simulation {
        self.sim
    }
}

/// Convenience: build + run + report.
pub fn run_config(cfg: SimConfig) -> anyhow::Result<(Report, SimSummary)> {
    let mut sim = Simulation::new(cfg)?;
    let report = sim.run();
    let summary = SimSummary {
        steps: sim.steps_total,
        events: sim.events_processed(),
        cache_stats: sim.cache_stats(),
        inter_instance_bytes: sim.inter_instance_bytes(),
        peak_instances: sim.peak_instances(),
        controller: sim.reported_controller().to_string(),
    };
    Ok((report, summary))
}

/// Simulator-internal counters (Fig. 3 cost accounting).
#[derive(Debug, Clone)]
pub struct SimSummary {
    pub steps: u64,
    pub events: u64,
    pub cache_stats: Vec<crate::memory::CacheStats>,
    pub inter_instance_bytes: u64,
    /// Highest concurrently-`Active` instance count over the run.
    pub peak_instances: usize,
    /// Resolved cluster-controller name (`"static"` = frozen fleet).
    pub controller: String,
}

// Compile-time guarantee that the simulation core stays thread-movable;
// losing `Send` here would silently break the sweep engine.
#[allow(dead_code)]
fn assert_core_is_send() {
    fn assert_send<T: Send>() {}
    assert_send::<Simulation>();
    assert_send::<crate::metrics::Report>();
    assert_send::<SimSummary>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn small(mut cfg: SimConfig) -> SimConfig {
        cfg.workload.num_requests = 20;
        cfg.workload.lengths = crate::workload::LengthDist::short();
        cfg
    }

    #[test]
    fn single_instance_dense_completes() {
        let cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        let (report, summary) = run_config(cfg).unwrap();
        assert_eq!(report.num_finished, 20);
        assert!(report.throughput_tps > 0.0);
        assert!(report.ttft_ns.mean > 0.0);
        assert!(summary.steps > 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        let (a, sa) = run_config(cfg.clone()).unwrap();
        let (b, sb) = run_config(cfg).unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert_eq!(sa.steps, sb.steps);
        assert!((a.tpot_ns.mean - b.tpot_ns.mean).abs() < 1e-9);
    }

    #[test]
    fn moe_single_instance_completes() {
        let cfg = small(presets::single_moe("tiny-moe", "rtx3090"));
        let (report, _) = run_config(cfg).unwrap();
        assert_eq!(report.num_finished, 20);
    }

    #[test]
    fn multi_instance_spreads_load() {
        let mut cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        // burst arrivals force queueing so least-outstanding actually spreads
        cfg.workload.traffic = crate::workload::Traffic::burst();
        let mut sim = Simulation::new(cfg).unwrap();
        let report = sim.run();
        assert_eq!(report.num_finished, 20);
        // both instances must have done work under least-outstanding routing
        assert!(report.utilization.get(&0).copied().unwrap_or(0.0) > 0.0);
        assert!(report.utilization.get(&1).copied().unwrap_or(0.0) > 0.0);
    }

    #[test]
    fn pd_disaggregation_completes_with_transfers() {
        let cfg = small(presets::pd_dense("tiny-dense", "rtx3090"));
        let mut sim = Simulation::new(cfg).unwrap();
        let report = sim.run();
        assert_eq!(report.num_finished, 20);
        assert!(
            sim.inter_instance_bytes() > 0,
            "P/D must move KV across instances"
        );
    }

    #[test]
    fn pd_layered_transfer_moves_fewer_exposed_bytes() {
        let mk = |policy| {
            let mut cfg = small(presets::pd_dense("tiny-dense", "rtx3090"));
            for i in &mut cfg.instances {
                i.kv_transfer = policy;
            }
            let mut sim = Simulation::new(cfg).unwrap();
            let r = sim.run();
            (r, sim.inter_instance_bytes())
        };
        let (_, blocking_bytes) = mk(KvTransferPolicy::Blocking);
        let (_, layered_bytes) = mk(KvTransferPolicy::Layered);
        assert!(
            layered_bytes < blocking_bytes,
            "layered {layered_bytes} !< blocking {blocking_bytes}"
        );
    }

    #[test]
    fn prefix_cache_improves_ttft() {
        let base = small(presets::single_dense("tiny-dense", "rtx3090"));
        let mut with_pc = presets::with_prefix_cache(
            base.clone(),
            crate::config::CacheScope::PerInstance,
        );
        // identical workload apart from prefix sharing
        let mut base_shared = base.clone();
        base_shared.workload.sessions = 10;
        base_shared.workload.shared_prefix = 64;
        with_pc.workload = base_shared.workload.clone();

        let (cold, _) = run_config(base_shared).unwrap();
        let (warm, summary) = run_config(with_pc).unwrap();
        assert_eq!(cold.num_finished, warm.num_finished);
        assert!(summary.cache_stats[0].hit_rate() > 0.0);
        assert!(
            warm.ttft_ns.mean < cold.ttft_ns.mean,
            "PC TTFT {} !< no-PC TTFT {}",
            warm.ttft_ns.mean,
            cold.ttft_ns.mean
        );
    }

    #[test]
    fn global_cache_shared_across_instances() {
        let cfg = small(presets::with_prefix_cache(
            presets::multi_dense("tiny-dense", "rtx3090"),
            crate::config::CacheScope::Global,
        ));
        let mut sim = Simulation::new(cfg).unwrap();
        let report = sim.run();
        assert!(report.num_finished > 0);
        assert_eq!(sim.cache_stats().len(), 1, "global scope = one cache");
    }

    #[test]
    fn all_fig3_configs_run() {
        for cfg in presets::fig3_configs("tiny-dense", "tiny-moe", "rtx3090") {
            let name = cfg.name.clone();
            let (report, _) = run_config(small(cfg)).unwrap();
            assert_eq!(report.num_finished, 20, "config {name}");
        }
    }

    #[test]
    fn simulation_moves_across_threads() {
        // The tentpole property behind the sweep engine: a fully-built
        // simulation is Send and produces the same report on a foreign
        // thread as on the building thread.
        let cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        let (home, _) = run_config(cfg.clone()).unwrap();
        let mut sim = Simulation::new(cfg).unwrap();
        let away = std::thread::spawn(move || sim.run()).join().unwrap();
        assert_eq!(home.makespan, away.makespan);
        assert_eq!(home.generated_tokens, away.generated_tokens);
        assert_eq!(
            home.to_json().to_string(),
            away.to_json().to_string(),
            "thread migration must not perturb the report"
        );
    }

    #[test]
    fn multi_tenant_bursty_reports_breakdowns() {
        use crate::workload::{SloClass, TenantSpec, Traffic};
        let mut cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        cfg.workload.num_requests = 40;
        cfg.workload.traffic = Traffic::mmpp(80.0, 0.0, 1.0, 3.0);
        cfg.workload.tenants = TenantSpec::mix(3);
        for i in &mut cfg.instances {
            i.sched = "slo".to_string();
        }
        let (report, _) = run_config(cfg).unwrap();
        assert_eq!(report.num_finished, 40);
        assert!(!report.per_tenant.is_empty());
        assert!(!report.per_class.is_empty());
        let finished: usize = report.per_tenant.iter().map(|t| t.num_finished).sum();
        assert_eq!(finished, 40, "tenant partition must cover all requests");
        let by_class: usize = report.per_class.iter().map(|c| c.num_finished).sum();
        assert_eq!(by_class, 40);
        assert!(report.goodput_tps <= report.throughput_tps + 1e-9);
        assert!(report
            .per_class
            .iter()
            .any(|c| c.class == SloClass::Batch));
    }

    #[test]
    fn custom_traffic_source_injects_via_builder() {
        use crate::workload::{ReplaySource, Traffic};
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        // the config names an unregistered source, but the builder override
        // wins, mirroring the policy-override contract
        cfg.workload.traffic = Traffic::Custom {
            name: "not-registered".into(),
        };
        let reqs = {
            let mut spec = cfg.workload.clone();
            spec.traffic = Traffic::burst();
            spec.num_requests = 8;
            spec.generate().unwrap()
        };
        let mut sim = Simulation::builder(cfg)
            .with_traffic_source(Box::new(ReplaySource::from_requests(reqs)))
            .build()
            .unwrap();
        let report = sim.run();
        assert_eq!(report.num_finished, 8);
        // and without the override, the unknown name fails with candidates
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.workload.traffic = Traffic::Custom {
            name: "not-registered".into(),
        };
        let e = Simulation::new(cfg).unwrap_err().to_string();
        assert!(e.contains("not-registered") && e.contains("poisson"), "{e}");
    }

    #[test]
    fn cycle_backend_runs() {
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.workload.num_requests = 5;
        cfg.perf = PerfBackend::Cycle;
        let (report, _) = run_config(cfg).unwrap();
        assert_eq!(report.num_finished, 5);
    }

    #[test]
    fn unknown_policy_names_fail_at_build_with_candidates() {
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.router = "coin-flip".to_string();
        let e = Simulation::new(cfg).unwrap_err().to_string();
        assert!(e.contains("coin-flip") && e.contains("round-robin"), "{e}");

        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.instances[0].sched = "lifo".to_string();
        let e = Simulation::new(cfg).unwrap_err().to_string();
        assert!(e.contains("lifo") && e.contains("fcfs"), "{e}");

        let mut cfg = small(presets::with_prefix_cache(
            presets::single_dense("tiny-dense", "rtx3090"),
            crate::config::CacheScope::PerInstance,
        ));
        cfg.instances[0].prefix_cache.as_mut().unwrap().policy =
            "random".to_string();
        let e = Simulation::new(cfg).unwrap_err().to_string();
        assert!(e.contains("random") && e.contains("lru"), "{e}");

        // Global scope: instances after the cache-creating one share the
        // first instance's cache, but their evict names must still resolve.
        let mut cfg = small(presets::with_prefix_cache(
            presets::multi_dense("tiny-dense", "rtx3090"),
            crate::config::CacheScope::Global,
        ));
        cfg.instances[1].prefix_cache.as_mut().unwrap().policy =
            "bogus".to_string();
        let e = Simulation::new(cfg).unwrap_err().to_string();
        assert!(e.contains("bogus") && e.contains("lru"), "{e}");
    }

    #[test]
    fn builder_overrides_skip_name_resolution() {
        // Policies injected through the builder win over config names, so
        // unregistered names are fine when every slot is overridden.
        use crate::policy::{CacheLeaf, EvictionPolicy, SchedulePolicy};
        use crate::router::{InstanceView, RoutePolicy};

        struct FirstFit;
        impl RoutePolicy for FirstFit {
            fn choose(
                &mut self,
                _req: &crate::workload::Request,
                candidates: &[InstanceView],
            ) -> usize {
                candidates[0].id
            }
            fn name(&self) -> &str {
                "first-fit"
            }
        }
        struct ReverseId;
        impl SchedulePolicy for ReverseId {
            fn name(&self) -> &str {
                "reverse-id"
            }
            fn order(
                &mut self,
                wait: &mut [u64],
                _seqs: &crate::instance::SeqMap,
                _now: Nanos,
            ) {
                wait.sort_by_key(|id| std::cmp::Reverse(*id));
            }
        }
        struct EvictAll;
        impl EvictionPolicy for EvictAll {
            fn name(&self) -> &str {
                "evict-first"
            }
            fn pick(&mut self, leaves: &[CacheLeaf]) -> Option<usize> {
                leaves.first().map(|l| l.id)
            }
        }

        let mut cfg = small(presets::with_prefix_cache(
            presets::multi_dense("tiny-dense", "rtx3090"),
            crate::config::CacheScope::PerInstance,
        ));
        cfg.router = "not-registered".to_string();
        for i in &mut cfg.instances {
            i.sched = "not-registered".to_string();
            i.prefix_cache.as_mut().unwrap().policy = "not-registered".to_string();
        }
        let mut sim = Simulation::builder(cfg)
            .with_route_policy(Box::new(FirstFit))
            .with_sched_policy(|| Box::new(ReverseId))
            .with_evict_policy(|| Box::new(EvictAll))
            .build()
            .unwrap();
        assert_eq!(sim.router_policy_name(), "first-fit");
        assert_eq!(sim.instance(0).sched_name(), "reverse-id");
        let report = sim.run();
        assert_eq!(report.num_finished, 20);
    }

    #[test]
    fn driver_stepped_run_matches_one_shot_under_static() {
        let cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        let (oneshot, _) = run_config(cfg.clone()).unwrap();

        let mut sim = Simulation::new(cfg).unwrap();
        let mut driver = sim.driver();
        // walk the simulation in 2 ms slices, inspecting between steps
        let mut t = 0;
        loop {
            t += 2 * MILLI;
            driver.run_until(t);
            let view = driver.view();
            assert!(view.active() >= 1);
            if driver.is_done() {
                break;
            }
        }
        let stepped = driver.finish();
        assert_eq!(
            oneshot.to_json().to_string(),
            stepped.to_json().to_string(),
            "stepped execution must be byte-identical to run()"
        );
        assert_eq!(stepped.controller, "static");
        assert!(stepped.timeline.is_empty(), "static schedules no ticks");
    }

    #[test]
    fn driver_single_steps_every_event() {
        let cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        let (oneshot, summary) = run_config(cfg.clone()).unwrap();
        let mut sim = Simulation::new(cfg).unwrap();
        let mut driver = sim.driver();
        let mut times = vec![];
        while let Some(t) = driver.step() {
            times.push(t);
        }
        assert!(driver.is_done());
        let report = driver.finish();
        assert_eq!(times.len() as u64, summary.events);
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "time is monotone");
        assert_eq!(oneshot.to_json().to_string(), report.to_json().to_string());
    }

    #[test]
    fn unknown_controller_name_fails_with_candidates() {
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.cluster.controller = "chaos-monkey".to_string();
        let e = Simulation::new(cfg).unwrap_err().to_string();
        assert!(
            e.contains("chaos-monkey") && e.contains("queue-threshold"),
            "{e}"
        );
    }

    #[test]
    fn failure_replay_reroutes_and_recovers() {
        use crate::config::FailureSpec;
        let mut cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        cfg.workload.num_requests = 30;
        cfg.cluster.controller = "failure-replay".to_string();
        cfg.cluster.tick_ms = 10;
        cfg.cluster.warmup_ms = 50;
        // fail instance 1 early, recover it mid-run
        cfg.cluster.failures = vec![FailureSpec {
            instance: 1,
            at_ms: 40,
            recover_ms: Some(400),
        }];
        let mut sim = Simulation::new(cfg).unwrap();
        let report = sim.run();
        assert_eq!(report.num_finished, 30, "failure must not lose requests");
        assert_eq!(report.controller, "failure-replay");
        let kinds: Vec<&str> =
            report.timeline.iter().map(|e| e.kind.as_str()).collect();
        assert!(kinds.contains(&"fail"), "timeline records the failure");
        assert!(kinds.contains(&"recover"), "timeline records the recovery");
        assert!(kinds.contains(&"ready"), "recovered instance turned active");
        let fail = report
            .timeline
            .iter()
            .find(|e| e.kind == "fail")
            .unwrap();
        assert_eq!(fail.instance, Some(1));
        assert_eq!(fail.at, 40 * MILLI, "scripted failures are ns-exact");
        assert_eq!(fail.active, 1, "one active instance right after the kill");
        // deterministic across runs
        let mut cfg2 = small(presets::multi_dense("tiny-dense", "rtx3090"));
        cfg2.workload.num_requests = 30;
        cfg2.cluster = sim.cfg.cluster.clone();
        let (b, _) = run_config(cfg2).unwrap();
        assert_eq!(report.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn builder_injected_controller_drains_and_retunes() {
        use crate::cluster::{ClusterAction, ClusterController, ClusterView};

        /// Drains instance 1 on the first tick and caps instance 0's batch.
        struct DrainOnce {
            fired: bool,
        }
        impl ClusterController for DrainOnce {
            fn name(&self) -> &str {
                "drain-once"
            }
            fn on_tick(
                &mut self,
                _now: Nanos,
                _view: &ClusterView,
            ) -> Vec<ClusterAction> {
                if self.fired {
                    return vec![];
                }
                self.fired = true;
                vec![
                    ClusterAction::Drain { instance: 1 },
                    ClusterAction::SetBatchCap {
                        instance: 0,
                        max_seqs: 2,
                    },
                ]
            }
        }

        let mut cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        cfg.workload.num_requests = 24;
        cfg.cluster.tick_ms = 5;
        let mut sim = Simulation::builder(cfg)
            .with_controller(Box::new(DrainOnce { fired: false }))
            .build()
            .unwrap();
        let report = sim.run();
        assert_eq!(report.num_finished, 24, "drained requests are re-routed");
        assert_eq!(report.controller, "drain-once");
        assert_eq!(sim.instance(0).cfg.max_batch_seqs, 2);
        assert!(sim.instance(1).lifecycle().is_stopped());
        assert_eq!(sim.num_instances(), 1, "stopped instances leave the fleet");
        assert_eq!(sim.fleet_size(), 2, "but stay addressable by id");
        let kinds: Vec<&str> =
            report.timeline.iter().map(|e| e.kind.as_str()).collect();
        assert!(kinds.contains(&"drain"));
        assert!(kinds.contains(&"drained"));
        assert!(kinds.contains(&"set-batch-cap"));
        assert!(kinds.contains(&"sample"));
    }

    #[test]
    fn queue_threshold_scales_fleet_up_and_down() {
        let (report, summary) = run_config(presets::autoscale_bursty()).unwrap();
        assert_eq!(report.num_finished, 200);
        assert_eq!(summary.controller, "queue-threshold");
        assert!(
            summary.peak_instances > 1,
            "burst pressure must scale the fleet up (peak {})",
            summary.peak_instances
        );
        let kinds: Vec<&str> =
            report.timeline.iter().map(|e| e.kind.as_str()).collect();
        assert!(kinds.contains(&"scale-up"));
        assert!(
            kinds.contains(&"scale-down"),
            "quiet phases must drain the extra capacity: {kinds:?}"
        );
        assert!(kinds.contains(&"sample"), "fleet-size samples recorded");
    }

    #[test]
    fn admission_overload_rejects_and_conserves_requests() {
        use crate::config::AdmissionConfig;
        let mut cfg = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg.workload.num_requests = 40;
        cfg.workload.traffic = crate::workload::Traffic::burst();
        // A tiny bucket against a burst: most arrivals must bounce.
        cfg.cluster.admission = Some(AdmissionConfig {
            rate: 10.0,
            burst: 3.0,
            breaker_queue: 0,
            breaker_cooldown_ms: 500,
        });
        let mut sim = Simulation::new(cfg).unwrap();
        let report = sim.run();
        assert!(report.rejected > 0, "burst must overflow the token bucket");
        assert!(report.num_finished > 0, "admitted requests still finish");
        let in_flight = sim.cluster_view(0).in_flight;
        assert_eq!(
            report.rejected + report.num_finished + in_flight,
            report.num_requests,
            "rejected + finished + in-flight must equal arrivals"
        );
        assert_eq!(
            report.to_json().get("rejected").as_i64(),
            Some(report.rejected as i64)
        );
        // determinism: same config, same rejections
        let mut cfg2 = small(presets::single_dense("tiny-dense", "rtx3090"));
        cfg2.workload.num_requests = 40;
        cfg2.workload.traffic = crate::workload::Traffic::burst();
        cfg2.cluster.admission = sim.cfg.cluster.admission.clone();
        let (b, _) = run_config(cfg2).unwrap();
        assert_eq!(report.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn zone_outage_opens_fault_windows_and_reports_domains() {
        use crate::cluster::{ClusterAction, ClusterController, ClusterView};

        /// Kills zone "a" once work is in flight; recovers it two ticks
        /// later; also marks instance 1 a straggler.
        struct ZoneOutage {
            failed_at_tick: Option<u32>,
            ticks: u32,
            recovered: bool,
        }
        impl ClusterController for ZoneOutage {
            fn name(&self) -> &str {
                "zone-outage"
            }
            fn on_tick(&mut self, now: Nanos, view: &ClusterView) -> Vec<ClusterAction> {
                self.ticks += 1;
                match self.failed_at_tick {
                    None if view.in_flight > 0 => {
                        self.failed_at_tick = Some(self.ticks);
                        vec![
                            ClusterAction::SetPerfScale {
                                instance: 1,
                                scale: 2.0,
                            },
                            ClusterAction::FailDomain {
                                zone: "a".to_string(),
                                at: now,
                            },
                        ]
                    }
                    Some(t) if !self.recovered && self.ticks >= t + 2 => {
                        self.recovered = true;
                        vec![ClusterAction::Recover { instance: 0 }]
                    }
                    _ => vec![],
                }
            }
            fn has_pending(&self, _now: Nanos) -> bool {
                self.failed_at_tick.is_some() && !self.recovered
            }
        }

        let mut cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        cfg.workload.num_requests = 30;
        cfg.cluster.tick_ms = 5;
        cfg.cluster.warmup_ms = 20;
        cfg.instances[0].zone = "a".to_string();
        let mut sim = Simulation::builder(cfg)
            .with_controller(Box::new(ZoneOutage {
                failed_at_tick: None,
                ticks: 0,
                recovered: false,
            }))
            .build()
            .unwrap();
        let report = sim.run();
        assert_eq!(report.num_finished, 30, "outage must not lose requests");
        let kinds: Vec<&str> =
            report.timeline.iter().map(|e| e.kind.as_str()).collect();
        assert!(kinds.contains(&"fail-domain"), "{kinds:?}");
        assert!(kinds.contains(&"fail"));
        assert!(kinds.contains(&"perf-scale"));
        assert!(kinds.contains(&"recover"));
        assert!(kinds.contains(&"ready"));
        let res = report.resilience.expect("fault windows must be reported");
        assert_eq!(res.faults, 1);
        assert!(res.fault_ns > 0);
        // zone "a" saw downtime; the default zone stayed clean
        assert_eq!(res.domains.len(), 2);
        assert_eq!(res.domains[0].zone, "a");
        assert_eq!(res.domains[0].instances, 1);
        assert!(res.domains[0].downtime_ns > 0);
        assert!(res.domains[0].availability < 1.0);
        assert_eq!(res.domains[1].zone, "default");
        assert_eq!(res.domains[1].downtime_ns, 0);
        assert_eq!(res.domains[1].availability, 1.0);
        assert!((sim.instance(1).perf_scale() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn partition_parks_pd_handoffs_until_fabric_heals() {
        use crate::cluster::{ClusterAction, ClusterController, ClusterView};

        /// Partitions the decode zone once work is in flight, heals the
        /// fabric three ticks later.
        struct PartitionPulse {
            cut_at_tick: Option<u32>,
            ticks: u32,
            healed: bool,
        }
        impl ClusterController for PartitionPulse {
            fn name(&self) -> &str {
                "partition-pulse"
            }
            fn on_tick(
                &mut self,
                _now: Nanos,
                view: &ClusterView,
            ) -> Vec<ClusterAction> {
                self.ticks += 1;
                match self.cut_at_tick {
                    None if view.in_flight > 0 => {
                        self.cut_at_tick = Some(self.ticks);
                        vec![ClusterAction::PartitionDomain {
                            zone: "d".to_string(),
                        }]
                    }
                    Some(t) if !self.healed && self.ticks >= t + 3 => {
                        self.healed = true;
                        vec![ClusterAction::RestoreFabric]
                    }
                    _ => vec![],
                }
            }
            fn has_pending(&self, _now: Nanos) -> bool {
                self.cut_at_tick.is_some() && !self.healed
            }
        }

        let mut cfg = small(presets::pd_dense("tiny-dense", "rtx3090"));
        cfg.cluster.tick_ms = 2;
        for i in &mut cfg.instances {
            if i.role == Role::Decode {
                i.zone = "d".to_string();
            }
        }
        let mut sim = Simulation::builder(cfg)
            .with_controller(Box::new(PartitionPulse {
                cut_at_tick: None,
                ticks: 0,
                healed: false,
            }))
            .build()
            .unwrap();
        let report = sim.run();
        assert_eq!(
            report.num_finished, 20,
            "parked hand-offs must resume after the fabric heals"
        );
        let kinds: Vec<&str> =
            report.timeline.iter().map(|e| e.kind.as_str()).collect();
        assert!(kinds.contains(&"partition"), "{kinds:?}");
        assert!(kinds.contains(&"restore-fabric"));
    }

    #[test]
    fn inert_chaos_profile_is_byte_identical_to_no_controller() {
        let base = small(presets::multi_dense("tiny-dense", "rtx3090"));
        let (plain, plain_sum) = run_config(base.clone()).unwrap();
        let mut chaotic = base;
        chaotic.cluster.controller = "chaos".to_string(); // inert default profile
        let (under_chaos, chaos_sum) = run_config(chaotic).unwrap();
        assert_eq!(
            plain.to_json().to_string(),
            under_chaos.to_json().to_string(),
            "zero-fault chaos must leave no trace in the report"
        );
        assert_eq!(plain_sum.controller, "static");
        assert_eq!(chaos_sum.controller, "static");
    }

    #[test]
    fn session_affinity_reports_wrapped_name() {
        let mut cfg = small(presets::multi_dense("tiny-dense", "rtx3090"));
        cfg.router = "session-affinity".to_string();
        cfg.workload.sessions = 5;
        let mut sim = Simulation::new(cfg).unwrap();
        assert_eq!(
            sim.router_policy_name(),
            "session-affinity(least-outstanding)"
        );
        let report = sim.run();
        assert_eq!(report.num_finished, 20);
    }
}
