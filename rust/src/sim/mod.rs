//! Discrete-event simulation core.
//!
//! Time is `u64` nanoseconds ([`Nanos`]). The engine is a two-level
//! calendar queue with deterministic tie-breaking: events at equal
//! timestamps pop in insertion order (a monotone sequence number), so
//! simulations are bit-reproducible regardless of queue internals. See
//! DESIGN.md §10 for the structure and its determinism argument.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation time in nanoseconds.
pub type Nanos = u64;

pub const MICRO: Nanos = 1_000;
pub const MILLI: Nanos = 1_000_000;
pub const SECOND: Nanos = 1_000_000_000;

/// Convert seconds (f64) to simulation nanoseconds, saturating.
pub fn secs_to_nanos(s: f64) -> Nanos {
    if s <= 0.0 {
        0
    } else {
        (s * SECOND as f64).round().min(u64::MAX as f64) as Nanos
    }
}

/// Convert simulation nanoseconds to seconds.
pub fn nanos_to_secs(n: Nanos) -> f64 {
    n as f64 / SECOND as f64
}

/// An event tag dispatched by the coordinator run loop.
///
/// Keeping the payload a plain `Copy` enum (rather than boxed closures)
/// keeps the hot loop allocation-free and the schedule inspectable in
/// tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// A new request arrives at the global router.
    RequestArrival { request_id: u64 },
    /// An instance finished its current engine step and must schedule again.
    StepComplete { instance: usize },
    /// An instance was idle and new work may be available.
    Wake { instance: usize },
    /// KV-cache transfer (P/D disaggregation) completed for a request.
    KvTransferDone { request_id: u64, dst_instance: usize },
    /// An expert fetch (offloading) completed on an instance.
    ExpertFetchDone { instance: usize, layer: u64, expert: u64 },
    /// Periodic metrics sampling tick.
    MetricsTick,
    /// Periodic cluster-controller invocation (DESIGN.md §9). Never
    /// scheduled under the `static` controller, so static runs keep the
    /// pre-driver event stream byte for byte.
    ControllerTick,
    /// A `Starting` instance finished warming up and turns `Active`.
    InstanceReady { instance: usize },
    /// A scheduled hard failure (`ClusterAction::Fail`) fires.
    InstanceFail { instance: usize },
}

#[derive(Debug, Clone, Copy)]
struct Scheduled {
    at: Nanos,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, then FIFO.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Simulated time covered by one calendar bucket: 2^20 ns ≈ 1.05 ms,
/// matching the natural event spacing (step completions and arrival gaps
/// are µs-to-ms scale).
const BUCKET_BITS: u32 = 20;
/// Near-future ring size (power of two). Horizon = 512 * 2^20 ns ≈ 537 ms;
/// anything farther (controller ticks on long quiet phases, diurnal
/// arrivals) waits in the sorted overflow heap.
const NUM_BUCKETS: usize = 512;
const SLOT_MASK: usize = NUM_BUCKETS - 1;
/// Occupancy bitmap words (one bit per bucket slot).
const WORDS: usize = NUM_BUCKETS / 64;

#[inline]
fn bucket_of(at: Nanos) -> u64 {
    at >> BUCKET_BITS
}

#[inline]
fn slot_of(bucket: u64) -> usize {
    (bucket as usize) & SLOT_MASK
}

/// Deterministic event queue + clock.
///
/// A two-level calendar queue: a ring of [`NUM_BUCKETS`] near-future
/// buckets (each spanning `2^BUCKET_BITS` ns) plus a sorted overflow heap
/// for events beyond the ring horizon. The total order is exactly
/// `(at, seq)` — identical to the original binary-heap implementation:
///
/// * `base` (the active bucket) only advances in [`pop`](Self::pop), and
///   [`schedule_at`](Self::schedule_at) clamps to `now`, so no event can
///   ever target a bucket behind the active one.
/// * the active bucket is sorted by `(at, seq)` when entered and inserts
///   into it keep the undrained tail sorted (a new event always carries
///   the largest `seq`, so its position depends on `at` alone);
/// * overflow events migrate into their bucket the moment it becomes
///   active, before the entry sort — so a bucket is always fully
///   populated when it is ordered.
#[derive(Debug)]
pub struct EventQueue {
    /// Near-future FIFO buckets, indexed by `bucket & SLOT_MASK`. Only the
    /// active bucket is sorted; the rest are insertion-ordered until
    /// entered.
    buckets: Vec<Vec<Scheduled>>,
    /// One bit per occupied slot, for O(words) next-bucket scans.
    occupied: [u64; WORDS],
    /// Events currently in the ring (including the active bucket's tail).
    ring_len: usize,
    /// Bucket index (`at >> BUCKET_BITS`) of the active bucket.
    base: u64,
    /// Drain cursor within the active bucket.
    head: usize,
    /// Far-future events (bucket ≥ base + NUM_BUCKETS), earliest first.
    overflow: BinaryHeap<Scheduled>,
    now: Nanos,
    seq: u64,
    processed: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue {
            buckets: (0..NUM_BUCKETS).map(|_| Vec::new()).collect(),
            occupied: [0; WORDS],
            ring_len: 0,
            base: 0,
            head: 0,
            overflow: BinaryHeap::new(),
            now: 0,
            seq: 0,
            processed: 0,
        }
    }

    /// Current simulation time.
    pub fn now(&self) -> Nanos {
        self.now
    }

    /// Number of events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn len(&self) -> usize {
        self.ring_len + self.overflow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring_len == 0 && self.overflow.is_empty()
    }

    #[inline]
    fn mark(&mut self, slot: usize) {
        self.occupied[slot >> 6] |= 1u64 << (slot & 63);
    }

    #[inline]
    fn unmark(&mut self, slot: usize) {
        self.occupied[slot >> 6] &= !(1u64 << (slot & 63));
    }

    /// Circular distance (in buckets) from `cur_slot` to the next occupied
    /// slot. Only called with `ring_len > 0` and the active bucket empty.
    fn next_occupied_distance(&self, cur_slot: usize) -> u64 {
        for d in 1..=NUM_BUCKETS as u64 {
            let slot = (cur_slot + d as usize) & SLOT_MASK;
            if self.occupied[slot >> 6] & (1u64 << (slot & 63)) != 0 {
                return d;
            }
        }
        // simlint: allow(S01) — callers only probe when ring_len > 0, so a set bit exists
        unreachable!("ring_len > 0 but occupancy bitmap is empty");
    }

    /// Schedule `event` at absolute time `at` (clamped to now if in the
    /// past — the engine never time-travels).
    pub fn schedule_at(&mut self, at: Nanos, event: Event) {
        let at = at.max(self.now);
        let s = Scheduled {
            at,
            seq: self.seq,
            event,
        };
        self.seq += 1;
        let b = bucket_of(at);
        debug_assert!(b >= self.base, "event behind the active bucket");
        if b >= self.base.saturating_add(NUM_BUCKETS as u64) {
            self.overflow.push(s);
            return;
        }
        let slot = slot_of(b);
        if b == self.base {
            // Keep the active bucket's undrained tail sorted: the new
            // event has the largest seq, so it sits after every queued
            // event with the same timestamp.
            let bucket = &mut self.buckets[slot];
            let ins = self.head + bucket[self.head..].partition_point(|e| e.at <= at);
            bucket.insert(ins, s);
        } else {
            self.buckets[slot].push(s);
        }
        self.ring_len += 1;
        self.mark(slot);
    }

    /// Schedule `event` `delay` ns from now.
    pub fn schedule_in(&mut self, delay: Nanos, event: Event) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    /// Move `base` to the bucket holding the globally earliest event, pull
    /// that bucket's overflow stragglers in, and sort it. No-op while the
    /// active bucket still has events.
    fn advance(&mut self) {
        let cur_slot = slot_of(self.base);
        if self.head < self.buckets[cur_slot].len() {
            return;
        }
        let ring_next = if self.ring_len > 0 {
            Some(self.base + self.next_occupied_distance(cur_slot))
        } else {
            None
        };
        let over_next = self.overflow.peek().map(|s| bucket_of(s.at));
        let target = match (ring_next, over_next) {
            (Some(r), Some(o)) => r.min(o),
            (Some(r), None) => r,
            (None, Some(o)) => o,
            (None, None) => return,
        };
        self.base = target;
        self.head = 0;
        let slot = slot_of(target);
        while let Some(s) = self.overflow.peek() {
            if bucket_of(s.at) != target {
                break;
            }
            let s = *s;
            self.overflow.pop();
            self.buckets[slot].push(s);
            self.ring_len += 1;
        }
        if !self.buckets[slot].is_empty() {
            self.mark(slot);
            self.buckets[slot].sort_unstable_by_key(|s| (s.at, s.seq));
        }
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(Nanos, Event)> {
        self.advance();
        let slot = slot_of(self.base);
        if self.head >= self.buckets[slot].len() {
            return None; // ring and overflow both empty
        }
        let s = self.buckets[slot][self.head];
        self.head += 1;
        self.ring_len -= 1;
        if self.head == self.buckets[slot].len() {
            // clear() keeps the allocation — steady state reuses it.
            self.buckets[slot].clear();
            self.head = 0;
            self.unmark(slot);
        }
        debug_assert!(s.at >= self.now, "event queue went backwards");
        self.now = s.at;
        self.processed += 1;
        Some((s.at, s.event))
    }

    /// Timestamp of the next event without popping.
    pub fn peek_time(&self) -> Option<Nanos> {
        let bucket = &self.buckets[slot_of(self.base)];
        if self.head < bucket.len() {
            return Some(bucket[self.head].at);
        }
        // Active bucket drained: the next event is the earliest of the
        // next occupied ring bucket (unsorted — scan it) and the overflow
        // head. Cheap because this branch runs at most once per bucket.
        let ring_min = if self.ring_len > 0 {
            let d = self.next_occupied_distance(slot_of(self.base));
            let slot = (slot_of(self.base) + d as usize) & SLOT_MASK;
            self.buckets[slot].iter().map(|s| s.at).min()
        } else {
            None
        };
        let over_min = self.overflow.peek().map(|s| s.at);
        match (ring_min, over_min) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, Event::MetricsTick);
        q.schedule_at(10, Event::Wake { instance: 0 });
        q.schedule_at(20, Event::StepComplete { instance: 1 });
        let order: Vec<Nanos> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..5 {
            q.schedule_at(100, Event::Wake { instance: i });
        }
        let order: Vec<Event> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        let expect: Vec<Event> = (0..5).map(|i| Event::Wake { instance: i }).collect();
        assert_eq!(order, expect);
    }

    #[test]
    fn clock_advances_and_never_reverses() {
        let mut q = EventQueue::new();
        q.schedule_at(50, Event::MetricsTick);
        q.pop();
        assert_eq!(q.now(), 50);
        // scheduling in the past clamps to now
        q.schedule_at(10, Event::MetricsTick);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 50);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(100, Event::MetricsTick);
        q.pop();
        q.schedule_in(25, Event::MetricsTick);
        assert_eq!(q.peek_time(), Some(125));
    }

    #[test]
    fn unit_conversions() {
        assert_eq!(secs_to_nanos(1.5), 1_500_000_000);
        assert_eq!(secs_to_nanos(-1.0), 0);
        assert!((nanos_to_secs(2_000_000_000) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn processed_counter() {
        let mut q = EventQueue::new();
        q.schedule_at(1, Event::MetricsTick);
        q.schedule_at(2, Event::MetricsTick);
        q.pop();
        q.pop();
        assert_eq!(q.processed(), 2);
    }

    // ---- calendar-queue specifics -------------------------------------

    /// One bucket spans 2^BUCKET_BITS ns; the ring spans NUM_BUCKETS of
    /// them. Times chosen around those edges exercise ring vs overflow.
    const BUCKET: Nanos = 1 << BUCKET_BITS;
    const HORIZON: Nanos = BUCKET * NUM_BUCKETS as Nanos;

    #[test]
    fn far_future_overflow_pops_in_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3 * HORIZON, Event::MetricsTick); // deep overflow
        q.schedule_at(5, Event::Wake { instance: 1 });
        q.schedule_at(HORIZON + 7, Event::Wake { instance: 2 }); // just past horizon
        q.schedule_at(HORIZON - 1, Event::Wake { instance: 3 }); // last ring bucket
        assert_eq!(q.len(), 4);
        let order: Vec<Nanos> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![5, HORIZON - 1, HORIZON + 7, 3 * HORIZON]);
        assert!(q.is_empty());
    }

    #[test]
    fn overflow_ties_still_fifo() {
        let mut q = EventQueue::new();
        for i in 0..4 {
            q.schedule_at(2 * HORIZON, Event::Wake { instance: i });
        }
        let order: Vec<Event> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        let expect: Vec<Event> = (0..4).map(|i| Event::Wake { instance: i }).collect();
        assert_eq!(order, expect);
    }

    #[test]
    fn interleaved_push_pop_across_buckets() {
        let mut q = EventQueue::new();
        q.schedule_at(10, Event::Wake { instance: 0 });
        q.schedule_at(5 * BUCKET, Event::Wake { instance: 1 });
        assert_eq!(q.pop().unwrap().0, 10);
        // insert into the (drained) active bucket at the current time
        q.schedule_at(10, Event::Wake { instance: 2 });
        // and into a bucket between active and the queued one
        q.schedule_at(2 * BUCKET, Event::Wake { instance: 3 });
        let order: Vec<usize> = std::iter::from_fn(|| {
            q.pop().map(|(_, e)| match e {
                Event::Wake { instance } => instance,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(order, vec![2, 3, 1]);
    }

    #[test]
    fn saturating_far_future_schedule() {
        let mut q = EventQueue::new();
        q.schedule_at(100, Event::MetricsTick);
        q.pop();
        q.schedule_in(u64::MAX, Event::Wake { instance: 9 }); // saturates
        q.schedule_in(u64::MAX, Event::Wake { instance: 10 });
        assert_eq!(q.peek_time(), Some(u64::MAX));
        assert_eq!(q.pop(), Some((u64::MAX, Event::Wake { instance: 9 })));
        assert_eq!(q.pop(), Some((u64::MAX, Event::Wake { instance: 10 })));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn peek_matches_pop_when_active_bucket_drained() {
        let mut q = EventQueue::new();
        q.schedule_at(1, Event::MetricsTick);
        q.schedule_at(7 * BUCKET + 3, Event::Wake { instance: 1 });
        q.schedule_at(HORIZON + 1, Event::Wake { instance: 2 });
        q.pop(); // drains the active bucket
        assert_eq!(q.peek_time(), Some(7 * BUCKET + 3));
        assert_eq!(q.pop().unwrap().0, 7 * BUCKET + 3);
        assert_eq!(q.peek_time(), Some(HORIZON + 1));
        assert_eq!(q.pop().unwrap().0, HORIZON + 1);
        assert_eq!(q.peek_time(), None);
    }

    /// Mini soak against a sorted reference: random pushes (bursts, near
    /// and far future) interleaved with pops must match (at, seq) order
    /// exactly. The full property test lives in tests/queue_equivalence.rs.
    #[test]
    fn random_soak_matches_sorted_reference() {
        let mut q = EventQueue::new();
        let mut reference: Vec<(Nanos, u64, Event)> = vec![];
        let mut seq = 0u64;
        let mut state = 0x2545F4914F6CDD1Du64;
        let mut rand = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let mut popped = vec![];
        let mut expect = vec![];
        for round in 0..2000 {
            let delay = match rand() % 5 {
                0 => 0,
                1 => rand() % 1000,
                2 => rand() % (4 * BUCKET),
                3 => rand() % (2 * HORIZON),
                _ => rand() % (8 * HORIZON),
            };
            let ev = Event::Wake {
                instance: round as usize,
            };
            let at = q.now().saturating_add(delay);
            q.schedule_in(delay, ev);
            reference.push((at, seq, ev));
            seq += 1;
            if rand() % 3 == 0 {
                // pop the reference minimum and compare
                if let Some((t, e)) = q.pop() {
                    popped.push((t, e));
                    let min_idx = reference
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, &(a, s, _))| (a, s))
                        .map(|(i, _)| i)
                        .unwrap();
                    let (a, _, e) = reference.remove(min_idx);
                    expect.push((a, e));
                }
            }
        }
        while let Some((t, e)) = q.pop() {
            popped.push((t, e));
            let min_idx = reference
                .iter()
                .enumerate()
                .min_by_key(|(_, &(a, s, _))| (a, s))
                .map(|(i, _)| i)
                .unwrap();
            let (a, _, e) = reference.remove(min_idx);
            expect.push((a, e));
        }
        assert!(reference.is_empty());
        assert_eq!(popped, expect);
    }
}
