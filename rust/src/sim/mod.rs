//! Discrete-event simulation core.
//!
//! Time is `u64` nanoseconds ([`Nanos`]). The engine is a binary-heap event
//! queue with deterministic tie-breaking: events at equal timestamps pop in
//! insertion order (a monotone sequence number), so simulations are
//! bit-reproducible regardless of heap internals.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation time in nanoseconds.
pub type Nanos = u64;

pub const MICRO: Nanos = 1_000;
pub const MILLI: Nanos = 1_000_000;
pub const SECOND: Nanos = 1_000_000_000;

/// Convert seconds (f64) to simulation nanoseconds, saturating.
pub fn secs_to_nanos(s: f64) -> Nanos {
    if s <= 0.0 {
        0
    } else {
        (s * SECOND as f64).round().min(u64::MAX as f64) as Nanos
    }
}

/// Convert simulation nanoseconds to seconds.
pub fn nanos_to_secs(n: Nanos) -> f64 {
    n as f64 / SECOND as f64
}

/// An event tag dispatched by the coordinator run loop.
///
/// Keeping the payload a plain enum (rather than boxed closures) keeps the
/// hot loop allocation-free and the schedule inspectable in tests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// A new request arrives at the global router.
    RequestArrival { request_id: u64 },
    /// An instance finished its current engine step and must schedule again.
    StepComplete { instance: usize },
    /// An instance was idle and new work may be available.
    Wake { instance: usize },
    /// KV-cache transfer (P/D disaggregation) completed for a request.
    KvTransferDone { request_id: u64, dst_instance: usize },
    /// An expert fetch (offloading) completed on an instance.
    ExpertFetchDone { instance: usize, layer: u64, expert: u64 },
    /// Periodic metrics sampling tick.
    MetricsTick,
    /// Periodic cluster-controller invocation (DESIGN.md §9). Never
    /// scheduled under the `static` controller, so static runs keep the
    /// pre-driver event stream byte for byte.
    ControllerTick,
    /// A `Starting` instance finished warming up and turns `Active`.
    InstanceReady { instance: usize },
    /// A scheduled hard failure (`ClusterAction::Fail`) fires.
    InstanceFail { instance: usize },
}

#[derive(Debug)]
struct Scheduled {
    at: Nanos,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, then FIFO.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic event queue + clock.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    now: Nanos,
    seq: u64,
    processed: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current simulation time.
    pub fn now(&self) -> Nanos {
        self.now
    }

    /// Number of events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `at` (clamped to now if in the
    /// past — the engine never time-travels).
    pub fn schedule_at(&mut self, at: Nanos, event: Event) {
        let at = at.max(self.now);
        self.heap.push(Scheduled {
            at,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` `delay` ns from now.
    pub fn schedule_in(&mut self, delay: Nanos, event: Event) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(Nanos, Event)> {
        let s = self.heap.pop()?;
        debug_assert!(s.at >= self.now, "event queue went backwards");
        self.now = s.at;
        self.processed += 1;
        Some((s.at, s.event))
    }

    /// Timestamp of the next event without popping.
    pub fn peek_time(&self) -> Option<Nanos> {
        self.heap.peek().map(|s| s.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, Event::MetricsTick);
        q.schedule_at(10, Event::Wake { instance: 0 });
        q.schedule_at(20, Event::StepComplete { instance: 1 });
        let order: Vec<Nanos> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..5 {
            q.schedule_at(100, Event::Wake { instance: i });
        }
        let order: Vec<Event> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        let expect: Vec<Event> = (0..5).map(|i| Event::Wake { instance: i }).collect();
        assert_eq!(order, expect);
    }

    #[test]
    fn clock_advances_and_never_reverses() {
        let mut q = EventQueue::new();
        q.schedule_at(50, Event::MetricsTick);
        q.pop();
        assert_eq!(q.now(), 50);
        // scheduling in the past clamps to now
        q.schedule_at(10, Event::MetricsTick);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 50);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(100, Event::MetricsTick);
        q.pop();
        q.schedule_in(25, Event::MetricsTick);
        assert_eq!(q.peek_time(), Some(125));
    }

    #[test]
    fn unit_conversions() {
        assert_eq!(secs_to_nanos(1.5), 1_500_000_000);
        assert_eq!(secs_to_nanos(-1.0), 0);
        assert!((nanos_to_secs(2_000_000_000) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn processed_counter() {
        let mut q = EventQueue::new();
        q.schedule_at(1, Event::MetricsTick);
        q.schedule_at(2, Event::MetricsTick);
        q.pop();
        q.pop();
        assert_eq!(q.processed(), 2);
    }
}
