//! Radix tree over token sequences (RadixAttention-style prefix index).
//!
//! Nodes carry compressed token-slice edge labels. The tree answers
//! longest-prefix-match queries in O(match length) and supports LRU/LFU
//! leaf eviction; token ownership is tracked per node so the cache manager
//! can convert evictions into freed bytes.

use crate::sim::Nanos;
use crate::util::fxhash::FxHashMap;

/// Token alphabet (synthetic token ids).
pub type Token = u32;

/// Compact, policy-visible snapshot of one evictable leaf.
///
/// This is the input type of
/// [`EvictionPolicy::pick`](crate::policy::EvictionPolicy::pick)
/// (re-exported as `policy::CacheLeaf`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheLeaf {
    /// Stable node id (returned by the policy to evict this leaf).
    pub id: usize,
    /// Tokens freed if this leaf is evicted.
    pub tokens: u64,
    /// Simulation time of the last lookup touching this leaf.
    pub last_access: Nanos,
    /// Number of lookups that touched this leaf.
    pub access_count: u64,
}

#[derive(Debug)]
struct Node {
    /// Compressed edge label leading into this node (empty at root).
    label: Vec<Token>,
    /// Child index keyed by first label token. Fx-hashed: keys are
    /// synthetic token ids, so SipHash resistance buys nothing and the
    /// lookup sits on the per-insert hot path.
    children: FxHashMap<Token, usize>,
    parent: usize,
    last_access: Nanos,
    access_count: u64,
}

/// Result of a longest-prefix match.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Match {
    /// Number of tokens matched from the query's start.
    pub tokens: u64,
    /// Node ids along the matched path (for access-time bumping).
    path: Vec<usize>,
}

/// Prefix radix tree with per-node access metadata.
#[derive(Debug)]
pub struct RadixTree {
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    /// Recycled label allocations (from evicted leaves and edge splits),
    /// so steady-state insert/evict churn stops hitting the allocator.
    label_pool: Vec<Vec<Token>>,
    total_tokens: u64,
}

pub const ROOT: usize = 0;

/// Cap on pooled label vectors; beyond this, freed labels drop normally.
const LABEL_POOL_CAP: usize = 64;

impl Default for RadixTree {
    fn default() -> Self {
        Self::new()
    }
}

impl RadixTree {
    pub fn new() -> Self {
        RadixTree {
            nodes: vec![Some(Node {
                label: vec![],
                children: FxHashMap::default(),
                parent: ROOT,
                last_access: 0,
                access_count: 0,
            })],
            free: vec![],
            label_pool: vec![],
            total_tokens: 0,
        }
    }

    /// A label vector holding a copy of `toks`, reusing a pooled
    /// allocation when one is available.
    fn take_label(&mut self, toks: &[Token]) -> Vec<Token> {
        let mut label = self.label_pool.pop().unwrap_or_default();
        label.clear();
        label.extend_from_slice(toks);
        label
    }

    /// Return a freed label's allocation to the pool.
    fn pool_label(&mut self, label: Vec<Token>) {
        if label.capacity() > 0 && self.label_pool.len() < LABEL_POOL_CAP {
            self.label_pool.push(label);
        }
    }

    fn node(&self, id: usize) -> &Node {
        // simlint: allow(S01) — arena ids are only handed out for live nodes; a dangle is tree corruption
        self.nodes[id].as_ref().expect("dangling node id")
    }
    fn node_mut(&mut self, id: usize) -> &mut Node {
        // simlint: allow(S01) — arena ids are only handed out for live nodes; a dangle is tree corruption
        self.nodes[id].as_mut().expect("dangling node id")
    }

    fn alloc(&mut self, n: Node) -> usize {
        if let Some(id) = self.free.pop() {
            self.nodes[id] = Some(n);
            id
        } else {
            self.nodes.push(Some(n));
            self.nodes.len() - 1
        }
    }

    /// Total tokens stored in the tree (== cached KV tokens).
    pub fn total_tokens(&self) -> u64 {
        self.total_tokens
    }

    /// Number of live nodes (excluding root).
    pub fn num_nodes(&self) -> usize {
        self.nodes.iter().flatten().count() - 1
    }

    /// Longest-prefix match of `query` against the tree.
    pub fn match_prefix(&self, query: &[Token]) -> Match {
        let mut cur = ROOT;
        let mut matched = 0usize;
        // simlint: allow(H01) — `vec![]` is capacity-0 (no allocation until a
        // node matches); bounded by tree depth, one lookup per admission
        let mut path = vec![];
        loop {
            let node = self.node(cur);
            let Some(&next) = query.get(matched).and_then(|t| node.children.get(t))
            else {
                break;
            };
            let child = self.node(next);
            let rest = &query[matched..];
            let common = child
                .label
                .iter()
                .zip(rest.iter())
                .take_while(|(a, b)| a == b)
                .count();
            matched += common;
            if common < child.label.len() {
                // partial edge match: count the tokens but stop here.
                path.push(next);
                break;
            }
            path.push(next);
            cur = next;
        }
        Match {
            tokens: matched as u64,
            path,
        }
    }

    /// Bump access metadata along a match path.
    pub fn touch(&mut self, m: &Match, now: Nanos) {
        for &id in &m.path {
            let n = self.node_mut(id);
            n.last_access = now;
            n.access_count += 1;
        }
    }

    /// Insert `seq`, sharing existing prefixes. Returns the number of NEW
    /// tokens added to the tree.
    pub fn insert(&mut self, seq: &[Token], now: Nanos) -> u64 {
        let mut cur = ROOT;
        let mut pos = 0usize;
        loop {
            if pos == seq.len() {
                return self.finish_insert(0);
            }
            let first = seq[pos];
            match self.node(cur).children.get(&first).copied() {
                None => {
                    // new leaf with the remaining suffix
                    let label = self.take_label(&seq[pos..]);
                    let added = label.len() as u64;
                    let leaf = self.alloc(Node {
                        label,
                        children: FxHashMap::default(),
                        parent: cur,
                        last_access: now,
                        access_count: 1,
                    });
                    self.node_mut(cur).children.insert(first, leaf);
                    return self.finish_insert(added);
                }
                Some(child) => {
                    let common = {
                        let c = self.node(child);
                        c.label
                            .iter()
                            .zip(&seq[pos..])
                            .take_while(|(a, b)| a == b)
                            .count()
                    };
                    let child_label_len = self.node(child).label.len();
                    if common == child_label_len {
                        // full edge consumed; descend
                        pos += common;
                        self.node_mut(child).last_access = now;
                        cur = child;
                    } else {
                        // split the edge at `common`
                        self.split_edge(cur, child, common, now);
                        pos += common;
                        cur = self.node(child).parent; // the new mid node
                    }
                }
            }
        }
    }

    fn finish_insert(&mut self, added: u64) -> u64 {
        self.total_tokens += added;
        added
    }

    /// Split `child`'s edge after `common` tokens, introducing a mid node.
    fn split_edge(&mut self, parent: usize, child: usize, common: usize, now: Nanos) {
        debug_assert!(common > 0 && common < self.node(child).label.len());
        let (full, la, ac) = {
            let child_node = self.node_mut(child);
            (
                std::mem::take(&mut child_node.label),
                child_node.last_access,
                child_node.access_count,
            )
        };
        // Copy the suffix into a pooled vector and truncate the original
        // allocation in place for the prefix — no fresh allocation unless
        // the pool is empty.
        let suffix = self.take_label(&full[common..]);
        let mut prefix = full;
        prefix.truncate(common);
        let (first_prefix, first_suffix) = (prefix[0], suffix[0]);
        // mid node takes the prefix
        let mid = self.alloc(Node {
            label: prefix,
            children: FxHashMap::default(),
            parent,
            last_access: now.max(la),
            access_count: ac,
        });
        // child keeps the suffix, re-parented under mid
        let c = self.node_mut(child);
        c.label = suffix;
        c.parent = mid;
        self.node_mut(mid).children.insert(first_suffix, child);
        self.node_mut(parent).children.insert(first_prefix, mid);
    }

    /// Collect leaf nodes (eviction candidates).
    pub fn leaves(&self) -> Vec<CacheLeaf> {
        self.nodes
            .iter()
            .enumerate()
            .filter_map(|(id, n)| n.as_ref().map(|n| (id, n)))
            .filter(|(id, n)| *id != ROOT && n.children.is_empty())
            .map(|(id, n)| CacheLeaf {
                id,
                tokens: n.label.len() as u64,
                last_access: n.last_access,
                access_count: n.access_count,
            })
            // simlint: allow(H01) — eviction-candidate snapshot, built only
            // under cache pressure (eviction), not on the per-event path
            .collect()
    }

    /// Full token path from the root to (and including) node `id`.
    pub fn path_tokens(&self, id: usize) -> Vec<Token> {
        // Two walks: size the output exactly, then fill back-to-front by
        // slice copy — one allocation instead of one per path node.
        let mut len = 0usize;
        let mut cur = id;
        while cur != ROOT {
            let n = self.node(cur);
            len += n.label.len();
            cur = n.parent;
        }
        // simlint: allow(H01) — single exact-size allocation for the returned
        // path, on the eviction/host-demotion path only
        let mut out = vec![0 as Token; len];
        let mut end = len;
        cur = id;
        while cur != ROOT {
            let n = self.node(cur);
            let start = end - n.label.len();
            out[start..end].copy_from_slice(&n.label);
            end = start;
            cur = n.parent;
        }
        out
    }

    /// Remove a leaf node, returning its token count. Panics on non-leaf.
    pub fn remove_leaf(&mut self, id: usize) -> u64 {
        assert!(id != ROOT, "cannot remove root");
        // simlint: allow(S01) — arena ids are only handed out for live nodes; a dangle is tree corruption
        let node = self.nodes[id].take().expect("dangling node id");
        assert!(node.children.is_empty(), "remove_leaf on internal node");
        let parent = node.parent;
        let first = node.label[0];
        let freed = node.label.len() as u64;
        self.node_mut(parent).children.remove(&first);
        self.free.push(id);
        self.total_tokens -= freed;
        self.pool_label(node.label);
        freed
    }

    /// Check structural invariants (tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut counted = 0u64;
        for (id, n) in self.nodes.iter().enumerate() {
            let Some(n) = n else { continue };
            if id != ROOT {
                if n.label.is_empty() {
                    return Err(format!("node {id} has empty label"));
                }
                let parent = self
                    .nodes
                    .get(n.parent)
                    .and_then(|p| p.as_ref())
                    .ok_or(format!("node {id} has dangling parent"))?;
                if parent.children.get(&n.label[0]) != Some(&id) {
                    return Err(format!("node {id} not linked from parent"));
                }
                counted += n.label.len() as u64;
            }
            for (&t, &c) in &n.children {
                let child = self
                    .nodes
                    .get(c)
                    .and_then(|x| x.as_ref())
                    .ok_or(format!("dangling child {c}"))?;
                if child.label.first() != Some(&t) {
                    return Err(format!("child {c} keyed by wrong token"));
                }
            }
        }
        if counted != self.total_tokens {
            return Err(format!(
                "token accounting off: counted {counted} != {}",
                self.total_tokens
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn seq(xs: &[u32]) -> Vec<Token> {
        xs.to_vec()
    }

    #[test]
    fn empty_tree_matches_nothing() {
        let t = RadixTree::new();
        assert_eq!(t.match_prefix(&seq(&[1, 2, 3])).tokens, 0);
        assert_eq!(t.total_tokens(), 0);
    }

    #[test]
    fn insert_then_full_match() {
        let mut t = RadixTree::new();
        assert_eq!(t.insert(&seq(&[1, 2, 3, 4]), 10), 4);
        assert_eq!(t.match_prefix(&seq(&[1, 2, 3, 4])).tokens, 4);
        assert_eq!(t.match_prefix(&seq(&[1, 2])).tokens, 2);
        assert_eq!(t.match_prefix(&seq(&[1, 2, 9])).tokens, 2);
        assert_eq!(t.match_prefix(&seq(&[9])).tokens, 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_not_double_counted() {
        let mut t = RadixTree::new();
        t.insert(&seq(&[1, 2, 3, 4]), 1);
        let added = t.insert(&seq(&[1, 2, 3, 9, 9]), 2);
        assert_eq!(added, 2); // only the divergent suffix
        assert_eq!(t.total_tokens(), 6);
        assert_eq!(t.match_prefix(&seq(&[1, 2, 3, 9, 9])).tokens, 5);
        assert_eq!(t.match_prefix(&seq(&[1, 2, 3, 4])).tokens, 4);
        t.check_invariants().unwrap();
    }

    #[test]
    fn duplicate_insert_adds_nothing() {
        let mut t = RadixTree::new();
        t.insert(&seq(&[5, 6, 7]), 1);
        assert_eq!(t.insert(&seq(&[5, 6, 7]), 2), 0);
        assert_eq!(t.total_tokens(), 3);
    }

    #[test]
    fn edge_split_preserves_matches() {
        let mut t = RadixTree::new();
        t.insert(&seq(&[1, 2, 3, 4, 5]), 1);
        t.insert(&seq(&[1, 2, 9]), 2); // splits the 5-edge after 2 tokens
        assert_eq!(t.match_prefix(&seq(&[1, 2, 3, 4, 5])).tokens, 5);
        assert_eq!(t.match_prefix(&seq(&[1, 2, 9])).tokens, 3);
        t.check_invariants().unwrap();
    }

    #[test]
    fn leaves_and_eviction() {
        let mut t = RadixTree::new();
        t.insert(&seq(&[1, 2, 3, 4]), 1);
        t.insert(&seq(&[1, 2, 9, 9]), 5);
        let leaves = t.leaves();
        assert_eq!(leaves.len(), 2);
        // evict the older leaf ([3,4], last_access=1)
        let victim = *leaves.iter().min_by_key(|l| l.last_access).unwrap();
        assert_eq!(victim.last_access, 1);
        assert_eq!(victim.tokens, 2);
        t.remove_leaf(victim.id);
        assert_eq!(t.total_tokens(), 4);
        assert_eq!(t.match_prefix(&seq(&[1, 2, 3, 4])).tokens, 2);
        assert_eq!(t.match_prefix(&seq(&[1, 2, 9, 9])).tokens, 4);
        t.check_invariants().unwrap();
    }

    #[test]
    fn touch_updates_access_metadata() {
        let mut t = RadixTree::new();
        t.insert(&seq(&[1, 2, 3]), 1);
        let m = t.match_prefix(&seq(&[1, 2, 3]));
        t.touch(&m, 42);
        let leaves = t.leaves();
        assert_eq!(leaves[0].last_access, 42);
        assert_eq!(leaves[0].access_count, 2); // insert + touch
    }

    #[test]
    fn prop_tree_consistent_under_random_ops() {
        prop::check(
            "radix-invariants",
            96,
            |rng: &mut Rng| {
                let seqs: Vec<Vec<Token>> = (0..12)
                    .map(|_| {
                        let len = 1 + rng.below(20) as usize;
                        (0..len).map(|_| rng.below(4) as Token).collect()
                    })
                    .collect();
                seqs
            },
            |seqs| {
                let mut t = RadixTree::new();
                for (i, s) in seqs.iter().enumerate() {
                    t.insert(s, i as Nanos);
                    t.check_invariants()?;
                    // inserted sequence must fully match afterwards
                    let m = t.match_prefix(s);
                    if m.tokens != s.len() as u64 {
                        return Err(format!(
                            "inserted seq {s:?} matches only {} tokens",
                            m.tokens
                        ));
                    }
                }
                // random evictions keep the structure valid
                while t.num_nodes() > 0 {
                    let leaves = t.leaves();
                    if leaves.is_empty() {
                        break;
                    }
                    t.remove_leaf(leaves[0].id);
                    t.check_invariants()?;
                }
                Ok(())
            },
        );
    }
}
