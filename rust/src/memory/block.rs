//! Paged KV-cache block manager (PagedAttention-style).
//!
//! Device KV memory is divided into fixed-size blocks of `block_size`
//! tokens. Sequences own chains of blocks; blocks are reference-counted so
//! the prefix cache can share fully-filled prompt blocks between sequences
//! (copy-on-write is unnecessary in a simulator: decode always appends to
//! uniquely-owned tail blocks).

use crate::util::fxhash::FxHashMap;

/// Block identifier.
pub type BlockId = u32;

/// Allocation failure: not enough free blocks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OutOfBlocks {
    pub requested: usize,
    pub free: usize,
}

impl std::fmt::Display for OutOfBlocks {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "out of KV blocks: requested {}, free {}",
            self.requested, self.free
        )
    }
}

impl std::error::Error for OutOfBlocks {}

/// Fixed-pool, ref-counted block allocator.
#[derive(Debug, Clone)]
pub struct BlockManager {
    block_size: u64,
    total: usize,
    free_list: Vec<BlockId>,
    refcount: Vec<u32>,
    /// Sequence table: request id -> owned block chain (in token order).
    seqs: FxHashMap<u64, Vec<BlockId>>,
}

impl BlockManager {
    /// `capacity_bytes / (block_size * kv_bytes_per_token)` blocks.
    pub fn new(capacity_bytes: u64, block_size: u64, kv_bytes_per_token: u64) -> Self {
        let block_bytes = block_size * kv_bytes_per_token;
        let total = (capacity_bytes / block_bytes.max(1)) as usize;
        BlockManager {
            block_size,
            total,
            free_list: (0..total as BlockId).rev().collect(),
            refcount: vec![0; total],
            seqs: FxHashMap::default(),
        }
    }

    pub fn block_size(&self) -> u64 {
        self.block_size
    }
    pub fn total_blocks(&self) -> usize {
        self.total
    }
    pub fn free_blocks(&self) -> usize {
        self.free_list.len()
    }
    pub fn used_blocks(&self) -> usize {
        self.total - self.free_list.len()
    }
    /// Fraction of the pool in use.
    pub fn utilization(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.used_blocks() as f64 / self.total as f64
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: u64) -> usize {
        tokens.div_ceil(self.block_size) as usize
    }

    /// Whether `n` fresh blocks can be allocated.
    pub fn can_allocate(&self, n: usize) -> bool {
        self.free_list.len() >= n
    }

    fn alloc_one(&mut self) -> Option<BlockId> {
        let id = self.free_list.pop()?;
        self.refcount[id as usize] = 1;
        Some(id)
    }

    /// Allocate a chain for a new sequence holding `tokens` tokens,
    /// optionally starting with shared (ref-bumped) prefix blocks.
    pub fn allocate_seq(
        &mut self,
        seq_id: u64,
        tokens: u64,
        shared_prefix: &[BlockId],
    ) -> Result<(), OutOfBlocks> {
        assert!(!self.seqs.contains_key(&seq_id), "seq {seq_id} exists");
        let needed_total = self.blocks_for(tokens);
        let shared = shared_prefix.len().min(needed_total);
        let fresh = needed_total - shared;
        if !self.can_allocate(fresh) {
            return Err(OutOfBlocks {
                requested: fresh,
                free: self.free_list.len(),
            });
        }
        let mut chain = Vec::with_capacity(needed_total);
        for &b in &shared_prefix[..shared] {
            self.refcount[b as usize] += 1;
            chain.push(b);
        }
        for _ in 0..fresh {
            // simlint: allow(S01) — can_allocate(fresh) was checked above; the pop cannot fail
            chain.push(self.alloc_one().unwrap());
        }
        self.seqs.insert(seq_id, chain);
        Ok(())
    }

    /// Grow a sequence to hold `new_tokens` total tokens (decode append).
    pub fn grow_seq(&mut self, seq_id: u64, new_tokens: u64) -> Result<(), OutOfBlocks> {
        let have = self
            .seqs
            .get(&seq_id)
            // simlint: allow(S01) — growing an unknown sequence is caller error; fail fast
            .unwrap_or_else(|| panic!("unknown seq {seq_id}"))
            .len();
        let need = self.blocks_for(new_tokens);
        if need <= have {
            return Ok(());
        }
        let fresh = need - have;
        if !self.can_allocate(fresh) {
            return Err(OutOfBlocks {
                requested: fresh,
                free: self.free_list.len(),
            });
        }
        for _ in 0..fresh {
            // simlint: allow(S01) — can_allocate(fresh) was checked above; the pop cannot fail
            let b = self.alloc_one().unwrap();
            // simlint: allow(S01) — presence checked at function entry via the same key
            self.seqs.get_mut(&seq_id).unwrap().push(b);
        }
        Ok(())
    }

    /// Release a sequence; blocks return to the pool when refcount drops
    /// to zero. Returns the freed block ids.
    pub fn free_seq(&mut self, seq_id: u64) -> Vec<BlockId> {
        let chain = self.seqs.remove(&seq_id).unwrap_or_default();
        // simlint: allow(H01) — the freed-id list is the return value, built
        // once per finished/evicted sequence (not per step or per event)
        let mut freed = vec![];
        for b in chain {
            self.release_block(b, &mut freed);
        }
        freed
    }

    fn release_block(&mut self, b: BlockId, freed: &mut Vec<BlockId>) {
        let rc = &mut self.refcount[b as usize];
        debug_assert!(*rc > 0);
        *rc -= 1;
        if *rc == 0 {
            self.free_list.push(b);
            freed.push(b);
        }
    }

    /// Pin blocks for external sharing (prefix cache insert): bump refcount
    /// so the blocks survive their owning sequence.
    pub fn pin_blocks(&mut self, blocks: &[BlockId]) {
        for &b in blocks {
            assert!(self.refcount[b as usize] > 0, "pin of free block {b}");
            self.refcount[b as usize] += 1;
        }
    }

    /// Unpin previously pinned blocks (prefix cache eviction).
    pub fn unpin_blocks(&mut self, blocks: &[BlockId]) -> Vec<BlockId> {
        let mut freed = vec![];
        for &b in blocks {
            self.release_block(b, &mut freed);
        }
        freed
    }

    /// The block chain of a sequence.
    pub fn seq_blocks(&self, seq_id: u64) -> Option<&[BlockId]> {
        self.seqs.get(&seq_id).map(|v| v.as_slice())
    }

    /// Number of live sequences.
    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Invariant check (tests / debug builds): refcounts, free list, and
    /// sequence chains are mutually consistent.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut expected = vec![0u32; self.total];
        // simlint: allow(D04) — accumulates per-block counts; commutative over u32 adds
        for chain in self.seqs.values() {
            for &b in chain {
                expected[b as usize] += 1;
            }
        }
        for &b in &self.free_list {
            if self.refcount[b as usize] != 0 {
                return Err(format!("free block {b} has refcount"));
            }
        }
        for (i, (&rc, &exp)) in self.refcount.iter().zip(&expected).enumerate() {
            // pins (prefix cache) may exceed chain ownership
            if rc < exp {
                return Err(format!(
                    "block {i}: refcount {rc} < chain ownership {exp}"
                ));
            }
            if rc == 0 && exp > 0 {
                return Err(format!("block {i} owned but refcount 0"));
            }
        }
        let free_set: std::collections::BTreeSet<BlockId> =
            self.free_list.iter().copied().collect();
        if free_set.len() != self.free_list.len() {
            return Err("duplicate blocks in free list".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn mgr(blocks: usize) -> BlockManager {
        // block_size 16 tokens, 1 byte/token → capacity = blocks*16
        BlockManager::new(blocks as u64 * 16, 16, 1)
    }

    #[test]
    fn pool_sizing() {
        let m = mgr(10);
        assert_eq!(m.total_blocks(), 10);
        assert_eq!(m.free_blocks(), 10);
        assert_eq!(m.blocks_for(1), 1);
        assert_eq!(m.blocks_for(16), 1);
        assert_eq!(m.blocks_for(17), 2);
    }

    #[test]
    fn allocate_and_free_roundtrip() {
        let mut m = mgr(10);
        m.allocate_seq(1, 40, &[]).unwrap(); // 3 blocks
        assert_eq!(m.used_blocks(), 3);
        m.check_invariants().unwrap();
        let freed = m.free_seq(1);
        assert_eq!(freed.len(), 3);
        assert_eq!(m.free_blocks(), 10);
        m.check_invariants().unwrap();
    }

    #[test]
    fn allocation_fails_when_exhausted() {
        let mut m = mgr(4);
        m.allocate_seq(1, 48, &[]).unwrap(); // 3 blocks
        let err = m.allocate_seq(2, 32, &[]).unwrap_err();
        assert_eq!(err.requested, 2);
        assert_eq!(err.free, 1);
        // failed allocation must not leak
        assert_eq!(m.used_blocks(), 3);
        m.check_invariants().unwrap();
    }

    #[test]
    fn grow_appends_blocks() {
        let mut m = mgr(10);
        m.allocate_seq(1, 16, &[]).unwrap();
        m.grow_seq(1, 17).unwrap();
        assert_eq!(m.seq_blocks(1).unwrap().len(), 2);
        m.grow_seq(1, 20).unwrap(); // still 2 blocks
        assert_eq!(m.seq_blocks(1).unwrap().len(), 2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_refcounting() {
        let mut m = mgr(10);
        m.allocate_seq(1, 32, &[]).unwrap();
        let prefix: Vec<BlockId> = m.seq_blocks(1).unwrap().to_vec();
        m.allocate_seq(2, 48, &prefix).unwrap(); // shares 2, allocs 1
        assert_eq!(m.used_blocks(), 3);
        // freeing seq 1 must not free shared blocks
        m.free_seq(1);
        assert_eq!(m.used_blocks(), 3);
        m.check_invariants().unwrap();
        m.free_seq(2);
        assert_eq!(m.free_blocks(), 10);
    }

    #[test]
    fn pin_survives_owner() {
        let mut m = mgr(10);
        m.allocate_seq(1, 32, &[]).unwrap();
        let blocks: Vec<BlockId> = m.seq_blocks(1).unwrap().to_vec();
        m.pin_blocks(&blocks);
        m.free_seq(1);
        assert_eq!(m.used_blocks(), 2); // pinned blocks still resident
        let freed = m.unpin_blocks(&blocks);
        assert_eq!(freed.len(), 2);
        assert_eq!(m.free_blocks(), 10);
    }

    #[test]
    fn utilization_tracks() {
        let mut m = mgr(4);
        assert_eq!(m.utilization(), 0.0);
        m.allocate_seq(1, 32, &[]).unwrap();
        assert!((m.utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn prop_random_alloc_free_never_corrupts() {
        prop::check(
            "blockmgr-invariants",
            64,
            |rng: &mut Rng| {
                // generate a random op sequence
                let ops: Vec<(u8, u64)> = (0..40)
                    .map(|_| (rng.below(3) as u8, 1 + rng.below(60)))
                    .collect();
                ops
            },
            |ops| {
                let mut m = mgr(16);
                let mut live: Vec<u64> = vec![];
                let mut next_id = 0u64;
                for &(op, arg) in ops {
                    match op {
                        0 => {
                            let id = next_id;
                            next_id += 1;
                            if m.allocate_seq(id, arg, &[]).is_ok() {
                                live.push(id);
                            }
                        }
                        1 => {
                            if let Some(&id) = live.first() {
                                let _ = m.grow_seq(id, arg + 60);
                            }
                        }
                        _ => {
                            if !live.is_empty() {
                                let id = live.remove(0);
                                m.free_seq(id);
                            }
                        }
                    }
                    m.check_invariants()?;
                }
                for id in live {
                    m.free_seq(id);
                }
                if m.free_blocks() != 16 {
                    return Err(format!("leak: {} free of 16", m.free_blocks()));
                }
                Ok(())
            },
        );
    }
}
