//! Prefix cache manager (§II-D): radix-tree index + tiered residency +
//! pluggable eviction.
//!
//! Tier 1 is the compute unit's local memory (GPU/NPU HBM); evictions spill
//! to host CPU memory (tier 2) and are dropped beyond that. Lookups report
//! how many tokens hit each tier so the instance can insert the
//! corresponding memory-transfer events into its execution trace (device
//! hits avoid prefill compute outright; host hits additionally pay a
//! host->device transfer priced by the caller from `HardwareSpec::host_bw`).
//! Hierarchies with more tiers (e.g. SSD) are modeled by chaining managers.
//!
//! Victim selection is a [`EvictionPolicy`] trait object: the built-ins
//! below back the registry's `lru`, `lfu`, and `largest` entries, and
//! custom policies plug in via
//! [`crate::policy::register_evict_policy`] or
//! [`Simulation::builder`](crate::coordinator::Simulation::builder) with no
//! edits to this module.

use super::radix::{RadixTree, Token};
use crate::policy::{CacheLeaf, EvictionPolicy};
use crate::sim::Nanos;

/// Typed handle for the built-in eviction policies.
///
/// The cache itself stores a `Box<dyn EvictionPolicy>`; this enum is the
/// convenience bridge for code that wants a `Copy` value (tests, ablation
/// benches) — `to_policy()` instantiates the matching trait object, and
/// `as_str()` is the registry name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Least-recently-used leaf first (RadixAttention default).
    Lru,
    /// Least-frequently-used leaf first.
    Lfu,
    /// Largest leaf first (frees the most tokens per eviction).
    LargestFirst,
}

impl std::str::FromStr for EvictPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<EvictPolicy, Self::Err> {
        Ok(match s {
            "lru" => EvictPolicy::Lru,
            "lfu" => EvictPolicy::Lfu,
            "largest" => EvictPolicy::LargestFirst,
            _ => anyhow::bail!("unknown evict policy '{s}' (lru|lfu|largest)"),
        })
    }
}

impl EvictPolicy {
    pub fn all() -> &'static [EvictPolicy] {
        &[EvictPolicy::Lru, EvictPolicy::Lfu, EvictPolicy::LargestFirst]
    }

    pub fn as_str(self) -> &'static str {
        match self {
            EvictPolicy::Lru => "lru",
            EvictPolicy::Lfu => "lfu",
            EvictPolicy::LargestFirst => "largest",
        }
    }

    /// Instantiate the matching built-in trait object.
    pub fn to_policy(self) -> Box<dyn EvictionPolicy> {
        match self {
            EvictPolicy::Lru => Box::new(Lru),
            EvictPolicy::Lfu => Box::new(Lfu),
            EvictPolicy::LargestFirst => Box::new(LargestFirst),
        }
    }
}

// ---------------------------------------------------------------------------
// Built-in eviction policies
// ---------------------------------------------------------------------------

/// Least-recently-used leaf first (RadixAttention default).
#[derive(Debug, Default)]
pub struct Lru;

impl EvictionPolicy for Lru {
    fn name(&self) -> &str {
        "lru"
    }
    fn pick(&mut self, leaves: &[CacheLeaf]) -> Option<usize> {
        leaves
            .iter()
            .min_by_key(|l| (l.last_access, l.id))
            .map(|l| l.id)
    }
}

/// Least-frequently-used leaf first.
#[derive(Debug, Default)]
pub struct Lfu;

impl EvictionPolicy for Lfu {
    fn name(&self) -> &str {
        "lfu"
    }
    fn pick(&mut self, leaves: &[CacheLeaf]) -> Option<usize> {
        leaves
            .iter()
            .min_by_key(|l| (l.access_count, l.id))
            .map(|l| l.id)
    }
}

/// Largest leaf first (frees the most tokens per eviction).
#[derive(Debug, Default)]
pub struct LargestFirst;

impl EvictionPolicy for LargestFirst {
    fn name(&self) -> &str {
        "largest"
    }
    fn pick(&mut self, leaves: &[CacheLeaf]) -> Option<usize> {
        leaves
            .iter()
            .max_by_key(|l| (l.tokens, l.id))
            .map(|l| l.id)
    }
}

/// Result of a prefix lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PrefixHit {
    /// Tokens resident in device memory (skip prefill compute, local read).
    pub device_tokens: u64,
    /// Additional tokens resident in host memory (skip compute, but pay a
    /// host->device transfer of `host_tokens * kv_bytes_per_token`).
    pub host_tokens: u64,
}

impl PrefixHit {
    pub fn total(&self) -> u64 {
        self.device_tokens + self.host_tokens
    }
}

/// Cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hit_tokens_device: u64,
    pub hit_tokens_host: u64,
    pub queried_tokens: u64,
    pub inserted_tokens: u64,
    pub evicted_to_host: u64,
    pub dropped_tokens: u64,
}

impl CacheStats {
    /// Fraction of queried tokens served from any tier.
    pub fn hit_rate(&self) -> f64 {
        if self.queried_tokens == 0 {
            0.0
        } else {
            (self.hit_tokens_device + self.hit_tokens_host) as f64
                / self.queried_tokens as f64
        }
    }
}

/// Two-tier prefix cache for one scope (instance-local or global).
pub struct PrefixCache {
    device: RadixTree,
    host: RadixTree,
    /// Device-tier capacity in tokens.
    pub device_capacity: u64,
    /// Host-tier capacity in tokens.
    pub host_capacity: u64,
    /// Device-tier victim selection. The host tier always uses LRU: it is
    /// a spill buffer whose contents were already chosen for eviction once.
    policy: Box<dyn EvictionPolicy>,
    pub stats: CacheStats,
}

impl std::fmt::Debug for PrefixCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PrefixCache")
            .field("device_tokens", &self.device.total_tokens())
            .field("host_tokens", &self.host.total_tokens())
            .field("device_capacity", &self.device_capacity)
            .field("host_capacity", &self.host_capacity)
            .field("policy", &self.policy.name())
            .field("stats", &self.stats)
            .finish()
    }
}

impl PrefixCache {
    /// Build with a built-in eviction policy (convenience; see
    /// [`PrefixCache::with_policy`] for custom trait objects).
    pub fn new(device_capacity: u64, host_capacity: u64, policy: EvictPolicy) -> Self {
        Self::with_policy(device_capacity, host_capacity, policy.to_policy())
    }

    /// Build with an arbitrary (possibly custom) eviction policy.
    pub fn with_policy(
        device_capacity: u64,
        host_capacity: u64,
        policy: Box<dyn EvictionPolicy>,
    ) -> Self {
        PrefixCache {
            device: RadixTree::new(),
            host: RadixTree::new(),
            device_capacity,
            host_capacity,
            policy,
            stats: CacheStats::default(),
        }
    }

    /// Name of the device-tier eviction policy.
    pub fn policy_name(&self) -> &str {
        self.policy.name()
    }

    pub fn device_tokens(&self) -> u64 {
        self.device.total_tokens()
    }
    pub fn host_tokens(&self) -> u64 {
        self.host.total_tokens()
    }

    /// Longest-prefix lookup. On a host hit the matched host prefix is
    /// promoted into the device tier (the "memory-transfer event" of §II-D);
    /// the caller prices the transfer from the returned `host_tokens`.
    pub fn lookup(&mut self, query: &[Token], now: Nanos) -> PrefixHit {
        self.stats.lookups += 1;
        self.stats.queried_tokens += query.len() as u64;

        let dev = self.device.match_prefix(query);
        self.device.touch(&dev, now);
        let host = self.host.match_prefix(query);
        self.host.touch(&host, now);

        let device_tokens = dev.tokens;
        let host_extra = host.tokens.saturating_sub(dev.tokens);
        if host_extra > 0 {
            // Promote the full host-matched prefix to device.
            let promoted = &query[..host.tokens as usize];
            self.insert_device(promoted, now);
        }
        self.stats.hit_tokens_device += device_tokens;
        self.stats.hit_tokens_host += host_extra;
        PrefixHit {
            device_tokens,
            host_tokens: host_extra,
        }
    }

    /// Non-mutating best-match length across both tiers (router peek —
    /// §II-B: routing can adapt to the state of prefix caches).
    pub fn peek(&self, query: &[Token]) -> u64 {
        let dev = self.device.match_prefix(query).tokens;
        let host = self.host.match_prefix(query).tokens;
        dev.max(host)
    }

    /// Insert a finished prompt's tokens into the device tier (after
    /// prefill, §II-D: "new prefixes are inserted into radix tree").
    pub fn insert(&mut self, seq: &[Token], now: Nanos) {
        let added = self.insert_device(seq, now);
        self.stats.inserted_tokens += added;
    }

    fn insert_device(&mut self, seq: &[Token], now: Nanos) -> u64 {
        let added = self.device.insert(seq, now);
        // capacity pressure triggers eviction (spill to host tier)
        while self.device.total_tokens() > self.device_capacity {
            if !self.evict_one(now) {
                break;
            }
        }
        added
    }

    /// Evict one device leaf to the host tier. Returns false if nothing is
    /// evictable (or the policy refuses).
    fn evict_one(&mut self, now: Nanos) -> bool {
        let leaves = self.device.leaves();
        let Some(victim) = self.policy.pick(&leaves) else {
            return false;
        };
        // Hard check even in release: the natural custom-policy bug —
        // returning a slice *index* instead of a leaf *id* — would
        // otherwise evict the wrong leaf silently (or panic deep inside
        // the radix tree without naming the misbehaving policy).
        assert!(
            leaves.iter().any(|l| l.id == victim),
            "eviction policy '{}' picked leaf {}, which is not a candidate \
             (leaf ids: {:?}); EvictionPolicy::pick must return the `id` \
             field of one of the leaves it was given",
            self.policy.name(),
            victim,
            // simlint: allow(H01) — assert message: built only when the
            // eviction-policy contract is already violated
            leaves.iter().map(|l| l.id).collect::<Vec<_>>()
        );
        // Reconstruct the leaf's full token path before removal so the host
        // tier indexes the complete prefix.
        let path = self.device.path_tokens(victim);
        let freed = self.device.remove_leaf(victim);
        self.stats.evicted_to_host += freed;
        self.host.insert(&path, now);
        while self.host.total_tokens() > self.host_capacity {
            let hl = self.host.leaves();
            let Some(v) = Lru.pick(&hl) else {
                break;
            };
            let dropped = self.host.remove_leaf(v);
            self.stats.dropped_tokens += dropped;
        }
        true
    }

    /// Invariant check for tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.device.check_invariants()?;
        self.host.check_invariants()?;
        if self.device.total_tokens() > self.device_capacity.max(1) * 2 {
            return Err("device tier grossly over capacity".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(range: std::ops::Range<u32>) -> Vec<Token> {
        range.collect()
    }

    #[test]
    fn miss_then_hit() {
        let mut c = PrefixCache::new(1000, 1000, EvictPolicy::Lru);
        let q = toks(0..64);
        assert_eq!(c.lookup(&q, 1).total(), 0);
        c.insert(&q, 1);
        let hit = c.lookup(&q, 2);
        assert_eq!(hit.device_tokens, 64);
        assert_eq!(hit.host_tokens, 0);
        assert!(c.stats.hit_rate() > 0.0);
    }

    #[test]
    fn partial_prefix_hit() {
        let mut c = PrefixCache::new(1000, 1000, EvictPolicy::Lru);
        c.insert(&toks(0..32), 1);
        let mut q = toks(0..32);
        q.extend([900, 901, 902]);
        let hit = c.lookup(&q, 2);
        assert_eq!(hit.device_tokens, 32);
    }

    #[test]
    fn eviction_spills_to_host_and_promotes_back() {
        // device holds 40 tokens; insert two 32-token disjoint prompts
        let mut c = PrefixCache::new(40, 1000, EvictPolicy::Lru);
        let a = toks(0..32);
        let b = toks(100..132);
        c.insert(&a, 1);
        c.insert(&b, 2); // forces eviction of `a` (LRU)
        assert!(c.device_tokens() <= 40);
        assert!(c.stats.evicted_to_host > 0);
        // `a` now hits in host tier and is promoted
        let hit = c.lookup(&a, 3);
        assert_eq!(hit.total(), 32);
        assert!(hit.host_tokens > 0, "expected host-tier hit: {hit:?}");
        c.check_invariants().unwrap();
        // second lookup is a pure device hit post-promotion
        let hit2 = c.lookup(&a, 4);
        assert!(hit2.device_tokens >= hit.host_tokens);
    }

    #[test]
    fn host_capacity_drops_tokens() {
        let mut c = PrefixCache::new(32, 16, EvictPolicy::Lru);
        c.insert(&toks(0..32), 1);
        c.insert(&toks(100..132), 2);
        c.insert(&toks(200..232), 3);
        assert!(c.host_tokens() <= 16);
        assert!(c.stats.dropped_tokens > 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn lfu_keeps_hot_prefix() {
        let mut c = PrefixCache::new(70, 1000, EvictPolicy::Lfu);
        let hot = toks(0..32);
        let cold = toks(100..132);
        c.insert(&hot, 1);
        c.insert(&cold, 2);
        for t in 3..10 {
            c.lookup(&hot, t); // heat up `hot`
        }
        c.insert(&toks(200..232), 20); // forces one eviction
        let hot_hit = c.lookup(&hot, 30);
        assert_eq!(hot_hit.device_tokens, 32, "hot prefix must stay resident");
    }

    #[test]
    fn largest_first_frees_most() {
        let mut c = PrefixCache::new(100, 1000, EvictPolicy::LargestFirst);
        c.insert(&toks(0..80), 1);
        c.insert(&toks(100..120), 2);
        c.insert(&toks(200..240), 3); // over capacity → evict the 80-leaf
        assert!(c.lookup(&toks(0..80), 4).device_tokens < 80);
        assert_eq!(c.lookup(&toks(100..120), 5).device_tokens, 20);
    }

    #[test]
    fn shared_prefix_single_copy() {
        let mut c = PrefixCache::new(1000, 1000, EvictPolicy::Lru);
        let mut a = toks(0..32);
        a.extend([500, 501]);
        let mut b = toks(0..32);
        b.extend([600, 601]);
        c.insert(&a, 1);
        c.insert(&b, 2);
        // 32 shared + 2 + 2 unique
        assert_eq!(c.device_tokens(), 36);
    }

    #[test]
    fn custom_policy_via_with_policy() {
        /// Evicts the leaf with the smallest id — pathological but legal.
        struct SmallestId;
        impl EvictionPolicy for SmallestId {
            fn name(&self) -> &str {
                "smallest-id"
            }
            fn pick(&mut self, leaves: &[CacheLeaf]) -> Option<usize> {
                leaves.iter().map(|l| l.id).min()
            }
        }
        let mut c = PrefixCache::with_policy(40, 1000, Box::new(SmallestId));
        assert_eq!(c.policy_name(), "smallest-id");
        c.insert(&toks(0..32), 1);
        c.insert(&toks(100..132), 2);
        assert!(c.device_tokens() <= 40, "custom policy must still evict");
        c.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "not a candidate")]
    fn policy_returning_non_leaf_id_is_caught() {
        // The natural custom-policy bug: a slice index instead of a leaf
        // id. usize::MAX can never be a valid node id.
        struct IndexNotId;
        impl EvictionPolicy for IndexNotId {
            fn name(&self) -> &str {
                "index-not-id"
            }
            fn pick(&mut self, _leaves: &[CacheLeaf]) -> Option<usize> {
                Some(usize::MAX)
            }
        }
        let mut c = PrefixCache::with_policy(40, 1000, Box::new(IndexNotId));
        c.insert(&toks(0..32), 1);
        c.insert(&toks(100..132), 2); // over capacity → pick() → panic
    }

    #[test]
    fn refusing_policy_stops_eviction() {
        struct Never;
        impl EvictionPolicy for Never {
            fn name(&self) -> &str {
                "never"
            }
            fn pick(&mut self, _leaves: &[CacheLeaf]) -> Option<usize> {
                None
            }
        }
        let mut c = PrefixCache::with_policy(40, 1000, Box::new(Never));
        c.insert(&toks(0..32), 1);
        c.insert(&toks(100..132), 2);
        // nothing evicted: the device tier runs over capacity instead
        assert_eq!(c.device_tokens(), 64);
        assert_eq!(c.stats.evicted_to_host, 0);
    }

    #[test]
    fn policy_parsing() {
        // std::str::FromStr (not an inherent shadow), so `.parse()` works.
        assert_eq!("lru".parse::<EvictPolicy>().unwrap(), EvictPolicy::Lru);
        assert_eq!("lfu".parse::<EvictPolicy>().unwrap(), EvictPolicy::Lfu);
        assert_eq!(
            "largest".parse::<EvictPolicy>().unwrap(),
            EvictPolicy::LargestFirst
        );
        assert!("fifo".parse::<EvictPolicy>().is_err());
        assert_eq!(EvictPolicy::Lru.as_str(), "lru");
        // as_str <-> parse <-> to_policy round-trip for every variant
        for p in EvictPolicy::all() {
            assert_eq!(p.as_str().parse::<EvictPolicy>().unwrap(), *p);
            assert_eq!(p.to_policy().name(), p.as_str());
        }
    }
}
