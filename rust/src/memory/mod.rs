//! Memory modeling: paged KV-cache blocks, radix-tree prefix index, and the
//! tiered prefix-cache manager (§II-D).

pub mod block;
pub mod cache;
pub mod radix;

pub use block::{BlockId, BlockManager, OutOfBlocks};
pub use cache::{
    CacheStats, EvictPolicy, LargestFirst, Lfu, Lru, PrefixCache, PrefixHit,
};
pub use radix::{CacheLeaf, RadixTree, Token};

#[cfg(test)]
mod tests {
    use super::radix::RadixTree;

    #[test]
    fn path_tokens_reconstructs_full_prefix() {
        let mut t = RadixTree::new();
        t.insert(&[1, 2, 3, 4, 5], 1);
        t.insert(&[1, 2, 9], 2); // split after [1,2]
        let leaves = t.leaves();
        for leaf in leaves {
            let path = t.path_tokens(leaf.id);
            // every reconstructed path must fully match in the tree
            assert_eq!(t.match_prefix(&path).tokens, path.len() as u64);
            assert!(path.starts_with(&[1, 2]));
        }
    }
}
