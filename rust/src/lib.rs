//! LLMServingSim2.0 — a unified simulator for heterogeneous hardware and
//! serving techniques in LLM infrastructure (reproduction).
//!
//! Architecture (three layers, see DESIGN.md):
//! * **Rust (this crate)** — the discrete-event serving simulator: global
//!   request router, heterogeneous multi-instance serving, P/D
//!   disaggregation, MoE expert parallelism/offloading, radix-tree prefix
//!   caching, trace-driven performance modeling, plus the PJRT runtime and
//!   operator-level profiler.
//! * **JAX (build-time)** — the operator zoo lowered to HLO text artifacts.
//! * **Pallas (build-time)** — attention/expert-FFN kernels inside those
//!   artifacts.
//!
//! The simulation core is `Send` end-to-end (perf models are
//! `Arc<dyn PerfModel + Send + Sync>`), which the [`sweep`] engine exploits
//! to run whole configuration grids across worker threads while keeping
//! every individual simulation sequential and bit-deterministic.
//!
//! Every serving decision point — request routing, wait-queue scheduling,
//! prefix-cache eviction, and traffic generation — is a named, registered
//! trait object (see [`policy`]): configs store policy *names*, a
//! [`policy::PolicyRegistry`] maps names to factories, and resolution
//! happens once when a [`coordinator::Simulation`] is built. Custom
//! policies plug in through [`policy::register_route_policy`] & friends or
//! per-simulation via [`coordinator::Simulation::builder`], with zero core
//! edits.
//!
//! Hardware is the third registered axis (see [`perf::hardware`]): the
//! four built-in device presets live in a global `HardwareRegistry`
//! alongside user-profiled devices imported as **hardware bundles** (spec
//! + trace samples + calibration factors, one JSON file emitted by
//! `profile --emit-bundle`). A registered device resolves by name in
//! configs, `simulate --hardware`, and `sweep --hardware all`, priced by
//! trace interpolation where samples exist and calibrated roofline
//! elsewhere — the paper's single-command accelerator integration.
//!
//! The fleet itself is open too: execution is a stepped
//! [`coordinator::SimDriver`] (`step`/`run_until`/`finish`) over the event
//! queue, and a [`cluster::ClusterController`] — the fourth registered
//! axis — is invoked on a configurable tick with a read-only
//! [`cluster::ClusterView`], returning typed [`cluster::ClusterAction`]s
//! (scale up/down, drain, fail, recover, retune). Instances carry a
//! lifecycle (`Starting -> Active -> Draining -> Stopped`); the `static`
//! built-in reproduces the frozen-fleet behavior byte for byte.
//!
//! The [`workload`] engine streams requests into the coordinator (a
//! pull-based [`workload::TrafficSource`] — Poisson, bursty MMPP, diurnal,
//! closed-loop sessions, trace replay, or custom), annotated with tenants
//! and SLO classes that flow through scheduling into per-class/per-tenant
//! SLO-attainment and goodput reporting. Million-request scenarios run in
//! memory bounded by in-flight state.
//!
//! The crate polices its own determinism contract: the [`lint`] module (and
//! the `simlint` binary built from it) statically checks the core modules
//! for entropy leaks — SipHash maps, ambient clocks, unseeded RNGs,
//! hash-order enumeration in reports — and for unjustified panics. CI runs
//! it on every push; see DESIGN.md §11.

#![forbid(unsafe_code)]
#![deny(unused_must_use)]

pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod groundtruth;
pub mod instance;
pub mod lint;
pub mod memory;
pub mod metrics;
pub mod moe;
pub mod model;
pub mod network;
pub mod perf;
pub mod policy;
pub mod router;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod util;
pub mod workload;
